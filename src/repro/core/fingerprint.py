"""Content fingerprints for (config, params) — the result-cache identity.

A cross-request attribution cache (``serve.result_cache``) and warm-start
persistence (``serve.warm_state``) both need to answer "is this the same
model?" byte-precisely: an attribution computed under different weights is a
different artifact, and a restored executable whose closure baked different
params would silently return wrong results. The fingerprint is sha256 over

  * the frozen ``ArchConfig``'s ``repr`` (deterministic for a frozen
    dataclass: field order is class order, values are primitives), and
  * every param leaf's tree path, dtype, shape, and raw bytes.

Hashing a reduced config's params is ~ms; for production-size trees callers
should compute it once and reuse (``ExplainEngine.model_fingerprint`` caches).
"""
from __future__ import annotations

import hashlib
from typing import Any

import jax
import numpy as np


def config_fingerprint(cfg: Any) -> str:
    """sha256 hex of the config's deterministic ``repr``."""
    return hashlib.sha256(repr(cfg).encode()).hexdigest()


def params_fingerprint(params: Any) -> str:
    """sha256 hex over every leaf's (tree path, dtype, shape, bytes).

    The tree path rides the hash so structurally different trees with the
    same leaf bytes never collide; leaves are hashed in flatten order, which
    is deterministic for a given tree.
    """
    h = hashlib.sha256()
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        a = np.asarray(leaf)
        h.update(jax.tree_util.keystr(path).encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def model_fingerprint(cfg: Any, params: Any) -> str:
    """One identity for (architecture, weights) — what caches key on."""
    h = hashlib.sha256()
    h.update(config_fingerprint(cfg).encode())
    h.update(params_fingerprint(params).encode())
    return h.hexdigest()
