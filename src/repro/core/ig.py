"""The IG engine — stage 2: batched, chunked gradient accumulation.

One compiled program serves every schedule (uniform / paper / warp / gauss):
the (alphas, weights) vectors are runtime data. The step axis is folded into
the batch axis (the paper's GPU batching, as a shardable pjit data axis), and
steps are processed in static-size chunks under ``lax.scan`` so the same
executable serves any m and memory stays bounded.

Kernel injection: ``interp_fn`` / ``accum_fn`` default to the pure-jnp oracles
and can be swapped for the Pallas kernels in ``repro.kernels``.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.paths import interpolate
from repro.core.schedule import Schedule
from repro.core.probes import ScalarFn


class IGResult(NamedTuple):
    attributions: jax.Array  # (B, *F)
    f_x: jax.Array  # (B,) model output at the input
    f_baseline: jax.Array  # (B,) model output at the baseline
    delta: jax.Array  # (B,) convergence δ (completeness gap, Eq. 3)


def _default_accum(acc: jax.Array, grads: jax.Array, weights: jax.Array) -> jax.Array:
    """acc (B,*F) += Σ_k w_k g_k.  grads: (B, c, *F); weights: (B, c)."""
    wexp = weights.reshape(weights.shape + (1,) * (grads.ndim - 2))
    return acc + jnp.sum(grads.astype(jnp.float32) * wexp, axis=1)


def attribute(
    f: ScalarFn,
    x: jax.Array,
    baseline: jax.Array,
    sched: Schedule,
    target: jax.Array,
    *,
    chunk: int = 0,
    interp_fn: Callable = interpolate,
    accum_fn: Callable = _default_accum,
) -> IGResult:
    """Integrated Gradients along the straight-line path with any schedule.

    f: (xs (N, *F), targets (N,)) -> (N,);  x/baseline: (B, *F).
    sched.alphas/weights: (m,) shared or (B, m) per-example.
    """
    B = x.shape[0]
    alphas, weights = sched.alphas, sched.weights
    if alphas.ndim == 1:
        alphas = jnp.broadcast_to(alphas, (B,) + alphas.shape)
        weights = jnp.broadcast_to(weights, (B,) + weights.shape)
    m = alphas.shape[-1]
    c = chunk if chunk and chunk < m else m
    assert m % c == 0, f"chunk {c} must divide m {m}"
    n_chunks = m // c
    a_ch = alphas.reshape(B, n_chunks, c).swapaxes(0, 1)  # (n_chunks, B, c)
    w_ch = weights.reshape(B, n_chunks, c).swapaxes(0, 1)

    grad_f = jax.grad(lambda xs, t: f(xs, t).sum())

    def step(acc, xs):
        a, w = xs  # (B, c)
        xi = interp_fn(x, baseline, a)  # (B, c, *F)
        flat = xi.reshape((B * c,) + x.shape[1:])
        t = jnp.repeat(target, c)
        g = grad_f(flat, t).reshape((B, c) + x.shape[1:])
        return accum_fn(acc, g, w), None

    acc0 = jnp.zeros_like(x, dtype=jnp.float32)
    acc, _ = jax.lax.scan(step, acc0, (a_ch, w_ch))
    attr = (x - baseline).astype(jnp.float32) * acc

    both = jnp.concatenate([x, baseline], axis=0)
    fv = f(both, jnp.concatenate([target, target]))
    f_x, f_b = fv[:B], fv[B:]
    delta = jnp.abs(attr.reshape(B, -1).sum(-1) - (f_x - f_b))
    return IGResult(attr, f_x, f_b, delta)
