"""The IG engine — stage 2: batched, chunked gradient accumulation.

One compiled program serves every schedule (uniform / paper / warp / gauss):
the (alphas, weights) vectors are runtime data. The step axis is folded into
the batch axis (the paper's GPU batching, as a shardable pjit data axis), and
steps are processed in static-size chunks under ``lax.scan`` so the same
executable serves any m and memory stays bounded.

Kernel injection: ``interp_fn`` / ``accum_fn`` default to the pure-jnp oracles
and can be swapped for the Pallas kernels in ``repro.kernels``.

Masking (shape-bucketed serving, DESIGN.md §6): ``mask`` marks real
positions of right-padded inputs. It is threaded through ``interp_fn`` (padded
positions never leave the baseline), ``accum_fn`` (padded gradients never
accumulate), the final attribution (exact zeros at padded positions), and the
completeness gap δ (summed over real positions only — which the exact zeros
make the same as summing everything).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.paths import interpolate, mask_to_baseline
from repro.core.probes import ScalarFn, repeat_tree
from repro.core.schedule import Schedule


class IGResult(NamedTuple):
    attributions: jax.Array  # (B, *F)
    f_x: jax.Array  # (B,) model output at the input
    f_baseline: jax.Array  # (B,) model output at the baseline
    delta: jax.Array  # (B,) convergence δ (completeness gap, Eq. 3)


class IGState(NamedTuple):
    """Resumable stage-2 accumulator (adaptive iso-convergence, DESIGN.md §7).

    ``acc`` is Σ_k w_k g_k at the rung last run — the path integral estimate
    *before* the (x − x′) factor — and ``f_x``/``f_baseline`` are the endpoint
    forwards, computed once at rung 0 and carried so ladder hops never repeat
    them. Rows may be gathered/re-batched freely: every field is per-example.
    """

    acc: jax.Array  # (B, *F) float32 running Σ w·g
    f_x: jax.Array  # (B,)
    f_baseline: jax.Array  # (B,)


def _expand_mask(mask: jax.Array, ndim: int, *, lead: int = 1) -> jax.Array:
    """(B, *L) -> (B, 1×(lead-1), *L, 1, ...) broadcastable to rank ``ndim``."""
    shape = mask.shape[:1] + (1,) * (lead - 1) + mask.shape[1:]
    return mask.reshape(shape + (1,) * (ndim - len(shape))).astype(jnp.float32)


def _default_accum(
    acc: jax.Array,
    grads: jax.Array,
    weights: jax.Array,
    *,
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """acc (B,*F) += Σ_k w_k g_k.  grads: (B, c, *F); weights: (B, c);
    mask: optional (B, *L) real-position mask (padded grads are dropped)."""
    if mask is not None:
        grads = grads * _expand_mask(mask, grads.ndim, lead=2)
    wexp = weights.reshape(weights.shape + (1,) * (grads.ndim - 2))
    return acc + jnp.sum(grads.astype(jnp.float32) * wexp, axis=1)


def attribute(
    f: ScalarFn,
    x: jax.Array,
    baseline: jax.Array,
    sched: Schedule,
    target: Any,
    *,
    mask: Optional[jax.Array] = None,
    chunk: int = 0,
    interp_fn: Callable = interpolate,
    accum_fn: Callable = _default_accum,
    state: Optional[IGState] = None,
    state_scale: float = 1.0,
    return_state: bool = False,
):
    """Integrated Gradients along the straight-line path with any schedule.

    f: (xs (N, *F), targets) -> (N,);  x/baseline: (B, *F).
    target: pytree of per-example arrays (plain (B,) ids, or e.g.
    {"target": ids, "pos": positions} for bucketed serving).
    sched.alphas/weights: (m,) shared or (B, m) per-example.
    mask: optional (B, *L) real-position mask, L a prefix of the feature dims.

    Resumability (DESIGN.md §7): pass ``state`` from a prior call to continue
    accumulating — ``sched`` then holds only the NEW nodes, the endpoint
    forwards are reused, and the prior accumulator enters scaled by
    ``state_scale`` (0.5 per nested-refinement doubling: the old nodes'
    weights in the refined schedule are exactly half their old values, and
    power-of-two scaling is exact, so resuming is bit-identical to one fixed
    run over the full refined schedule at the same ``chunk``). With
    ``return_state`` the call returns ``(IGResult, IGState)``.
    """
    B = x.shape[0]
    # pinned view for the endpoint terms; the scan's interpolants are pinned
    # inside interp_fn (mask kwarg) — exactly one select on each path
    xp = mask_to_baseline(x, baseline, mask)
    alphas, weights = sched.alphas, sched.weights
    if alphas.ndim == 1:
        alphas = jnp.broadcast_to(alphas, (B,) + alphas.shape)
        weights = jnp.broadcast_to(weights, (B,) + weights.shape)
    m = alphas.shape[-1]
    c = chunk if chunk and chunk < m else m
    assert m % c == 0, f"chunk {c} must divide m {m}"
    n_chunks = m // c
    a_ch = alphas.reshape(B, n_chunks, c).swapaxes(0, 1)  # (n_chunks, B, c)
    w_ch = weights.reshape(B, n_chunks, c).swapaxes(0, 1)

    grad_f = jax.grad(lambda xs, t: f(xs, t).sum())
    mkw = {} if mask is None else {"mask": mask}

    def step(acc, xs):
        a, w = xs  # (B, c)
        xi = interp_fn(x, baseline, a, **mkw)  # (B, c, *F)
        flat = xi.reshape((B * c,) + x.shape[1:])
        t = repeat_tree(target, c)
        g = grad_f(flat, t).reshape((B, c) + x.shape[1:])
        return accum_fn(acc, g, w, **mkw), None

    if state is None:
        acc0 = jnp.zeros_like(x, dtype=jnp.float32)
    else:
        acc0 = state.acc.astype(jnp.float32)
        if state_scale != 1.0:
            acc0 = acc0 * jnp.float32(state_scale)
    acc, _ = jax.lax.scan(step, acc0, (a_ch, w_ch))
    attr = (xp - baseline).astype(jnp.float32) * acc
    if mask is not None:
        attr = attr * _expand_mask(mask, attr.ndim)

    if state is None:
        both = jnp.concatenate([xp, baseline], axis=0)
        fv = f(both, jax.tree.map(lambda t: jnp.concatenate([t, t], axis=0), target))
        f_x, f_b = fv[:B], fv[B:]
    else:
        f_x, f_b = state.f_x, state.f_baseline
    # attr is exactly zero at masked positions, so the full sum IS the
    # real-token sum — δ measures completeness over real tokens only.
    delta = jnp.abs(attr.reshape(B, -1).sum(-1) - (f_x - f_b))
    res = IGResult(attr, f_x, f_b, delta)
    if return_state:
        return res, IGState(acc, f_x, f_b)
    return res
