"""The IG engine — stage 2: batched, chunked gradient accumulation.

One compiled program serves every schedule (uniform / paper / warp / gauss):
the (alphas, weights) vectors are runtime data. The step axis is folded into
the batch axis (the paper's GPU batching, as a shardable pjit data axis), and
steps are processed in static-size chunks under ``lax.scan`` so the same
executable serves any m and memory stays bounded.

Kernel injection: ``interp_fn`` / ``accum_fn`` default to the pure-jnp oracles
and can be swapped for the Pallas kernels in ``repro.kernels``.

Masking (shape-bucketed serving, DESIGN.md §6): ``mask`` marks real
positions of right-padded inputs. It is threaded through ``interp_fn`` (padded
positions never leave the baseline), ``accum_fn`` (padded gradients never
accumulate), the final attribution (exact zeros at padded positions), and the
completeness gap δ (summed over real positions only — which the exact zeros
make the same as summing everything).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.paths import interpolate, mask_to_baseline
from repro.core.probes import ScalarFn, repeat_tree
from repro.core.schedule import Schedule


class IGResult(NamedTuple):
    attributions: jax.Array  # (B, *F)
    f_x: jax.Array  # (B,) model output at the input
    f_baseline: jax.Array  # (B,) model output at the baseline
    delta: jax.Array  # (B,) convergence δ (completeness gap, Eq. 3)


def _expand_mask(mask: jax.Array, ndim: int, *, lead: int = 1) -> jax.Array:
    """(B, *L) -> (B, 1×(lead-1), *L, 1, ...) broadcastable to rank ``ndim``."""
    shape = mask.shape[:1] + (1,) * (lead - 1) + mask.shape[1:]
    return mask.reshape(shape + (1,) * (ndim - len(shape))).astype(jnp.float32)


def _default_accum(
    acc: jax.Array,
    grads: jax.Array,
    weights: jax.Array,
    *,
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """acc (B,*F) += Σ_k w_k g_k.  grads: (B, c, *F); weights: (B, c);
    mask: optional (B, *L) real-position mask (padded grads are dropped)."""
    if mask is not None:
        grads = grads * _expand_mask(mask, grads.ndim, lead=2)
    wexp = weights.reshape(weights.shape + (1,) * (grads.ndim - 2))
    return acc + jnp.sum(grads.astype(jnp.float32) * wexp, axis=1)


def attribute(
    f: ScalarFn,
    x: jax.Array,
    baseline: jax.Array,
    sched: Schedule,
    target: Any,
    *,
    mask: Optional[jax.Array] = None,
    chunk: int = 0,
    interp_fn: Callable = interpolate,
    accum_fn: Callable = _default_accum,
) -> IGResult:
    """Integrated Gradients along the straight-line path with any schedule.

    f: (xs (N, *F), targets) -> (N,);  x/baseline: (B, *F).
    target: pytree of per-example arrays (plain (B,) ids, or e.g.
    {"target": ids, "pos": positions} for bucketed serving).
    sched.alphas/weights: (m,) shared or (B, m) per-example.
    mask: optional (B, *L) real-position mask, L a prefix of the feature dims.
    """
    B = x.shape[0]
    # pinned view for the endpoint terms; the scan's interpolants are pinned
    # inside interp_fn (mask kwarg) — exactly one select on each path
    xp = mask_to_baseline(x, baseline, mask)
    alphas, weights = sched.alphas, sched.weights
    if alphas.ndim == 1:
        alphas = jnp.broadcast_to(alphas, (B,) + alphas.shape)
        weights = jnp.broadcast_to(weights, (B,) + weights.shape)
    m = alphas.shape[-1]
    c = chunk if chunk and chunk < m else m
    assert m % c == 0, f"chunk {c} must divide m {m}"
    n_chunks = m // c
    a_ch = alphas.reshape(B, n_chunks, c).swapaxes(0, 1)  # (n_chunks, B, c)
    w_ch = weights.reshape(B, n_chunks, c).swapaxes(0, 1)

    grad_f = jax.grad(lambda xs, t: f(xs, t).sum())
    mkw = {} if mask is None else {"mask": mask}

    def step(acc, xs):
        a, w = xs  # (B, c)
        xi = interp_fn(x, baseline, a, **mkw)  # (B, c, *F)
        flat = xi.reshape((B * c,) + x.shape[1:])
        t = repeat_tree(target, c)
        g = grad_f(flat, t).reshape((B, c) + x.shape[1:])
        return accum_fn(acc, g, w, **mkw), None

    acc0 = jnp.zeros_like(x, dtype=jnp.float32)
    acc, _ = jax.lax.scan(step, acc0, (a_ch, w_ch))
    attr = (xp - baseline).astype(jnp.float32) * acc
    if mask is not None:
        attr = attr * _expand_mask(mask, attr.ndim)

    both = jnp.concatenate([xp, baseline], axis=0)
    fv = f(both, jax.tree.map(lambda t: jnp.concatenate([t, t], axis=0), target))
    f_x, f_b = fv[:B], fv[B:]
    # attr is exactly zero at masked positions, so the full sum IS the
    # real-token sum — δ measures completeness over real tokens only.
    delta = jnp.abs(attr.reshape(B, -1).sum(-1) - (f_x - f_b))
    return IGResult(attr, f_x, f_b, delta)
