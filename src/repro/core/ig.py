"""The IG engine — stage 2: batched, chunked gradient accumulation.

One compiled program serves every schedule (uniform / paper / warp / gauss):
the (alphas, weights) vectors are runtime data. The step axis is folded into
the batch axis (the paper's GPU batching, as a shardable pjit data axis), and
steps are processed in static-size chunks under ``lax.scan`` so the same
executable serves any m and memory stays bounded.

Attribution methods (DESIGN.md §8): the per-chunk accumulator and the
finalizer are method data, dispatched through the ``repro.core.methods``
MethodSpec registry — vanilla Riemann IG and IDGI's gradient-direction
f-difference split ride the identical scan; path-ensemble methods
(noise_tunnel / expected_grad) expand their batch BEFORE this function and
reduce after it, so per-row they ARE the riemann method.

Kernel injection: ``interp_fn`` / ``accum_fn`` default to the pure-jnp
oracles (the method's registered accumulator) and can be swapped for the
Pallas kernels in ``repro.kernels``.

Masking (shape-bucketed serving, DESIGN.md §6): ``mask`` marks real
positions of right-padded inputs. It is threaded through ``interp_fn`` (padded
positions never leave the baseline), the accumulator (padded gradients never
accumulate), the final attribution (exact zeros at padded positions), and the
completeness gap δ (summed over real positions only — which the exact zeros
make the same as summing everything).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import methods as methods_mod
from repro.core.methods import MethodSpec, expand_mask
from repro.core.paths import interp_add, interpolate, mask_to_baseline
from repro.core.probes import ScalarFn, repeat_tree
from repro.core.schedule import Schedule


class IGResult(NamedTuple):
    attributions: jax.Array  # (B, *F)
    f_x: jax.Array  # (B,) model output at the input
    f_baseline: jax.Array  # (B,) model output at the baseline
    delta: jax.Array  # (B,) convergence δ (completeness gap, Eq. 3)


class IGState(NamedTuple):
    """Resumable stage-2 accumulator (adaptive iso-convergence, DESIGN.md §7).

    ``acc`` is the method's running node sum at the rung last run — for
    riemann methods Σ_k w_k g_k (the path integral estimate *before* the
    (x − x′) factor), for IDGI the attribution itself — and ``f_x``/
    ``f_baseline`` are the endpoint forwards, computed once at rung 0 and
    carried so ladder hops never repeat them. Rows may be gathered/re-batched
    freely: every field is per-example. Any registered method's accumulator
    is additive over nodes and degree-1 in the weights (the MethodSpec state
    contract, DESIGN.md §8), so this one pytree serves the whole zoo.
    """

    acc: jax.Array  # (B, *F) float32 running node sum
    f_x: jax.Array  # (B,)
    f_baseline: jax.Array  # (B,)


def attribute(
    f: ScalarFn,
    x: jax.Array,
    baseline: jax.Array,
    sched: Schedule,
    target: Any,
    *,
    method: Union[str, MethodSpec] = "ig",
    mask: Optional[jax.Array] = None,
    chunk: int = 0,
    fused: bool = False,
    interp_fn: Callable = interpolate,
    interp_add_fn: Callable = interp_add,
    accum_fn: Optional[Callable] = None,
    state: Optional[IGState] = None,
    state_scale: float = 1.0,
    return_state: bool = False,
    f_x: Optional[jax.Array] = None,
):
    """Path attribution along the straight line with any schedule + method.

    f: (xs (N, *F), targets) -> (N,);  x/baseline: (B, *F).
    target: pytree of per-example arrays (plain (B,) ids, or e.g.
    {"target": ids, "pos": positions} for bucketed serving).
    sched.alphas/weights: (m,) shared or (B, m) per-example.
    method: a ``repro.core.methods`` registry name or MethodSpec — selects
    the per-chunk accumulator and the finalizer. Path-ensemble expansion
    (noise_tunnel / expected_grad) is the CALLER's job (``core.api``): this
    function computes one path per row.
    mask: optional (B, *L) real-position mask, L a prefix of the feature dims.
    accum_fn: optional accumulator override (Pallas kernel injection); must
    honor the MethodSpec accumulator signature
    ``(acc, grads, weights, *, diff, mask)``.

    Fused stage 2 (``fused=True``, DESIGN.md §10): the interpolants are
    generated INSIDE the differentiated chunk function — interpolation
    composed with the model forward under one VJP — so the (B·chunk, *F)
    interpolant batch is never a program-boundary tensor that must round-trip
    HBM. For ``grad_linear`` accumulator classes (riemann) the chunk's whole
    weighted gradient sum Σ_k w_k g_k is recovered as ONE (B, *F) cotangent
    (the transpose of the step-axis broadcast), so the per-step gradient
    batch never materializes either; quadratic classes (idgi) keep per-step
    gradients but still fuse the interpolation into the backward program.
    ``interp_add_fn`` is the fused path's kernel-injection hook — the
    interp-plus-carry unit (``paths.interp_add`` oracle; Pallas custom-VJP
    drop-in in ``repro.kernels.interp_accum.ops``). The fused and unfused
    paths accumulate in f32 either way and agree to float tolerance (not
    bitwise — the weight multiply rides the VJP seed instead of the
    accumulator); each is separately bit-identical under adaptive resume.

    Probe-reuse (``f_x``, unified serving): a caller that already holds the
    endpoint forward value f(x) for every row — e.g. the decode loop's chosen
    -token log-prob from the very forward being attributed — passes it here
    and only f(baseline) is computed (a B-row batch instead of 2B). Per-row
    forward values are batch-shape independent, so the result is bit-identical
    to the self-computed endpoints whenever the passed value is. Ignored when
    resuming from ``state`` (endpoints already live there).

    Resumability (DESIGN.md §7): pass ``state`` from a prior call to continue
    accumulating — ``sched`` then holds only the NEW nodes, the endpoint
    forwards are reused, and the prior accumulator enters scaled by
    ``state_scale`` (0.5 per nested-refinement doubling: the old nodes'
    weights in the refined schedule are exactly half their old values, and
    power-of-two scaling is exact, so resuming is bit-identical to one fixed
    run over the full refined schedule at the same ``chunk``). With
    ``return_state`` the call returns ``(IGResult, IGState)``.
    """
    spec = methods_mod.get(method)
    if spec.forward_only:
        raise ValueError(
            f"method {spec.name!r} is forward-only (perturbation class); "
            "it never differentiates the model — use "
            "repro.core.perturb.attribute_from_masks / PerturbExplainer"
        )
    if accum_fn is None:
        accum_fn = spec.accum_fn
    B = x.shape[0]
    # pinned view for the endpoint terms; the scan's interpolants are pinned
    # inside interp_fn (mask kwarg) — exactly one select on each path
    xp = mask_to_baseline(x, baseline, mask)
    diff = xp - baseline  # path direction, consumed by direction-aware accums
    alphas, weights = sched.alphas, sched.weights
    if alphas.ndim == 1:
        alphas = jnp.broadcast_to(alphas, (B,) + alphas.shape)
        weights = jnp.broadcast_to(weights, (B,) + weights.shape)
    m = alphas.shape[-1]
    c = chunk if chunk and chunk < m else m
    assert m % c == 0, f"chunk {c} must divide m {m}"
    n_chunks = m // c
    a_ch = alphas.reshape(B, n_chunks, c).swapaxes(0, 1)  # (n_chunks, B, c)
    w_ch = weights.reshape(B, n_chunks, c).swapaxes(0, 1)

    grad_f = jax.grad(lambda xs, t: f(xs, t).sum())
    mkw = {} if mask is None else {"mask": mask}
    feat = x.shape[1:]

    def step(acc, xs):
        a, w = xs  # (B, c)
        xi = interp_fn(x, baseline, a, **mkw)  # (B, c, *F)
        flat = xi.reshape((B * c,) + feat)
        t = repeat_tree(target, c)
        g = grad_f(flat, t).reshape((B, c) + feat)
        return accum_fn(acc, g, w, diff=diff, **mkw), None

    def step_fused_linear(acc, xs):
        # grad-linear accumulators (riemann class): Σ_k w_k g_k for the whole
        # chunk is the cotangent of a (B, *F) carry broadcast over the step
        # axis — one VJP output, no (B, c, *F) gradient batch, interpolants
        # generated inside the differentiated program (DESIGN.md §10).
        a, w = xs  # (B, c)

        def chunk_sum(u):
            xi = interp_add_fn(x, baseline, a, u, **mkw)  # (B, c, *F)
            t = repeat_tree(target, c)
            vals = f(xi.reshape((B * c,) + feat), t).astype(jnp.float32)
            return jnp.sum(vals * w.astype(jnp.float32).reshape(-1))

        inc = jax.grad(chunk_sum)(jnp.zeros_like(x, dtype=jnp.float32))
        if mask is not None:  # match the unfused accumulators' masked grads
            inc = inc * expand_mask(mask, inc.ndim)
        return acc + inc, None

    def step_fused(acc, xs):
        # quadratic accumulators (idgi): per-step gradients are irreducible
        # (⟨g,g⟩, Σ c_k g_k²), but the interpolation still composes into the
        # differentiated program — grads arrive as the cotangent of a
        # per-step additive carry, never of a materialized interpolant input.
        a, w = xs

        def chunk_vals(z):
            xi = interp_add_fn(x, baseline, a, z, **mkw)
            t = repeat_tree(target, c)
            return f(xi.reshape((B * c,) + feat), t).sum()

        g = jax.grad(chunk_vals)(jnp.zeros((B, c) + feat, jnp.float32))
        return accum_fn(acc, g, w, diff=diff, **mkw), None

    if fused:
        step = step_fused_linear if spec.grad_linear else step_fused

    if state is None:
        acc0 = jnp.zeros_like(x, dtype=jnp.float32)
    else:
        acc0 = state.acc.astype(jnp.float32)
        if state_scale != 1.0:
            acc0 = acc0 * jnp.float32(state_scale)
    acc, _ = jax.lax.scan(step, acc0, (a_ch, w_ch))
    attr = spec.finalize(acc, xp, baseline, mask)

    if state is not None:
        f_x, f_b = state.f_x, state.f_baseline
    elif f_x is not None:
        f_x = f_x.astype(jnp.float32)
        f_b = f(baseline, target)
    else:
        both = jnp.concatenate([xp, baseline], axis=0)
        fv = f(both, jax.tree.map(lambda t: jnp.concatenate([t, t], axis=0), target))
        f_x, f_b = fv[:B], fv[B:]
    # attr is exactly zero at masked positions, so the full sum IS the
    # real-token sum — δ measures completeness over real tokens only.
    delta = jnp.abs(attr.reshape(B, -1).sum(-1) - (f_x - f_b))
    res = IGResult(attr, f_x, f_b, delta)
    if return_state:
        return res, IGState(acc, f_x, f_b)
    return res
