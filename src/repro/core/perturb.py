"""Forward-only perturbation attribution — occlusion, RISE, LIME.

The paper's pipeline needs gradients; a serving system at scale must also
explain models it cannot differentiate (quantized, remote, black-box
endpoints — the first ROADMAP open item). This module is the second
executable class next to ``riemann``/``idgi``: instead of interpolating and
back-propagating, it evaluates the model FORWARD on a batch of masked
variants of the input and turns the f-values into per-position scores.

Mask contract (the whole class hangs off it):

  * A perturbation mask ``z`` is a (P, S) binary keep-mask over the S
    position axis: ``z=1`` keeps the input, ``z=0`` replaces the position
    with the baseline — ``x_p = z_p ⊙ x + (1 − z_p) ⊙ x′`` in embedding
    space, the same space the IG path interpolates in, so LM tokens and ViT
    patches ride unchanged.
  * Masks are drawn from keys PURE in the request index (``request_key`` —
    the same fold-in discipline as the path-ensemble expansion, DESIGN.md
    §8): replayed traffic draws bit-identical masks, batch-pad rows
    duplicate a real row's masks, and the serving engine's zero-recompile /
    padding-invariance gates extend to this class unchanged.
  * Pad positions are pinned to the baseline BEFORE perturbation
    (``mask_to_baseline``) and the final scores are multiplied by the
    real-position mask — padded positions get exactly zero attribution,
    like the gradient class.

Methods (registered in ``repro.core.methods`` with ``forward_only=True``):

  occlusion — deterministic sliding windows: score_s = the mean drop
              f(x) − f(x_p) over the windows that occlude position s.
  rise      — random binary keep-masks (Petsiuk et al., 2018):
              score_s = E[f(x_p) | z_s = 1] − E[f(x_p)], estimated from P
              Bernoulli(p_keep) masks.
  lime      — binary masks over contiguous position GROUPS (the tabular/
              sequence analogue of superpixels), exponential-kernel
              weighted ridge regression of f(x_p) on the group indicators;
              a group's coefficient is spread to its positions. The
              weighted least-squares solve is the ``kernels/lstsq`` Pallas
              kernel's job on the serving path (``solve_fn`` injection);
              the default is the pure-jnp oracle.

Everything accumulates CHUNKED sufficient statistics under ``lax.scan``
(the forward analogue of stage 2's gradient chunks): occlusion/RISE carry
(B, S) numerators/denominators, LIME carries the (B, G+1, G+1) normal
equations — so one compiled program serves any mask budget P at bounded
memory, and the accumulator consumes ``f(perturbed)`` VALUES where the
gradient class consumes VJPs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.paths import mask_to_baseline
from repro.core.probes import ScalarFn, repeat_tree


class PerturbResult(NamedTuple):
    """Forward-only analogue of ``ig.IGResult``; attributions are per
    POSITION (B, S) — the class scores positions, not features."""

    attributions: jax.Array  # (B, S) f32 per-position scores
    f_x: jax.Array  # (B,) model output at the (pinned) input
    f_baseline: jax.Array  # (B,) model output at the baseline
    delta: jax.Array  # (B,) |Σ_s score_s − (f_x − f_b)| — diagnostic only:
    # perturbation methods satisfy no completeness axiom, so δ is reported
    # for observability and never gates convergence.


class PerturbMasks(NamedTuple):
    """One request's (or one batch's) drawn masks.

    ``z`` is the (…, P, S) position keep-mask batch. LIME additionally
    carries the (…, P, G) group indicators its regression runs on and the
    (S,) position→group map; both are ``None`` for occlusion/RISE."""

    z: jax.Array  # (..., P, S) position keep-masks
    groups: Optional[jax.Array] = None  # (..., P, G) lime group masks
    group_ids: Optional[jax.Array] = None  # (S,) int32 position -> group


# ------------------------------------------------------------- mask drawing


def request_key(seed: int, s_bucket: int, index: int | jax.Array) -> jax.Array:
    """The per-request mask key: pure in (seed, bucket S, request index) —
    the SAME discipline as the path-ensemble expansion (DESIGN.md §8), so
    replay is bit-identical and pad rows duplicate a real row's stream."""
    base = jax.random.fold_in(jax.random.PRNGKey(seed), s_bucket)
    return jax.random.fold_in(base, index)


def occlusion_masks(S: int, n_masks: int) -> jax.Array:
    """(P=n_masks, S) sliding-window occlusion masks (deterministic).

    Window width ⌈S/P⌉, stride = width (the windows tile S); when fewer
    windows than P tile S, windows repeat cyclically so P is EXACTLY
    ``n_masks`` for every S — the mask batch shape is pure in (S, P), which
    keeps the serving executable set closed. Duplicate windows only enter
    the per-position average twice (numerator and denominator alike)."""
    window = -(-S // n_masks)  # ceil
    n_win = -(-S // window)
    starts = (jnp.arange(n_masks) % n_win) * window
    pos = jnp.arange(S)
    occluded = (pos[None, :] >= starts[:, None]) & (
        pos[None, :] < starts[:, None] + window
    )
    return 1.0 - occluded.astype(jnp.float32)


def rise_masks(key: jax.Array, n_masks: int, S: int, p_keep: float = 0.5) -> jax.Array:
    """(P, S) iid Bernoulli(p_keep) keep-masks."""
    return jax.random.bernoulli(key, p_keep, (n_masks, S)).astype(jnp.float32)


def default_n_groups(S: int) -> int:
    """LIME group count for a bucket width — pure in S (shape closure)."""
    return min(S, 16)


def lime_group_ids(S: int, n_groups: int) -> jax.Array:
    """(S,) int32 position→group map: contiguous, near-equal groups — the
    sequence/patch-grid analogue of superpixels."""
    return jnp.minimum(jnp.arange(S) * n_groups // S, n_groups - 1).astype(jnp.int32)


def lime_masks(key: jax.Array, n_masks: int, n_groups: int) -> jax.Array:
    """(P, G) iid Bernoulli(0.5) group keep-masks (the LIME design rows)."""
    return jax.random.bernoulli(key, 0.5, (n_masks, n_groups)).astype(jnp.float32)


def draw_masks(
    method: str,
    keys: jax.Array,
    S: int,
    n_masks: int,
    *,
    p_keep: float = 0.5,
    n_groups: int = 0,
) -> PerturbMasks:
    """Per-request mask batches for a (B,)-keyed request batch.

    ``keys``: (B,) request keys from ``request_key`` (ignored by the
    deterministic occlusion generator, which broadcasts one mask set).
    Returns ``PerturbMasks`` with leading batch axis: z (B, P, S), and for
    lime also groups (B, P, G) + the shared group_ids (S,).
    """
    B = keys.shape[0]
    if method == "occlusion":
        z = jnp.broadcast_to(occlusion_masks(S, n_masks), (B, n_masks, S))
        return PerturbMasks(z)
    if method == "rise":
        z = jax.vmap(lambda k: rise_masks(k, n_masks, S, p_keep))(keys)
        return PerturbMasks(z)
    if method == "lime":
        G = n_groups if n_groups else default_n_groups(S)
        gids = lime_group_ids(S, G)
        zg = jax.vmap(lambda k: lime_masks(k, n_masks, G))(keys)
        return PerturbMasks(zg[..., gids], zg, gids)
    raise ValueError(f"unknown perturbation method {method!r}")


# ----------------------------------------------- forward-value accumulators
#
# The forward-only MethodSpec contract: the accumulator consumes f(perturbed)
# VALUES, not gradients —
#   update(stats, vals (B, c) f32, z (B, c, S | G), *, ctx) -> stats
#   finalize(stats, *, ctx) -> (B, S) f32 scores
# ``stats`` is a per-method pytree of f32 sufficient statistics; ``ctx`` is
# the static per-call context dict built by ``attribute_from_masks``
# (endpoints, mask, P, the lime solve hook). ``init`` builds the scan carry.


def occlusion_init(B: int, S: int, G: int) -> dict:
    return {"num": jnp.zeros((B, S), jnp.float32), "den": jnp.zeros((B, S), jnp.float32)}


def occlusion_update(stats: dict, vals: jax.Array, z: jax.Array, *, ctx: dict) -> dict:
    """Accumulate the f-drop of every window onto the positions it occludes."""
    drop = ctx["f_x"][:, None] - vals  # (B, c)
    occ = 1.0 - z  # (B, c, S) occluded indicator
    return {
        "num": stats["num"] + jnp.einsum("bc,bcs->bs", drop, occ),
        "den": stats["den"] + occ.sum(axis=1),
    }


def occlusion_finalize(stats: dict, *, ctx: dict) -> jax.Array:
    den = stats["den"]
    return jnp.where(den > 0.0, stats["num"] / jnp.where(den > 0.0, den, 1.0), 0.0)


def rise_init(B: int, S: int, G: int) -> dict:
    return {
        "sz": jnp.zeros((B, S), jnp.float32),  # Σ_p f_p · z_ps
        "nz": jnp.zeros((B, S), jnp.float32),  # Σ_p z_ps
        "sv": jnp.zeros((B,), jnp.float32),  # Σ_p f_p
    }


def rise_update(stats: dict, vals: jax.Array, z: jax.Array, *, ctx: dict) -> dict:
    return {
        "sz": stats["sz"] + jnp.einsum("bc,bcs->bs", vals, z),
        "nz": stats["nz"] + z.sum(axis=1),
        "sv": stats["sv"] + vals.sum(axis=1),
    }


def rise_finalize(stats: dict, *, ctx: dict) -> jax.Array:
    """score_s = E[f | z_s = 1] − E[f]; positions never kept score 0."""
    nz = stats["nz"]
    cond = stats["sz"] / jnp.where(nz > 0.0, nz, 1.0)
    mean = stats["sv"][:, None] / jnp.float32(ctx["n_masks"])
    return jnp.where(nz > 0.0, cond - mean, 0.0)


def lime_weights(zg: jax.Array, kernel_width: float) -> jax.Array:
    """Exponential proximity kernel π_p = exp(−(1 − cover_p)² / width²) on
    the group-coverage fraction (full-coverage masks weigh most)."""
    cover = zg.mean(axis=-1)
    return jnp.exp(-((1.0 - cover) ** 2) / jnp.float32(kernel_width) ** 2)


def lime_init(B: int, S: int, G: int) -> dict:
    return {
        "A": jnp.zeros((B, G + 1, G + 1), jnp.float32),  # XᵀWX (+ intercept)
        "b": jnp.zeros((B, G + 1), jnp.float32),  # XᵀWy
    }


def lime_update(stats: dict, vals: jax.Array, zg: jax.Array, *, ctx: dict) -> dict:
    """Accumulate the weighted normal equations of f ~ [groups, 1]."""
    B, c, G = zg.shape
    xg = jnp.concatenate([zg, jnp.ones((B, c, 1), zg.dtype)], axis=-1)
    w = lime_weights(zg, ctx["kernel_width"])  # (B, c)
    return {
        "A": stats["A"] + jnp.einsum("bci,bc,bcj->bij", xg, w, xg),
        "b": stats["b"] + jnp.einsum("bci,bc,bc->bi", xg, w, vals),
    }


def lime_finalize(stats: dict, *, ctx: dict) -> jax.Array:
    """Ridge-solve the accumulated normal equations and spread each group's
    coefficient to its positions. ``group_valid`` rows (groups with no real
    position in a padded bucket) are pinned to identity by the solver, so
    their β — and therefore every pad position's score — is exactly zero."""
    gv = ctx["group_valid"]
    if gv is not None:  # intercept column is always live
        gv = jnp.concatenate([gv, jnp.ones((gv.shape[0], 1), gv.dtype)], axis=-1)
    beta = ctx["solve_fn"](stats["A"], stats["b"], mask=gv, ridge=ctx["ridge"])
    return jnp.take(beta[:, :-1], ctx["group_ids"], axis=1)  # (B, S)


_FWD = {
    "occlusion": (occlusion_init, occlusion_update, occlusion_finalize),
    "rise": (rise_init, rise_update, rise_finalize),
    "lime": (lime_init, lime_update, lime_finalize),
}


def _default_solve(A, rhs, *, mask=None, ridge=0.0):
    from repro.kernels.lstsq.ref import wls_solve_ref

    return wls_solve_ref(A, rhs, mask=mask, ridge=ridge)


# ---------------------------------------------------------------- attribute


def attribute_from_masks(
    f: ScalarFn,
    x: jax.Array,
    baseline: jax.Array,
    target: Any,
    pm: PerturbMasks,
    *,
    method: Union[str, Any] = "occlusion",
    mask: Optional[jax.Array] = None,
    group_valid: Optional[jax.Array] = None,
    chunk: int = 0,
    ridge: float = 1e-2,
    kernel_width: float = 0.25,
    solve_fn: Optional[Callable] = None,
    f_x: Optional[jax.Array] = None,
) -> PerturbResult:
    """Forward-only attribution over pre-drawn masks — the compiled unit.

    f: (xs (N, S, *E), targets) -> (N,);  x/baseline: (B, S, *E).
    pm: batched ``PerturbMasks`` (z (B, P, S); lime adds groups/group_ids).
    mask: optional (B, S) real-position mask — pad positions are pinned to
    the baseline before perturbation and scored exactly zero.
    group_valid: optional (B, G) — lime groups containing at least one real
    position; invalid groups are pinned out of the solve (β = 0 exactly).
    chunk: masks per scan step (0 = all P at once); must divide P.
    solve_fn: the lime WLS hook ``(A, rhs, *, mask, ridge) -> beta`` —
    ``kernels.lstsq.ops.wls_solve`` on the kernel-injected serving path,
    the ``kernels.lstsq.ref`` oracle by default.
    f_x: optional known (B,) endpoint f(x) (probe reuse): only f(baseline)
    is then computed alongside the mask batch.

    Like the gradient class, masks expand OUTSIDE this function (plan time
    / batch construction) so the compiled program's shapes are pure in
    (B, S, P) and replayed traffic hits warmed executables.
    """
    from repro.core import methods as methods_mod

    spec = methods_mod.get(method)
    if not spec.forward_only:
        raise ValueError(
            f"method {spec.name!r} is gradient-based; use repro.core.ig.attribute"
        )
    init, update, finalize = _FWD[spec.accum]

    B, S = x.shape[:2]
    feat = x.shape[2:]
    P = pm.z.shape[1]
    G = pm.groups.shape[-1] if pm.groups is not None else 0
    xp = mask_to_baseline(x, baseline, mask)

    if f_x is not None:
        f_x = f_x.astype(jnp.float32)
        f_b = f(baseline, target).astype(jnp.float32)
    else:
        both = jnp.concatenate([xp, baseline], axis=0)
        fv = f(both, jax.tree.map(lambda t: jnp.concatenate([t, t], axis=0), target))
        f_x, f_b = fv[:B].astype(jnp.float32), fv[B:].astype(jnp.float32)

    ctx = {
        "f_x": f_x,
        "n_masks": P,
        "kernel_width": kernel_width,
        "ridge": ridge,
        "group_ids": pm.group_ids,
        "group_valid": group_valid,
        "solve_fn": solve_fn if solve_fn is not None else _default_solve,
    }

    c = chunk if chunk and chunk < P else P
    assert P % c == 0, f"chunk {c} must divide n_masks {P}"
    n_chunks = P // c
    z_ch = pm.z.reshape(B, n_chunks, c, S).swapaxes(0, 1)  # (n_chunks, B, c, S)
    # the accumulator's design rows: group indicators for lime, the position
    # masks themselves otherwise
    acc_rows = pm.groups if pm.groups is not None else pm.z
    r_ch = acc_rows.reshape(B, n_chunks, c, acc_rows.shape[-1]).swapaxes(0, 1)

    def step(stats, xs):
        z, rows = xs  # (B, c, S), (B, c, S|G)
        ze = z.reshape(z.shape + (1,) * len(feat))
        xi = ze * xp[:, None] + (1.0 - ze) * baseline[:, None]  # (B, c, S, *E)
        vals = f(xi.reshape((B * c, S) + feat), repeat_tree(target, c))
        vals = vals.reshape(B, c).astype(jnp.float32)
        return update(stats, vals, rows, ctx=ctx), None

    stats, _ = jax.lax.scan(step, init(B, S, G), (z_ch, r_ch))
    scores = finalize(stats, ctx=ctx)  # (B, S)
    if mask is not None:
        scores = scores * mask.astype(jnp.float32)
    delta = jnp.abs(scores.sum(-1) - (f_x - f_b))
    return PerturbResult(scores, f_x, f_b, delta)


# ------------------------------------------------------------- convenience


@dataclass(frozen=True)
class PerturbExplainer:
    """Self-contained forward-only explainer over (B, S, *E) inputs.

    Draws each row's masks from ``request_key(seed, S, row_index)`` — the
    same keying the serving engine uses with request indices, so a direct
    call and a served bucket of the same rows draw identical masks. Used by
    the golden fixtures, the quality benchmark, and the core tests; the
    serving path goes through ``ExplainEngine`` (plan-time mask expansion,
    compiled-executable cache).
    """

    f: ScalarFn
    method: str = "occlusion"
    n_masks: int = 64
    seed: int = 0
    chunk: int = 0
    p_keep: float = 0.5
    n_groups: int = 0  # 0 = default_n_groups(S)
    ridge: float = 1e-2
    kernel_width: float = 0.25
    solve_fn: Optional[Callable] = None

    def masks_for(self, B: int, S: int) -> PerturbMasks:
        keys = jax.vmap(lambda i: request_key(self.seed, S, i))(
            jnp.arange(B, dtype=jnp.uint32)
        )
        return draw_masks(
            self.method, keys, S, self.n_masks,
            p_keep=self.p_keep, n_groups=self.n_groups,
        )

    def attribute(
        self,
        x: jax.Array,
        baseline: jax.Array,
        target: Any,
        *,
        mask: Optional[jax.Array] = None,
    ) -> PerturbResult:
        B, S = x.shape[:2]
        pm = self.masks_for(B, S)
        group_valid = None
        if pm.group_ids is not None and mask is not None:
            group_valid = group_real_mask(mask, pm.group_ids, pm.groups.shape[-1])
        return attribute_from_masks(
            self.f, x, baseline, target, pm,
            method=self.method, mask=mask, group_valid=group_valid,
            chunk=self.chunk, ridge=self.ridge,
            kernel_width=self.kernel_width, solve_fn=self.solve_fn,
        )


def group_real_mask(mask: jax.Array, group_ids: jax.Array, n_groups: int) -> jax.Array:
    """(B, S) real-position mask → (B, G) "group has a real position"."""
    onehot = jax.nn.one_hot(group_ids, n_groups, dtype=jnp.float32)  # (S, G)
    return (mask.astype(jnp.float32) @ onehot > 0.0).astype(jnp.float32)


# ----------------------------------------------------- image <-> cell views
#
# Perturbation scores POSITIONS; a dense image has none, so the quality
# bake-off carves (B, H, W, C) images into a grid of cell² patches — the
# same move ViT's patchify makes — and perturbs cells. The helpers below
# are the (exact, invertible) reshape pair plus the score broadcast that
# makes insertion/deletion AUC comparable with per-pixel gradient methods.


def image_to_cells(images: jax.Array, cell: int) -> jax.Array:
    """(B, H, W, C) -> (B, (H/cell)·(W/cell), cell·cell·C) position view."""
    B, H, W, C = images.shape
    gh, gw = H // cell, W // cell
    assert gh * cell == H and gw * cell == W, (H, W, cell)
    x = images.reshape(B, gh, cell, gw, cell, C)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(B, gh * gw, cell * cell * C)


def cells_to_image(cells: jax.Array, image_shape: tuple, cell: int) -> jax.Array:
    """Inverse of ``image_to_cells``."""
    B = cells.shape[0]
    H, W, C = image_shape
    gh, gw = H // cell, W // cell
    x = cells.reshape(B, gh, gw, cell, cell, C)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(B, H, W, C)


def cell_fn(f: ScalarFn, image_shape: tuple, cell: int) -> ScalarFn:
    """Lift a pixel-space scalar fn to the (B, S, D) cell view."""

    def g(xc, target):
        return f(cells_to_image(xc, image_shape, cell), target)

    return g


def cell_scores_to_pixels(
    scores: jax.Array, image_shape: tuple, cell: int
) -> jax.Array:
    """Broadcast (B, S) cell scores to (B, H, W, C) pixel attributions
    (every pixel of a cell shares its cell's score — the ranking the
    insertion/deletion curves consume)."""
    B, S = scores.shape
    H, W, C = image_shape
    cells = jnp.broadcast_to(scores[..., None], (B, S, cell * cell * C))
    return cells_to_image(cells, image_shape, cell)
