"""High-level Explainer API — the paper's algorithm as a one-call feature.

    explainer = Explainer(f, method="paper", n_int=4, m=64)
    result = explainer.attribute(x, baseline, target)

``f(xs, targets) -> (N,)`` is any differentiable scalar model output
(classifier probability, LM next-token log-prob, ...).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import ig, probes, schedule
from repro.core.ig import IGResult
from repro.core.probes import ScalarFn
from repro.core.schedule import Schedule


@dataclass
class Explainer:
    f: ScalarFn
    method: str = "paper"  # any name in schedule.SCHEDULES
    m: int = 64  # total interpolation steps
    n_int: int = 4  # stage-1 intervals (paper sweeps 2..8)
    refine_rounds: int = 4  # for the "refine" probe
    power: float = 0.5  # sqrt attenuation (paper); 1.0 = linear
    min_steps: int = 1
    rule: str = "midpoint"  # uniform-rule variant
    chunk: int = 0  # stage-2 step chunk (0 = all at once)
    interp_fn: Callable = None  # optional Pallas kernel injection
    accum_fn: Callable = None

    def build_schedule(
        self,
        x: jax.Array,
        baseline: jax.Array,
        target: Any,
        mask: Optional[jax.Array] = None,
    ) -> Schedule:
        """Stage 1 (probe) + step allocation, dispatched via the registry.

        Every family (refine included) rides the same path: run the probe
        its ``ScheduleFamily.probe`` spec names, hand the result to its
        uniform-signature builder. Probe cost: n_int+1 (+rounds) forwards.
        """
        fam = schedule.family(self.method)
        probe = probes.run_probe(
            fam.probe,
            self.f,
            x,
            baseline,
            target,
            n_int=self.n_int,
            rounds=self.refine_rounds,
            mask=mask,
        )
        return fam.build(
            probe, self.m, power=self.power, min_steps=self.min_steps, rule=self.rule
        )

    def attribute(
        self,
        x: jax.Array,
        baseline: jax.Array,
        target: Any,
        mask: Optional[jax.Array] = None,
    ) -> IGResult:
        sched = self.build_schedule(x, baseline, target, mask)
        kw = {}
        if self.interp_fn is not None:
            kw["interp_fn"] = self.interp_fn
        if self.accum_fn is not None:
            kw["accum_fn"] = self.accum_fn
        return ig.attribute(
            self.f, x, baseline, sched, target, mask=mask, chunk=self.chunk, **kw
        )

    def jitted(self) -> Callable:
        """One compiled end-to-end (stage1 + stage2) explanation step."""
        return jax.jit(self.attribute)
