"""High-level Explainer API — the paper's algorithm as a one-call feature.

    explainer = Explainer(f, method="ig", schedule="paper", n_int=4, m=64)
    result = explainer.attribute(x, baseline, target)

``f(xs, targets) -> (N,)`` is any differentiable scalar model output
(classifier probability, LM next-token log-prob, ...).

Two orthogonal registries compose here (DESIGN.md §2/§8):
  * ``schedule`` — a ``repro.core.schedule.SCHEDULES`` family name: where the
    quadrature nodes go (uniform / paper / warp / gauss / refine);
  * ``method`` — a ``repro.core.methods.METHODS`` name: what accumulates at
    those nodes (ig / idgi / noise_tunnel / expected_grad).
Every method rides every schedule; path-ensemble methods (noise_tunnel,
expected_grad) expand each example to ``n_samples`` contiguous rows before
stage 1 and reduce (mean over samples) after stage 2, so the compiled
pipeline only ever sees plain per-row attribution problems.

A quick end-to-end example (the quadratic has a linear path integrand, so
the midpoint rule is exact and the completeness gap δ is ~0):

    >>> import jax.numpy as jnp
    >>> f = lambda xs, targets: jnp.sum(xs ** 2, axis=-1)
    >>> ex = Explainer(f, schedule="uniform", m=8)
    >>> res = ex.attribute(jnp.ones((2, 3)), jnp.zeros((2, 3)), None)
    >>> res.attributions.shape
    (2, 3)
    >>> bool(res.delta.max() < 1e-4)  # Σφ == f(x) − f(x′) = 3.0
    True

Under a device mesh (``mesh=``, ``mesh_rules=``), the adaptive AOT
executables are compiled with ``NamedSharding``s over the leading batch dim
(DESIGN.md §9); the serving-grade path is ``repro.serve.ExplainEngine``.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ig, methods as methods_mod, probes
from repro.core import schedule as schedules
from repro.core.ig import IGResult, IGState
from repro.core.methods import MethodSpec
from repro.core.probes import ScalarFn, repeat_tree
from repro.core.schedule import Schedule


@dataclass
class Explainer:
    """One model function + one (method, schedule) configuration.

    Args:
        f: ``f(xs, targets) -> (N,)`` differentiable scalar model output.
        method: attribution method name in ``methods.METHODS`` (or a spec).
        schedule: schedule family name in ``schedule.SCHEDULES``.
        m: total interpolation steps (the stage-2 budget).
        n_int: stage-1 probe intervals (paper sweeps 2..8).
        chunk: stage-2 step chunk size (0 = all ``m`` at once).
        mesh / mesh_rules: optional device mesh — the adaptive AOT
            executables then shard every batch-leading input over the
            mesh's data axes (DESIGN.md §9).

    Example (paper schedule on a tiny quadratic):

        >>> import jax.numpy as jnp
        >>> f = lambda xs, t: jnp.sum(xs ** 2, axis=-1)
        >>> ex = Explainer(f, method="ig", schedule="paper", m=16, n_int=4)
        >>> res = ex.attribute(2.0 * jnp.ones((1, 4)), jnp.zeros((1, 4)), None)
        >>> bool(abs(res.attributions.sum() - res.f_x[0]) < 1e-3)
        True
    """

    f: ScalarFn
    method: Union[str, MethodSpec] = "ig"  # any name in methods.METHODS
    schedule: str = "paper"  # any name in schedule.SCHEDULES
    m: int = 64  # total interpolation steps
    n_int: int = 4  # stage-1 intervals (paper sweeps 2..8)
    refine_rounds: int = 4  # for the "refine" probe
    power: float = 0.5  # sqrt attenuation (paper); 1.0 = linear
    min_steps: int = 1
    rule: str = "midpoint"  # uniform-rule variant
    chunk: int = 0  # stage-2 step chunk (0 = all at once)
    # fused stage 2 (DESIGN.md §10): interpolation composed with the model
    # forward under one VJP — the (B·chunk, *F) interpolant batch never
    # crosses a program boundary, and grad-linear accumulators collapse the
    # per-step gradient batch into one (B, *F) cotangent.
    fused: bool = False
    interp_fn: Callable = None  # optional Pallas kernel injection
    interp_add_fn: Callable = None  # fused-path kernel injection (§10)
    accum_fn: Callable = None
    # path-ensemble controls (noise_tunnel / expected_grad): 0 samples means
    # "the method's registered default"; ``sample_seed`` makes the ensemble
    # deterministic — the same Explainer config always draws the same paths,
    # which is what lets adaptive runs be bit-compared against fixed runs.
    n_samples: int = 0
    sigma: float = 0.0
    sample_seed: int = 0
    # optional device mesh (DESIGN.md §9): attribute_adaptive's AOT rung
    # executables compile with NamedShardings over the batch-leading dim of
    # every input, and the cache key grows the mesh axis sizes so sharded
    # and single-device entries coexist. None = single-device.
    mesh: Any = None
    mesh_rules: Any = None

    @property
    def spec(self) -> MethodSpec:
        """The resolved ``MethodSpec`` for ``self.method``."""
        return methods_mod.get(self.method)

    @property
    def ensemble_size(self) -> int:
        """Sample rows per example (1 for non-ensemble methods)."""
        spec = self.spec
        if spec.expand is None:
            return 1
        return self.n_samples if self.n_samples else spec.n_samples

    @property
    def ensemble_sigma(self) -> float:
        """Path-ensemble perturbation scale (method default unless set)."""
        return self.sigma if self.sigma else self.spec.sigma_default

    # -- path-ensemble expansion ------------------------------------------

    def expand_inputs(
        self,
        x: jax.Array,
        baseline: jax.Array,
        target: Any,
        mask: Optional[jax.Array] = None,
    ) -> tuple[jax.Array, jax.Array, Any, Optional[jax.Array], int]:
        """(B, ...) -> (B·n, ...) sample rows (identity for n == 1), samples
        of example b contiguous at rows [b·n, (b+1)·n)."""
        spec, n = self.spec, self.ensemble_size
        if spec.expand is None or n == 1:
            return x, baseline, target, mask, 1
        key = jax.random.PRNGKey(self.sample_seed)
        x2, b2 = spec.expand(x, baseline, key, n, self.ensemble_sigma)
        t2 = repeat_tree(target, n)
        m2 = None if mask is None else jnp.repeat(mask, n, axis=0)
        return x2, b2, t2, m2, n

    @staticmethod
    def reduce_result(res: IGResult, n: int) -> IGResult:
        """Mean over each example's n contiguous sample rows; δ is recomputed
        on the reduced quantities (the expectation's completeness gap, not
        the mean of per-sample gaps)."""
        if n == 1:
            return res
        red = lambda a: a.reshape((-1, n) + a.shape[1:]).mean(axis=1)
        attr, f_x, f_b = red(res.attributions), red(res.f_x), red(res.f_baseline)
        B = attr.shape[0]
        delta = jnp.abs(attr.reshape(B, -1).sum(-1) - (f_x - f_b))
        return IGResult(attr, f_x, f_b, delta)

    # -- fixed-m attribution ----------------------------------------------

    def build_schedule(
        self,
        x: jax.Array,
        baseline: jax.Array,
        target: Any,
        mask: Optional[jax.Array] = None,
        f_x: Optional[jax.Array] = None,
    ) -> Schedule:
        """Stage 1 (probe) + step allocation, dispatched via the registry.

        Every family (refine included) rides the same path: run the probe
        its ``ScheduleFamily.probe`` spec names, hand the result to its
        uniform-signature builder. Probe cost: n_int+1 (+rounds) forwards,
        minus one when ``f_x`` donates the α=1 endpoint (probe-reuse
        contract — see ``probes.boundary_values``).
        """
        fam = schedules.family(self.schedule)
        probe = probes.run_probe(
            fam.probe,
            self.f,
            x,
            baseline,
            target,
            n_int=self.n_int,
            rounds=self.refine_rounds,
            mask=mask,
            known_fx=f_x,
        )
        return fam.build(
            probe, self.m, power=self.power, min_steps=self.min_steps, rule=self.rule
        )

    def attribute(
        self,
        x: jax.Array,
        baseline: jax.Array,
        target: Any,
        mask: Optional[jax.Array] = None,
        f_x: Optional[jax.Array] = None,
    ) -> IGResult:
        """Fixed-m attribution: stage-1 probe + stage-2 accumulation.

        Args:
            x: (B, *F) inputs; baseline: (B, *F) path start x′.
            target: pytree of per-example arrays passed through to ``f``
                (``None`` if ``f`` ignores it).
            mask: optional (B, *L) real-position mask — masked positions
                interpolate to the baseline and attribute exactly 0.
            f_x: optional (B,) known endpoint values f(x) — the probe-reuse
                contract (unified serving): the α=1 probe slot and the
                completeness endpoint reuse this value instead of re-running
                the forward. Dropped for path-ensemble methods (samples
                perturb x, so the passed value is for the wrong point).

        Returns:
            ``IGResult(attributions (B, *F), f_x, f_baseline, delta)`` where
            ``delta`` is the completeness gap |Σφ − (f_x − f_baseline)|.
        """
        x2, b2, t2, m2, n = self.expand_inputs(x, baseline, target, mask)
        if n != 1:
            f_x = None  # ensemble rows are perturbed — the endpoint moved
        sched = self.build_schedule(x2, b2, t2, m2, f_x=f_x)
        res = ig.attribute(
            self.f,
            x2,
            b2,
            sched,
            t2,
            method=self.spec,
            mask=m2,
            chunk=self.chunk,
            f_x=f_x,
            **self._ig_kwargs(),
        )
        return self.reduce_result(res, n)

    def jitted(self) -> Callable:
        """One compiled end-to-end (stage 1 + stage 2) explanation step —
        the single-program form the paper benchmarks; the serving engine
        AOT-compiles the same unit per bucket shape instead."""
        return jax.jit(self.attribute)

    # -- adaptive iso-convergence (DESIGN.md §7) ---------------------------

    @property
    def adaptive_chunk(self) -> int:
        """Stage-2 chunk used by the resumable path. ``chunk=0`` becomes the
        base rung size ``m`` so every rung's scan boundaries align with a
        fixed run over the final refined schedule (bit-identity needs the
        same chunking on both sides)."""
        c = self.chunk if self.chunk else self.m
        assert self.m % c == 0, (self.m, c)
        return c

    def _ig_kwargs(self) -> dict:
        kw = {}
        if self.fused:
            kw["fused"] = True
        if self.interp_fn is not None:
            kw["interp_fn"] = self.interp_fn
        if self.interp_add_fn is not None:
            kw["interp_add_fn"] = self.interp_add_fn
        if self.accum_fn is not None:
            kw["accum_fn"] = self.accum_fn
        return kw

    def start(
        self,
        x: jax.Array,
        baseline: jax.Array,
        target: Any,
        mask: Optional[jax.Array] = None,
        f_x: Optional[jax.Array] = None,
    ) -> tuple[IGResult, IGState, Schedule]:
        """Rung 0 of the adaptive ladder: probe, build the base schedule,
        accumulate its m nodes, and return the resumable state plus the
        materialized schedule (needed to refine later).

        ``f_x`` donates the known endpoint (probe-reuse contract, see
        ``attribute``); the returned ``IGState`` carries it, so every later
        ladder hop is unchanged whether the endpoint was donated or computed.

        Per-ROW, never expanded: the serving engine (and the adaptive loop
        below) performs path-ensemble expansion itself at batch-construction
        time, so this compiled unit stays method-independent up to the
        accumulator class (DESIGN.md §8)."""
        sched = self.build_schedule(x, baseline, target, mask, f_x=f_x)
        res, state = ig.attribute(
            self.f,
            x,
            baseline,
            sched,
            target,
            method=self.spec,
            mask=mask,
            chunk=self.adaptive_chunk,
            return_state=True,
            f_x=f_x,
            **self._ig_kwargs(),
        )
        return res, state, sched

    def resume(
        self,
        x: jax.Array,
        baseline: jax.Array,
        target: Any,
        new_nodes: Schedule,
        state: IGState,
        mask: Optional[jax.Array] = None,
    ) -> tuple[IGResult, IGState]:
        """One ladder hop: accumulate the refined schedule's NEW nodes on top
        of ``state``. ``state_scale=0.5`` re-expresses the old accumulator in
        the refined rung's exactly-halved weights. Per-row (see ``start``)."""
        res, state = ig.attribute(
            self.f,
            x,
            baseline,
            new_nodes,
            target,
            method=self.spec,
            mask=mask,
            chunk=self.adaptive_chunk,
            state=state,
            state_scale=0.5,
            return_state=True,
            **self._ig_kwargs(),
        )
        return res, state

    def attribute_adaptive(
        self,
        x: jax.Array,
        baseline: jax.Array,
        target: Any,
        *,
        tol: float = 1e-2,
        m_max: int = 0,
        mask: Optional[jax.Array] = None,
        cache: Optional[dict] = None,
    ) -> tuple[IGResult, dict]:
        """δ-feedback early-exit attribution up the m-ladder.

        Runs the base rung (``self.m`` nodes), then repeatedly refines the
        schedule (nested doubling — no prior gradient is discarded) and
        resumes accumulation for the rows whose completeness gap still
        exceeds ``tol · |f(x) − f(x′)|``, until all converge or the ladder
        tops out at ``m_max`` (default ``8·m``). Converged rows exit
        with the rung they converged at; their rows are excluded from later
        hops (the serving engine additionally re-buckets survivors — here
        rows are simply gathered, so each distinct (active-count, rung)
        shape compiles once into ``cache``; under a mesh the active count is
        first padded up to a multiple of the data-parallel extent so hops
        shard — see DESIGN.md §9 — and ``info["mesh_fallbacks"]`` counts any
        executable that still had to compile replicated).

        Path-ensemble methods expand each example to ``ensemble_size``
        sample rows first; the ladder then runs per ROW (each sample
        converges on its own δ) and the final IGResult is reduced back to
        per-example means. The ``info`` arrays stay per-row — ``n_samples``
        reports the expansion factor for callers that aggregate.

        Returns ``(IGResult, info)``: per-example final attributions/δ, and
        ``info`` with per-row ``m_used``/``hops``/``delta``/``threshold``
        /``converged`` plus aggregate ``total_steps`` (Σ m_used — the
        iso-convergence metric), ``probe_forwards``, ``compiles``, and the
        ``ladder``. Pass the same ``cache`` dict across calls to reuse the
        AOT-compiled rung executables (zero recompiles at steady state).
        """
        fam = schedules.family(self.schedule)
        ladder = schedules.m_ladder(self.m, m_max if m_max else 8 * self.m)
        cache = cache if cache is not None else {}
        compiles = 0
        mesh_fallbacks = 0
        x, baseline, target, mask, n_samples = self.expand_inputs(
            x, baseline, target, mask
        )
        B = x.shape[0]
        # data-parallel extent: hop batches are padded up to a multiple of
        # this (mesh-divisible padding, DESIGN.md §9) so survivors shard
        # instead of silently running replicated
        if self.mesh is not None:
            from repro.sharding import DEFAULT_RULES, dp_size

            dp = dp_size(self.mesh, self.mesh_rules or DEFAULT_RULES)
        else:
            dp = 1

        def aot(key, fn, args, donate=()):
            nonlocal compiles, mesh_fallbacks
            ex = cache.get(key)
            if ex is None:
                jit_kw = {}
                if donate:
                    # hop executables donate the IGState (DESIGN.md §10):
                    # the (B, *F) f32 accumulator is rebuilt fresh per rung
                    # and never read back, so the executable may write the
                    # resumed accumulator in place instead of copying
                    jit_kw["donate_argnums"] = donate
                # dp > 1 guard matches ExplainEngine._executable: on a
                # dp<=1 mesh there is nothing to shard, not a fallback
                if self.mesh is not None and dp > 1:
                    # shard every batch-leading input over the mesh's data
                    # axes (DESIGN.md §9); the AOT executable then places
                    # host arrays onto the mesh itself at call time. A tree
                    # whose batch does not divide dp compiles replicated and
                    # is COUNTED (info["mesh_fallbacks"]), never silent.
                    from repro.sharding import explain_arg_shardings

                    sh = explain_arg_shardings(
                        self.mesh, args, self.mesh_rules or DEFAULT_RULES
                    )
                    if sh is not None:
                        jit_kw["in_shardings"] = sh
                    else:
                        mesh_fallbacks += 1
                sds = jax.tree.map(
                    lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), args
                )
                with warnings.catch_warnings():
                    # CPU cannot honor donation; the aliasing request is
                    # still correct (and effective) on GPU/TPU backends
                    warnings.filterwarnings(
                        "ignore", message=".*donated buffers were not usable.*"
                    )
                    ex = jax.jit(fn, **jit_kw).lower(*sds).compile()
                cache[key] = ex
                compiles += 1
            return ex

        # cache keys carry the explainer config AND input signature (dtype,
        # target pytree structure, mesh axis sizes): a cache dict shared
        # across calls must never hand back an incompatible compiled program
        from repro.sharding import mesh_cache_key

        cfg_key = (
            self.spec.name,
            self.schedule,
            self.m,
            self.n_int,
            self.adaptive_chunk,
            self.fused,
            str(x.dtype),
            jax.tree.structure(target),
            mesh_cache_key(self.mesh),
        )
        has_mask = mask is not None
        args = (x, baseline, target, mask)
        res, state, sched = aot(
            ("start", cfg_key, x.shape, has_mask), self.start, args
        )(*args)

        delta = np.asarray(res.delta).copy()
        f_x, f_b = np.asarray(res.f_x), np.asarray(res.f_baseline)
        threshold = tol * np.abs(f_x - f_b)
        out_attr = np.asarray(res.attributions).copy()
        m_used = np.full((B,), ladder[0], np.int64)
        hops = np.zeros((B,), np.int64)
        total_steps = B * ladder[0]

        act = np.flatnonzero(delta > threshold)
        # per-example schedules for the survivors (uniform builds a shared
        # (m,) schedule — broadcast so rows can be gathered independently)
        bcast = lambda v: np.broadcast_to(np.asarray(v), (B, np.shape(v)[-1]))
        a_act, w_act = bcast(sched.alphas)[act], bcast(sched.weights)[act]
        acc_act = np.asarray(state.acc)[act]
        tgt_np = jax.tree.map(np.asarray, target)
        mask_np = np.asarray(mask) if has_mask else None

        for rung in ladder[1:]:
            if act.size == 0:
                break
            n_new = rung // 2
            refined = fam.refine(Schedule(jnp.asarray(a_act), jnp.asarray(w_act)))
            ra, rw = np.asarray(refined.alphas), np.asarray(refined.weights)
            # mesh-divisible padding (DESIGN.md §9): repeat the last survivor
            # into pad slots so the hop batch divides dp and shards; pad-row
            # results are sliced off below. sel indexes act-aligned arrays,
            # rows the full batch. No-op (sel == arange) when dp == 1.
            n_act = act.size
            sel = np.concatenate(
                [np.arange(n_act), np.full((-n_act) % dp, n_act - 1, np.int64)]
            )
            rows = act[sel]
            new_sched = Schedule(
                jnp.asarray(ra[sel][:, n_new:]), jnp.asarray(rw[sel][:, n_new:])
            )
            hop_args = (
                np.asarray(x)[rows],
                np.asarray(baseline)[rows],
                jax.tree.map(lambda t: t[rows], tgt_np),
                new_sched,
                IGState(acc_act[sel], f_x[rows], f_b[rows]),
                mask_np[rows] if has_mask else None,
            )
            ex = aot(
                ("hop", cfg_key, sel.size, n_new, x.shape[1:], has_mask),
                self.resume,
                hop_args,
                donate=(4,),  # the IGState — see aot()
            )
            res2, st2 = ex(*hop_args)
            total_steps += n_act * n_new
            d2 = np.asarray(res2.delta)[:n_act]
            out_attr[act] = np.asarray(res2.attributions)[:n_act]
            delta[act] = d2
            m_used[act] = rung
            hops[act] += 1
            keep = d2 > threshold[act]
            act = act[keep]
            a_act, w_act = ra[:n_act][keep], rw[:n_act][keep]
            acc_act = np.asarray(st2.acc)[:n_act][keep]

        final = self.reduce_result(
            IGResult(
                jnp.asarray(out_attr), res.f_x, res.f_baseline, jnp.asarray(delta)
            ),
            n_samples,
        )
        info = {
            "m_used": m_used,
            "hops": hops,
            "delta": delta,
            "threshold": threshold,
            "converged": delta <= threshold,
            "total_steps": int(total_steps),
            "probe_forwards": B
            * probes.probe_cost(fam.probe, n_int=self.n_int, rounds=self.refine_rounds),
            "compiles": compiles,
            "mesh_fallbacks": mesh_fallbacks,
            "ladder": ladder,
            "chunk": self.adaptive_chunk,
            "n_samples": n_samples,
        }
        return final, info
