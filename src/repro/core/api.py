"""High-level Explainer API — the paper's algorithm as a one-call feature.

    explainer = Explainer(f, method="paper", n_int=4, m=64)
    result = explainer.attribute(x, baseline, target)

``f(xs, targets) -> (N,)`` is any differentiable scalar model output
(classifier probability, LM next-token log-prob, ...).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import ig, probes, schedule
from repro.core.ig import IGResult
from repro.core.probes import ScalarFn
from repro.core.schedule import Schedule


@dataclass
class Explainer:
    f: ScalarFn
    method: str = "paper"  # uniform | paper | warp | gauss | refine
    m: int = 64  # total interpolation steps
    n_int: int = 4  # stage-1 intervals (paper sweeps 2..8)
    refine_rounds: int = 4  # for method == "refine"
    power: float = 0.5  # sqrt attenuation (paper); 1.0 = linear
    min_steps: int = 1
    rule: str = "midpoint"  # uniform-rule variant
    chunk: int = 0  # stage-2 step chunk (0 = all at once)
    interp_fn: Callable = None  # optional Pallas kernel injection
    accum_fn: Callable = None

    def build_schedule(
        self, x: jax.Array, baseline: jax.Array, target: jax.Array
    ) -> Schedule:
        """Stage 1 (probe) + step allocation. Probe cost: n_int+1 forwards."""
        if self.method == "uniform":
            return schedule.uniform(self.m, self.rule)
        if self.method == "refine":
            b, v = probes.refined_boundaries(
                self.f, x, baseline, target, self.n_int, self.refine_rounds
            )
            return schedule.from_boundaries(b, v, self.m, power=self.power)
        vals = probes.boundary_values(self.f, x, baseline, target, self.n_int)
        if self.method == "paper":
            return schedule.paper(vals, self.m, power=self.power, min_steps=self.min_steps)
        if self.method == "warp":
            return schedule.warp(vals, self.m, power=self.power)
        if self.method == "gauss":
            return schedule.gauss(vals, self.m, power=self.power)
        raise ValueError(f"unknown method {self.method!r}")

    def attribute(
        self, x: jax.Array, baseline: jax.Array, target: jax.Array
    ) -> IGResult:
        sched = self.build_schedule(x, baseline, target)
        kw = {}
        if self.interp_fn is not None:
            kw["interp_fn"] = self.interp_fn
        if self.accum_fn is not None:
            kw["accum_fn"] = self.accum_fn
        return ig.attribute(
            self.f, x, baseline, sched, target, chunk=self.chunk, **kw
        )

    def jitted(self) -> Callable:
        """One compiled end-to-end (stage1 + stage2) explanation step."""
        return jax.jit(self.attribute)
