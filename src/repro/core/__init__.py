"""The paper's primary contribution: non-uniform interpolation IG."""
from repro.core.api import Explainer
from repro.core.ig import IGResult, attribute
from repro.core.methods import METHODS, MethodSpec
from repro.core.schedule import Schedule, uniform, paper, warp, gauss

__all__ = [
    "Explainer",
    "IGResult",
    "attribute",
    "METHODS",
    "MethodSpec",
    "Schedule",
    "uniform",
    "paper",
    "warp",
    "gauss",
]
