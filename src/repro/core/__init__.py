"""The paper's primary contribution: non-uniform interpolation IG."""
from repro.core.api import Explainer
from repro.core.ig import IGResult, attribute
from repro.core.schedule import Schedule, uniform, paper, warp, gauss

__all__ = ["Explainer", "IGResult", "attribute", "Schedule", "uniform", "paper", "warp", "gauss"]
