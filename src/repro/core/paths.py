"""Straight-line IG path (Eq. 1): x(α) = x' + α (x - x')."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mask_to_baseline(
    x: jax.Array, baseline: jax.Array, mask: jax.Array
) -> jax.Array:
    """Pin masked-out positions exactly to the baseline (identity w/o mask).

    mask: (B, *L) with L a prefix of x's feature dims; 1/True = real. The one
    shared implementation — the interp oracle, the Pallas ops wrappers, and
    the IG engine all pin through here (bucketed serving; DESIGN.md §6).
    """
    if mask is None:
        return x
    m = mask.reshape(mask.shape + (1,) * (x.ndim - mask.ndim))
    return jnp.where(m.astype(bool), x, baseline)


def interpolate(
    x: jax.Array,
    baseline: jax.Array,
    alphas: jax.Array,
    *,
    mask: jax.Array = None,
) -> jax.Array:
    """Batch of interpolants along the straight-line path.

    x, baseline: (B, *F);  alphas: (K,) or (B, K)  ->  (B, K, *F).
    mask: optional (B, *L) real-position mask (L a prefix of F) — masked
    positions stay exactly at the baseline for every α (bucketed serving).

    This is the pure-jnp oracle for the ``repro.kernels.interpolate`` Pallas
    kernel (which fuses the broadcast to avoid K× HBM reads of x, x').
    """
    x = mask_to_baseline(x, baseline, mask)
    nf = x.ndim - 1
    if alphas.ndim == 1:
        a = alphas.reshape((1, -1) + (1,) * nf)
    else:
        a = alphas.reshape(alphas.shape + (1,) * nf)
    xe = x[:, None]
    be = baseline[:, None]
    return (be + a.astype(x.dtype) * (xe - be)).astype(x.dtype)


def at_alpha(x: jax.Array, baseline: jax.Array, alpha: jax.Array) -> jax.Array:
    """Single path point; alpha: () or (B,)."""
    a = alpha.reshape((-1,) + (1,) * (x.ndim - 1)) if alpha.ndim else alpha
    return (baseline + a.astype(x.dtype) * (x - baseline)).astype(x.dtype)
