"""Straight-line IG path (Eq. 1): x(α) = x' + α (x - x')."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mask_to_baseline(
    x: jax.Array, baseline: jax.Array, mask: jax.Array
) -> jax.Array:
    """Pin masked-out positions exactly to the baseline (identity w/o mask).

    mask: (B, *L) with L a prefix of x's feature dims; 1/True = real. The one
    shared implementation — the interp oracle, the Pallas ops wrappers, and
    the IG engine all pin through here (bucketed serving; DESIGN.md §6).
    """
    if mask is None:
        return x
    m = mask.reshape(mask.shape + (1,) * (x.ndim - mask.ndim))
    return jnp.where(m.astype(bool), x, baseline)


def interpolate(
    x: jax.Array,
    baseline: jax.Array,
    alphas: jax.Array,
    *,
    mask: jax.Array = None,
) -> jax.Array:
    """Batch of interpolants along the straight-line path.

    x, baseline: (B, *F);  alphas: (K,) or (B, K)  ->  (B, K, *F).
    mask: optional (B, *L) real-position mask (L a prefix of F) — masked
    positions stay exactly at the baseline for every α (bucketed serving).

    This is the pure-jnp oracle for the ``repro.kernels.interpolate`` Pallas
    kernel (which fuses the broadcast to avoid K× HBM reads of x, x').
    """
    x = mask_to_baseline(x, baseline, mask)
    nf = x.ndim - 1
    if alphas.ndim == 1:
        a = alphas.reshape((1, -1) + (1,) * nf)
    else:
        a = alphas.reshape(alphas.shape + (1,) * nf)
    xe = x[:, None]
    be = baseline[:, None]
    return (be + a.astype(x.dtype) * (xe - be)).astype(x.dtype)


def interp_add(
    x: jax.Array,
    baseline: jax.Array,
    alphas: jax.Array,
    carry: jax.Array,
    *,
    mask: jax.Array = None,
) -> jax.Array:
    """Interpolants plus an additive f32 carry — the fused-stage-2 unit.

    x, baseline: (B, *F); alphas: (K,) or (B, K); carry: (B, *F) f32
    (broadcast over the step axis) or (B, K, *F) f32 (per-step). Returns
    (B, K, *F) in ``x.dtype``.

    This is the function the fused stage 2 (``ig.attribute(fused=True)``,
    DESIGN.md §10) differentiates w.r.t. ``carry`` at zero: the interpolant
    batch is then generated INSIDE the differentiated chunk program (never a
    VJP-boundary input that must be materialized in HBM), and the transpose
    of the broadcast-add IS the weighted gradient accumulation.

    Dtype contract: the interpolants come from ``interpolate`` at INPUT
    precision — at ``carry == 0`` the output is bit-identical to the unfused
    path's interpolants (an x.dtype→f32→x.dtype round trip is exact), so
    fused and unfused stage 2 evaluate the model at the same quadrature
    nodes even under bf16. The carry add is lifted to f32, so the carry
    cotangent — the accumulator increment — reduces over the step axis in
    f32 regardless of the model dtype (same precision as the unfused f32
    accumulators). Pallas drop-in: the custom-VJP op in
    ``repro.kernels.interp_accum.ops``.
    """
    xi = interpolate(x, baseline, alphas, mask=mask).astype(jnp.float32)
    if carry.ndim == x.ndim:  # (B, *F): broadcast over the step axis
        carry = carry[:, None]
    return (xi + carry).astype(x.dtype)


def at_alpha(x: jax.Array, baseline: jax.Array, alpha: jax.Array) -> jax.Array:
    """Single path point; alpha: () or (B,)."""
    a = alpha.reshape((-1,) + (1,) * (x.ndim - 1)) if alpha.ndim else alpha
    return (baseline + a.astype(x.dtype) * (x - baseline)).astype(x.dtype)
