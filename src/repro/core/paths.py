"""Straight-line IG path (Eq. 1): x(α) = x' + α (x - x')."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def interpolate(x: jax.Array, baseline: jax.Array, alphas: jax.Array) -> jax.Array:
    """Batch of interpolants along the straight-line path.

    x, baseline: (B, *F);  alphas: (K,) or (B, K)  ->  (B, K, *F).

    This is the pure-jnp oracle for the ``repro.kernels.interpolate`` Pallas
    kernel (which fuses the broadcast to avoid K× HBM reads of x, x').
    """
    nf = x.ndim - 1
    if alphas.ndim == 1:
        a = alphas.reshape((1, -1) + (1,) * nf)
    else:
        a = alphas.reshape(alphas.shape + (1,) * nf)
    xe = x[:, None]
    be = baseline[:, None]
    return (be + a.astype(x.dtype) * (xe - be)).astype(x.dtype)


def at_alpha(x: jax.Array, baseline: jax.Array, alpha: jax.Array) -> jax.Array:
    """Single path point; alpha: () or (B,)."""
    a = alpha.reshape((-1,) + (1,) * (x.ndim - 1)) if alpha.ndim else alpha
    return (baseline + a.astype(x.dtype) * (x - baseline)).astype(x.dtype)
