"""Related-work integrations on top of the engine (paper §I: XRAI, Noise
Tunnel, multi-baseline all *reuse* baseline IG — so all of them inherit the
NUIG speedup for free; these wrappers demonstrate that composition).

``noise_samples`` is the one shared sampling primitive: the registered
``noise_tunnel`` MethodSpec (``repro.core.methods``) expands batches through
it, and the legacy ``noise_tunnel`` wrapper below averages full IGResults
over the same distribution.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.ig import IGResult


def noise_samples(x: jax.Array, key: jax.Array, n: int, sigma: float) -> jax.Array:
    """n gaussian-noised copies per example: (B, *F) -> (B·n, *F), samples of
    example b contiguous at rows [b·n, (b+1)·n) — the layout the MethodSpec
    expansion/reduction contract assumes (DESIGN.md §8)."""
    xr = jnp.repeat(x, n, axis=0)
    noise = jax.random.normal(key, xr.shape) * sigma
    return (xr + noise.astype(xr.dtype)).astype(x.dtype)


def noise_tunnel(
    attribute_fn: Callable[[jax.Array], IGResult],
    x: jax.Array,
    key: jax.Array,
    *,
    n_samples: int = 4,
    sigma: float = 0.1,
) -> IGResult:
    """SmoothGrad-style: average attributions over noisy copies of x.

    ``attribute_fn(x_noisy) -> IGResult`` encapsulates baseline + schedule, so
    NUIG (or any schedule) composes transparently.
    """
    def one(k):
        noise = jax.random.normal(k, x.shape).astype(x.dtype) * sigma
        return attribute_fn(x + noise)

    results = [one(k) for k in jax.random.split(key, n_samples)]
    stack = lambda sel: jnp.stack([sel(r) for r in results]).mean(0)
    return IGResult(
        stack(lambda r: r.attributions),
        stack(lambda r: r.f_x),
        stack(lambda r: r.f_baseline),
        stack(lambda r: r.delta),
    )


def multi_baseline(
    attribute_fn: Callable[[jax.Array], IGResult],
    baselines: list[jax.Array],
) -> IGResult:
    """Expected-gradients-style averaging over several baselines [8]."""
    results = [attribute_fn(b) for b in baselines]
    stack = lambda sel: jnp.stack([sel(r) for r in results]).mean(0)
    return IGResult(
        stack(lambda r: r.attributions),
        stack(lambda r: r.f_x),
        stack(lambda r: r.f_baseline),
        stack(lambda r: r.delta),
    )
