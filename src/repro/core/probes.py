"""Stage 1 of NUIG: probe the model along the path (paper §III Algorithm).

``n_int + 1`` forward-only passes at interval boundaries measure the change in
target probability per interval — the information-content metric. Probes are
batched across (examples × boundaries) so stage 1 rides the same compiled
forward as everything else (the paper's 0.2–3.2% overhead, §IV).

``run_probe`` is the registry-facing entry point: every schedule family in
``repro.core.schedule.SCHEDULES`` names one of the probe kinds here and the
caller never special-cases a method. ``target`` may be any pytree of
per-example arrays (e.g. ``{"target": ids, "pos": positions}`` for bucketed
serving) — it is repeated along axis 0 to match the folded (batch × probe)
axis. ``mask`` pins padded positions to the baseline so the probe never sees
off-path interpolants for shape-bucketed requests.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.paths import interpolate, mask_to_baseline
from repro.core.schedule import Probe

# f: (xs (N, *F), targets) -> (N,) scalar model output (prob / log-prob).
# ``targets`` is a pytree of (N, ...) arrays; plain (N,) ids are the common case.
ScalarFn = Callable[[jax.Array, Any], jax.Array]


def repeat_tree(target: Any, k: int) -> Any:
    """Repeat every leaf k× along axis 0: (B, ...) -> (B*k, ...)."""
    return jax.tree.map(lambda a: jnp.repeat(a, k, axis=0), target)


def boundary_values(
    f: ScalarFn,
    x: jax.Array,
    baseline: jax.Array,
    target: Any,
    n_int: int,
    *,
    mask: Optional[jax.Array] = None,
    known_fx: Optional[jax.Array] = None,
) -> jax.Array:
    """f at the n_int+1 uniform interval boundaries. Returns (B, n_int+1).

    ``known_fx`` is the KV-cache probe-reuse contract (unified serving,
    DESIGN.md §11): the α=1 boundary IS ``f(x)``, and a decode path that
    already ran the prompt forward (prefill logits) can hand that value in
    instead of paying the forward again — only the n_int boundaries below 1
    are evaluated and the passed (B,) value is spliced into the last slot.
    Per-row forward values are batch-shape independent, so the spliced probe
    is bit-identical to the full one whenever ``known_fx`` is (which holds
    for f32 prefill logits; see benchmarks/mixed_serving.py's gate).
    """
    B = x.shape[0]
    x = mask_to_baseline(x, baseline, mask)
    if known_fx is None:
        alphas = jnp.arange(n_int + 1) / n_int
        xi = interpolate(x, baseline, alphas)  # (B, n+1, *F)
        flat = xi.reshape((B * (n_int + 1),) + x.shape[1:])
        t = repeat_tree(target, n_int + 1)
        return f(flat, t).reshape(B, n_int + 1)
    alphas = jnp.arange(n_int) / n_int  # boundaries below α=1 only
    xi = interpolate(x, baseline, alphas)
    flat = xi.reshape((B * n_int,) + x.shape[1:])
    t = repeat_tree(target, n_int)
    vals = f(flat, t).reshape(B, n_int)
    return jnp.concatenate([vals, known_fx.astype(vals.dtype)[:, None]], axis=1)


def refined_boundaries(
    f: ScalarFn,
    x: jax.Array,
    baseline: jax.Array,
    target: Any,
    n0: int,
    rounds: int,
    *,
    mask: Optional[jax.Array] = None,
    known_fx: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Beyond-paper `secant-refine`: adaptively bisect the largest-|Δf|
    interval, one probe per round (static shapes: capacity = n0+1+rounds).

    Returns (boundaries (B, K), values (B, K)) sorted by boundary; padding
    duplicates the rightmost boundary (zero-width intervals, zero Δf).
    ``known_fx`` seeds the α=1 boundary value (see ``boundary_values``);
    bisection rounds never revisit the endpoints, so the splice is exact.
    """
    B = x.shape[0]
    x = mask_to_baseline(x, baseline, mask)
    vals0 = boundary_values(f, x, baseline, target, n0, known_fx=known_fx)
    b0 = jnp.broadcast_to(jnp.arange(n0 + 1) / n0, (B, n0 + 1))
    pad = rounds
    b = jnp.concatenate([b0, jnp.ones((B, pad))], axis=1)
    v = jnp.concatenate([vals0, jnp.repeat(vals0[:, -1:], pad, axis=1)], axis=1)

    def round_step(carry, _):
        b, v = carry
        d = jnp.abs(jnp.diff(v, axis=1)) * (jnp.diff(b, axis=1) > 1e-9)
        i = jnp.argmax(d, axis=1)  # (B,) interval to bisect
        left = jnp.take_along_axis(b, i[:, None], 1)[:, 0]
        right = jnp.take_along_axis(b, i[:, None] + 1, 1)[:, 0]
        mid = 0.5 * (left + right)
        xm = baseline + mid.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype) * (x - baseline)
        fm = f(xm, target)  # one batched probe per round
        # replace one padding slot (rightmost duplicate) with the new point
        slot = b.shape[1] - 1
        b2 = b.at[:, slot].set(mid)
        v2 = v.at[:, slot].set(fm)
        order = jnp.argsort(b2, axis=1)
        return (jnp.take_along_axis(b2, order, 1), jnp.take_along_axis(v2, order, 1)), None

    (b, v), _ = jax.lax.scan(round_step, (b, v), None, length=rounds)
    return b, v


def probe_cost(
    kind: str, *, n_int: int = 4, rounds: int = 4, known_fx: bool = False
) -> int:
    """Forward passes a probe kind spends per example (0 gradient steps).

    The adaptive serving path reports steps-to-tolerance; probe forwards are
    the paper's 0.2–3.2% stage-1 overhead and are accounted separately from
    gradient steps (a forward is roughly a third of a forward+backward).
    ``known_fx`` is the probe-reuse contract: the α=1 forward is donated by
    the decode path, so probing pays one fewer forward per example.
    """
    if kind == "none":
        return 0
    if kind == "boundary":
        base = n_int + 1
    elif kind == "refine":
        base = n_int + 1 + rounds
    else:
        raise ValueError(f"unknown probe kind {kind!r}")
    return base - 1 if known_fx else base


def run_probe(
    kind: str,
    f: ScalarFn,
    x: jax.Array,
    baseline: jax.Array,
    target: Any,
    *,
    n_int: int = 4,
    rounds: int = 4,
    mask: Optional[jax.Array] = None,
    known_fx: Optional[jax.Array] = None,
) -> Optional[Probe]:
    """Run the stage-1 probe a schedule family declares. Uniform signature
    for every kind so registries/engines need no per-method branching.
    ``known_fx`` (B,) donates the α=1 endpoint value (probe-reuse contract —
    see ``boundary_values``); ignored by probe kind "none"."""
    if kind == "none":
        return None
    if kind == "boundary":
        vals = boundary_values(f, x, baseline, target, n_int, mask=mask,
                               known_fx=known_fx)
        bounds = jnp.broadcast_to(jnp.arange(n_int + 1) / n_int, vals.shape)
        return Probe(bounds.astype(jnp.float32), vals)
    if kind == "refine":
        b, v = refined_boundaries(f, x, baseline, target, n_int, rounds,
                                  mask=mask, known_fx=known_fx)
        return Probe(b, v)
    raise ValueError(f"unknown probe kind {kind!r}")
