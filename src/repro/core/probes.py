"""Stage 1 of NUIG: probe the model along the path (paper §III Algorithm).

``n_int + 1`` forward-only passes at interval boundaries measure the change in
target probability per interval — the information-content metric. Probes are
batched across (examples × boundaries) so stage 1 rides the same compiled
forward as everything else (the paper's 0.2–3.2% overhead, §IV).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.paths import interpolate

# f: (xs (N, *F), targets (N,)) -> (N,) scalar model output (prob / log-prob)
ScalarFn = Callable[[jax.Array, jax.Array], jax.Array]


def boundary_values(
    f: ScalarFn, x: jax.Array, baseline: jax.Array, target: jax.Array, n_int: int
) -> jax.Array:
    """f at the n_int+1 uniform interval boundaries. Returns (B, n_int+1)."""
    B = x.shape[0]
    alphas = jnp.arange(n_int + 1) / n_int
    xi = interpolate(x, baseline, alphas)  # (B, n+1, *F)
    flat = xi.reshape((B * (n_int + 1),) + x.shape[1:])
    t = jnp.repeat(target, n_int + 1)
    return f(flat, t).reshape(B, n_int + 1)


def refined_boundaries(
    f: ScalarFn,
    x: jax.Array,
    baseline: jax.Array,
    target: jax.Array,
    n0: int,
    rounds: int,
) -> tuple[jax.Array, jax.Array]:
    """Beyond-paper `secant-refine`: adaptively bisect the largest-|Δf|
    interval, one probe per round (static shapes: capacity = n0+1+rounds).

    Returns (boundaries (B, K), values (B, K)) sorted by boundary; padding
    duplicates the rightmost boundary (zero-width intervals, zero Δf).
    """
    B = x.shape[0]
    vals0 = boundary_values(f, x, baseline, target, n0)  # (B, n0+1)
    b0 = jnp.broadcast_to(jnp.arange(n0 + 1) / n0, (B, n0 + 1))
    pad = rounds
    b = jnp.concatenate([b0, jnp.ones((B, pad))], axis=1)
    v = jnp.concatenate([vals0, jnp.repeat(vals0[:, -1:], pad, axis=1)], axis=1)

    def round_step(carry, _):
        b, v = carry
        d = jnp.abs(jnp.diff(v, axis=1)) * (jnp.diff(b, axis=1) > 1e-9)
        i = jnp.argmax(d, axis=1)  # (B,) interval to bisect
        left = jnp.take_along_axis(b, i[:, None], 1)[:, 0]
        right = jnp.take_along_axis(b, i[:, None] + 1, 1)[:, 0]
        mid = 0.5 * (left + right)
        xm = baseline + mid.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype) * (x - baseline)
        fm = f(xm, target)  # one batched probe per round
        # replace one padding slot (rightmost duplicate) with the new point
        slot = b.shape[1] - 1
        b2 = b.at[:, slot].set(mid)
        v2 = v.at[:, slot].set(fm)
        order = jnp.argsort(b2, axis=1)
        return (jnp.take_along_axis(b2, order, 1), jnp.take_along_axis(v2, order, 1)), None

    (b, v), _ = jax.lax.scan(round_step, (b, v), None, length=rounds)
    return b, v
