"""Attribution quality metrics.

convergence_delta — the paper's δ (Eq. 3, completeness gap): the *only*
metric the paper tunes against; iso-convergence = equal δ.

insertion/deletion AUC — beyond-paper sanity metric for heatmap quality
(higher insertion AUC / lower deletion AUC = better ordering of features).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.probes import ScalarFn


def convergence_delta(
    attributions: jax.Array, f_x: jax.Array, f_baseline: jax.Array
) -> jax.Array:
    """δ = |Σ_i φ_i − (f(x) − f(x'))|  per example (Eq. 3)."""
    B = attributions.shape[0]
    return jnp.abs(attributions.reshape(B, -1).sum(-1) - (f_x - f_baseline))


def completeness_satisfied(delta: jax.Array, tol: float) -> jax.Array:
    return delta <= tol


def insertion_deletion_auc(
    f: ScalarFn,
    x: jax.Array,
    baseline: jax.Array,
    attributions: jax.Array,
    target: jax.Array,
    steps: int = 16,
) -> tuple[jax.Array, jax.Array]:
    """Insert (resp. delete) features in decreasing-attribution order and
    trace f; returns (insertion_auc, deletion_auc), each (B,)."""
    B = x.shape[0]
    flat_x = x.reshape(B, -1)
    flat_b = baseline.reshape(B, -1)
    order = jnp.argsort(-attributions.reshape(B, -1), axis=-1)
    n = flat_x.shape[-1]
    rank = jnp.argsort(order, axis=-1)  # rank of each feature

    def curve(start_from_baseline: bool):
        def at_frac(i):
            kth = (i / steps) * n
            mask = (rank < kth).astype(x.dtype)  # top-k features "on"
            xs = jnp.where(
                mask > 0, flat_x, flat_b) if start_from_baseline else jnp.where(
                mask > 0, flat_b, flat_x)
            return f(xs.reshape(x.shape), target)

        vals = jnp.stack([at_frac(i) for i in range(steps + 1)])  # (steps+1, B)
        return jnp.trapezoid(vals, axis=0) / steps

    return curve(True), curve(False)
