"""IG baselines — the notion of 'missingness' (paper §II).

Vision: black / white / noise images. Token models: zero or pad-token
embeddings (interpolation happens in embedding space — tokens are discrete).

``BASELINES``/``get`` cover EVERY baseline here, including the ones that
need extra arguments (``gaussian`` a PRNG key, ``pad_embedding`` the
embedding table) — callers bind those with ``functools.partial`` or keyword
arguments; what the registry guarantees is that every name resolves and an
unknown name fails loudly with the valid names listed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def black(x: jax.Array) -> jax.Array:
    return jnp.zeros_like(x)


def white(x: jax.Array, value: float = 1.0) -> jax.Array:
    return jnp.full_like(x, value)


def gaussian(x: jax.Array, key: jax.Array, sigma: float = 1.0) -> jax.Array:
    return (jax.random.normal(key, x.shape) * sigma).astype(x.dtype)


def pad_embedding(embed_table: jax.Array, x_embeds: jax.Array, pad_id: int = 0) -> jax.Array:
    """Baseline for token models: every position = the pad-token embedding."""
    pad = embed_table[pad_id].astype(x_embeds.dtype)
    return jnp.broadcast_to(pad, x_embeds.shape)


BASELINES = {
    "black": black,
    "white": white,
    "gaussian": gaussian,
    "pad_embedding": pad_embedding,
}


def get(name: str):
    if name not in BASELINES:
        raise ValueError(
            f"unknown baseline {name!r}; valid baselines: {sorted(BASELINES)}"
        )
    return BASELINES[name]
