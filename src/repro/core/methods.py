"""Attribution-method zoo — the ``MethodSpec`` registry (DESIGN.md §8).

The paper accelerates one algorithm (path-integrated gradients), but the
serving stack — non-uniform schedules, shape-bucketed batching, the AOT
executable cache, the δ-adaptive m-ladder — is algorithm-agnostic. This
registry factors the one method-specific piece, the per-chunk *accumulator*,
out of ``repro.core.ig.attribute`` so every IG variant that rides the same
interpolate→grad→accumulate loop inherits the whole stack for free.

A ``MethodSpec`` mirrors ``schedule.ScheduleFamily``: a name, a per-chunk
accumulator with ONE uniform signature, a finalizer, and (optionally) a
path-ensemble expansion. The registered methods:

  ig            — vanilla Riemann IG: acc += Σ_k w_k g_k; φ = (x − x′) ⊙ acc.
  idgi          — IDGI (Yang et al., CVPR 2023): each step contributes its
                  f-difference split along the gradient direction,
                  φ_k = (g_k ⊙ g_k) / ⟨g_k, g_k⟩ · d_k, which discards the
                  gradient component orthogonal to the function change
                  (explanation noise). The quadrature-compatible form used
                  here takes the tangent f-difference d_k = ⟨g_k, x − x′⟩ w_k
                  (the secant f(x_{k+1}) − f(x_k) of the original is its
                  first-order approximation); every step stays additive and
                  weight-proportional, so IDGI rides chunked scans, nested
                  refinement, and bit-identical adaptive resume unchanged.
  noise_tunnel  — SmoothGrad-style expectation over noisy copies of x
                  (Goh et al., 2021 SmoothTaylor regime): expand each example
                  to n_samples noisy rows, run vanilla accumulation, average.
  expected_grad — expected gradients over a baseline distribution
                  (``core/baselines``): expand each example with baselines
                  jittered by ``baselines.gaussian``, average.

Forward-only class (``forward_only=True`` — ``repro.core.perturb``): a
SECOND executable class that never differentiates the model. The
accumulator consumes ``f(perturbed)`` VALUES over a batch of binary
position masks instead of gradients, so these methods explain models with
no usable VJP (quantized / remote / black-box):

  occlusion     — deterministic sliding-window masks; score = mean f-drop
                  over the windows occluding a position.
  rise          — random Bernoulli keep-masks (Petsiuk et al., 2018);
                  score = E[f | kept] − E[f].
  lime          — binary masks over contiguous position groups, weighted
                  ridge regression of f on the group indicators (the WLS
                  solve is the ``kernels/lstsq`` Pallas kernel on the
                  serving path).

For forward-only specs ``accum_fn``/``finalize`` follow the perturbation
contract (``update(stats, vals, z, *, ctx)`` / ``finalize(stats, *, ctx)``
— see ``perturb._FWD``), ``accum`` still names the executable class the
engine keys by (each method compiles its own), and ``n_masks`` is the
default mask budget P (the forward analogue of m).

Hop-executable compatibility (DESIGN.md §7/§8): the serving engine keys its
stage-2 executables by ``MethodSpec.accum`` — the accumulator CLASS — not by
method name. ``ig``/``noise_tunnel``/``expected_grad`` all accumulate with
``riemann`` (expansion happens outside the compiled program, at batch
construction), so they share one warmed set of hop executables; ``idgi``
compiles its own. Either way the shape set stays closed: zero steady-state
recompiles.

State pytree contract: an accumulator must be (a) additive over schedule
nodes and (b) homogeneous degree-1 in the weights, so that
``ig.IGState.acc`` scaled by the exact power-of-two ``state_scale`` resumes
bit-identically after ``schedule.refine_nested`` (both registered
accumulators satisfy this; see DESIGN.md §8 for the obligations a new
method must meet).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.baselines import gaussian


def expand_mask(mask: jax.Array, ndim: int, *, lead: int = 1) -> jax.Array:
    """(B, *L) -> (B, 1×(lead-1), *L, 1, ...) broadcastable to rank ``ndim``."""
    shape = mask.shape[:1] + (1,) * (lead - 1) + mask.shape[1:]
    return mask.reshape(shape + (1,) * (ndim - len(shape))).astype(jnp.float32)


# --------------------------------------------------------------- accumulators
#
# Uniform signature (the MethodSpec contract, DESIGN.md §8):
#   accum(acc (B, *F) f32, grads (B, c, *F), weights (B, c),
#         *, diff (B, *F), mask optional (B, *L)) -> (B, *F) f32
# ``diff`` is the masked path direction x − x′ (ignored by methods that do
# not need it). Pallas drop-ins live in ``repro.kernels.ig_accum.ops``.


def riemann_accum(
    acc: jax.Array,
    grads: jax.Array,
    weights: jax.Array,
    *,
    diff: Optional[jax.Array] = None,
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """acc += Σ_k w_k g_k — the vanilla IG path-integral estimate."""
    if mask is not None:
        grads = grads * expand_mask(mask, grads.ndim, lead=2)
    wexp = weights.reshape(weights.shape + (1,) * (grads.ndim - 2))
    return acc + jnp.sum(grads.astype(jnp.float32) * wexp, axis=1)


def idgi_accum(
    acc: jax.Array,
    grads: jax.Array,
    weights: jax.Array,
    *,
    diff: jax.Array,
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """acc += Σ_k c_k (g_k ⊙ g_k), c_k = w_k ⟨g_k, x − x′⟩ / ⟨g_k, g_k⟩.

    Each step distributes its (tangent) f-difference d_k = ⟨g_k, x − x′⟩ w_k
    over features ∝ g_k², i.e. along the gradient direction only — the IDGI
    noise-removal step. ⟨g, g⟩ == 0 (flat region) contributes exactly zero.
    Homogeneous degree-1 in ``weights`` ⇒ the resumable-state contract holds.
    """
    if mask is not None:
        grads = grads * expand_mask(mask, grads.ndim, lead=2)
    B, c = grads.shape[:2]
    g = grads.astype(jnp.float32).reshape(B, c, -1)
    d = diff.astype(jnp.float32).reshape(B, 1, -1)
    s = jnp.sum(g * g, axis=-1)  # (B, c)  ⟨g, g⟩
    p = jnp.sum(g * d, axis=-1)  # (B, c)  ⟨g, x − x′⟩
    coeff = weights.astype(jnp.float32) * p * jnp.where(s > 0.0, 1.0 / jnp.where(s > 0.0, s, 1.0), 0.0)
    return acc + jnp.sum((g * g) * coeff[..., None], axis=1).reshape(acc.shape)


# ----------------------------------------------------------------- finalizers


def riemann_finalize(
    acc: jax.Array, x: jax.Array, baseline: jax.Array,
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """φ = (x − x′) ⊙ acc, exactly zero at masked positions."""
    attr = (x - baseline).astype(jnp.float32) * acc
    if mask is not None:
        attr = attr * expand_mask(mask, attr.ndim)
    return attr


def idgi_finalize(
    acc: jax.Array, x: jax.Array, baseline: jax.Array,
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """IDGI's direction factor is inside the accumulator: φ = acc."""
    if mask is not None:
        acc = acc * expand_mask(mask, acc.ndim)
    return acc


# ------------------------------------------------------ path-ensemble expand
#
# Expansion signature: (x, baseline, key, n, sigma) -> (x', baseline') with
# leading axis B·n, samples of example b contiguous at rows [b·n, (b+1)·n).
# Expansion runs OUTSIDE the compiled stage-2 program (batch construction),
# which is what keeps the expanded methods on the riemann hop executables.


def noise_expand(
    x: jax.Array, baseline: jax.Array, key: jax.Array, n: int, sigma: float
) -> tuple[jax.Array, jax.Array]:
    """Noise-tunnel sampling: noisy copies of x, shared baseline."""
    from repro.core.smooth import noise_samples

    return noise_samples(x, key, n, sigma), jnp.repeat(baseline, n, axis=0)


def baseline_expand(
    x: jax.Array, baseline: jax.Array, key: jax.Array, n: int, sigma: float
) -> tuple[jax.Array, jax.Array]:
    """Expected-gradients sampling: shared x, baselines drawn from the
    ``core.baselines`` gaussian distribution centred on the nominal x′."""
    br = jnp.repeat(baseline, n, axis=0)
    return jnp.repeat(x, n, axis=0), br + gaussian(br, key, sigma)


# ------------------------------------------------------------------ registry


@dataclass(frozen=True)
class MethodSpec:
    """One attribution method = accumulator + finalizer (+ expansion).

    ``accum`` names the accumulator CLASS ("riemann" | "idgi") — the engine
    keys hop executables by it, so methods sharing an accumulator share one
    warmed executable set. ``expand`` (with ``n_samples``/``sigma_default``)
    turns the method into an expectation over a path ensemble; the per-row
    computation is then EXACTLY the riemann method, and reduction (mean over
    each example's contiguous sample rows) happens after stage 2.

    ``grad_linear`` declares the accumulator LINEAR in the per-step
    gradients (riemann: acc += Σ w_k g_k). The fused stage 2
    (``ig.attribute(fused=True)``, DESIGN.md §10) exploits it: the whole
    chunk's weighted gradient sum is one (B, *F) VJP cotangent — the
    per-step (B, chunk, *F) gradient batch never exists. Quadratic
    accumulators (idgi: Σ c_k g_k² with c_k itself ⟨g,·⟩-dependent) must
    keep per-step gradients; they set ``grad_linear=False`` and the fused
    path only composes the interpolation into the differentiated program.
    """

    name: str
    accum: str  # accumulator class — hop-executable compatibility key
    accum_fn: Callable
    finalize: Callable
    expand: Optional[Callable] = None
    n_samples: int = 1
    sigma_default: float = 0.1
    grad_linear: bool = True  # accumulator linear in per-step grads (§10)
    # forward-only perturbation class (repro.core.perturb): the accumulator
    # consumes f(perturbed) VALUES over n_masks binary masks, never a VJP —
    # ig.attribute refuses these specs; they serve through the engine's
    # forward-evaluator executables (or perturb.attribute_from_masks)
    forward_only: bool = False
    n_masks: int = 0  # default mask budget P (forward-only methods)
    description: str = ""

    def row_spec(self) -> "MethodSpec":
        """The per-row spec with expansion stripped — what the serving engine
        compiles (it expands requests itself at plan/bucket time)."""
        if self.expand is None:
            return self
        return replace(self, expand=None, n_samples=1)


METHODS: dict[str, MethodSpec] = {
    "ig": MethodSpec(
        "ig", "riemann", riemann_accum, riemann_finalize,
        description="vanilla integrated gradients (weighted Riemann sum)",
    ),
    "idgi": MethodSpec(
        "idgi", "idgi", idgi_accum, idgi_finalize, grad_linear=False,
        description="IDGI: per-step f-difference split along the gradient direction",
    ),
    "noise_tunnel": MethodSpec(
        "noise_tunnel", "riemann", riemann_accum, riemann_finalize,
        expand=noise_expand, n_samples=4, sigma_default=0.1,
        description="SmoothGrad-style expectation of IG over noisy copies of x",
    ),
    "expected_grad": MethodSpec(
        "expected_grad", "riemann", riemann_accum, riemann_finalize,
        expand=baseline_expand, n_samples=4, sigma_default=0.1,
        description="expected gradients over a gaussian baseline distribution",
    ),
}


def _register_forward_only() -> None:
    # deferred import: perturb needs nothing from this module at import time,
    # but keeping the registration lazy-shaped documents the one-way edge
    from repro.core import perturb

    for name, n_masks, desc in (
        ("occlusion", 64, "sliding-window occlusion (mean f-drop per position)"),
        ("rise", 64, "RISE: random binary keep-masks, E[f | kept] − E[f]"),
        ("lime", 64, "LIME: weighted ridge regression on position-group masks"),
    ):
        update, finalize = perturb._FWD[name][1:]
        METHODS[name] = MethodSpec(
            name, name, update, finalize, forward_only=True,
            grad_linear=False, n_masks=n_masks, description=desc,
        )


_register_forward_only()


def get(name: str) -> MethodSpec:
    """Look up a registered ``MethodSpec`` by name (specs pass through).

        >>> sorted(METHODS)
        ['expected_grad', 'idgi', 'ig', 'lime', 'noise_tunnel', 'occlusion', 'rise']
        >>> get("noise_tunnel").accum  # shares ig's executables (§8)
        'riemann'
        >>> get("rise").forward_only  # perturbation class: no VJP needed
        True
        >>> get("nope")
        Traceback (most recent call last):
            ...
        ValueError: unknown attribution method 'nope'; known: ['expected_grad', 'idgi', 'ig', 'lime', 'noise_tunnel', 'occlusion', 'rise']
    """
    if isinstance(name, MethodSpec):
        return name
    if name not in METHODS:
        raise ValueError(
            f"unknown attribution method {name!r}; known: {sorted(METHODS)}"
        )
    return METHODS[name]
