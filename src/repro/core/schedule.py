"""Interpolation schedules — the paper's contribution lives here.

A *schedule* is a pair ``(alphas[m], weights[m])`` approximating
``∫_0^1 g(α) dα ≈ Σ_k w_k g(α_k)``. Schedules are **data, not shapes**: the
same compiled stage-2 executable serves any allocation (the TPU-native
re-design of the paper's per-image dynamic step distribution; DESIGN.md §2).

Schedules:
  uniform        — baseline IG (left/right/midpoint/trapezoid Riemann)
  paper          — faithful NUIG: n_int equal intervals, integer step counts
                   ∝ sqrt(|Δf|) (largest-remainder rounding), uniform-in-interval
  warp           — beyond-paper: continuous inverse-CDF limit of `paper`
  gauss          — beyond-paper: Gauss–Legendre nodes in the warped domain
All functions are jit-compatible and batched over examples where noted.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class Schedule(NamedTuple):
    alphas: jax.Array  # (m,) or (B, m) — path positions in [0, 1]
    weights: jax.Array  # same shape — Riemann/quadrature weights, sum == 1


# ----------------------------------------------------------------- uniform


def uniform(m: int, rule: str = "midpoint") -> Schedule:
    """Baseline IG discretization (paper Eq. 2 uses the 'right'/'left' form).

    Args:
        m: node count; rule: "midpoint" | "left" | "right" | "trapezoid".

    Returns a ``Schedule`` with Σw == 1 for every rule and m:

        >>> s = uniform(4)
        >>> [round(float(a), 3) for a in s.alphas]
        [0.125, 0.375, 0.625, 0.875]
        >>> float(s.weights.sum())
        1.0
    """
    if rule == "midpoint":
        a = (jnp.arange(m) + 0.5) / m
        w = jnp.full((m,), 1.0 / m)
    elif rule == "left":
        a = jnp.arange(m) / m
        w = jnp.full((m,), 1.0 / m)
    elif rule == "right":
        a = jnp.arange(1, m + 1) / m
        w = jnp.full((m,), 1.0 / m)
    elif rule == "trapezoid":
        if m == 1:
            # Degenerate trapezoid: a single node IS both endpoints, and
            # halving "each" endpoint would hit the same slot twice (the
            # historical Σw == 0.25 bug). One node integrating [0, 1] must
            # carry the full measure; the midpoint is its unbiased position.
            a = jnp.asarray([0.5])
            w = jnp.asarray([1.0])
        else:
            a = jnp.arange(m) / (m - 1)
            w = jnp.full((m,), 1.0 / (m - 1))
            w = w.at[0].mul(0.5).at[-1].mul(0.5)
    else:
        raise ValueError(f"unknown rule {rule!r}")
    return Schedule(a.astype(jnp.float32), w.astype(jnp.float32))


# ------------------------------------------------- paper step allocation


def normalized_deltas(boundary_vals: jax.Array, power: float = 0.5) -> jax.Array:
    """|Δf| per interval -> importance density, normalized to sum 1.

    boundary_vals: (..., n_int+1) stage-1 probe outputs f(x(α_i)).
    ``power=0.5`` is the paper's sqrt attenuation (§III Algorithm).
    """
    d = jnp.abs(jnp.diff(boundary_vals, axis=-1))  # (..., n_int)
    d = d ** power
    # flat-region fallback: if all deltas vanish, fall back to uniform
    s = d.sum(-1, keepdims=True)
    n = d.shape[-1]
    return jnp.where(s > 1e-12, d / jnp.maximum(s, 1e-12), 1.0 / n)


def allocate_steps(importance: jax.Array, m: int, min_steps: int = 1) -> jax.Array:
    """Integer largest-remainder allocation of m steps ∝ importance.

    importance: (..., n_int) normalized;  returns int32 (..., n_int), sum == m.
    ``min_steps`` guards the paper's n_int>8 pathology (starved intervals).
    """
    n = importance.shape[-1]
    assert m >= n * min_steps, (m, n, min_steps)
    budget = m - n * min_steps
    q = importance * budget
    base = jnp.floor(q).astype(jnp.int32)
    rem = q - base
    short = budget - base.sum(-1, keepdims=True)  # how many +1s to hand out
    # rank remainders descending; slots with rank < short get +1
    order = jnp.argsort(-rem, axis=-1)
    rank = jnp.argsort(order, axis=-1)
    bonus = (rank < short).astype(jnp.int32)
    return base + bonus + min_steps


def from_allocation(
    alloc: jax.Array, m: int, lo: float = 0.0, hi: float = 1.0, rule: str = "midpoint"
) -> Schedule:
    """Uniform-in-interval schedule from integer per-interval step counts.

    alloc: (..., n_int) int32 summing to m. Fully static-shape: step k is
    mapped to its interval by a searchsorted-style comparison — the gather
    trick that makes the paper's dynamic allocation compile once on TPU.
    """
    n = alloc.shape[-1]
    csum = jnp.cumsum(alloc, axis=-1)  # (..., n)
    k = jnp.arange(m)  # (m,)
    # interval of step k: first i with csum[i] > k
    iv = (k[..., None, :] >= csum[..., :, None]).sum(-2)  # (..., m) int
    starts = csum - alloc  # first step index of each interval
    take = lambda t: jnp.take_along_axis(t, iv, axis=-1)
    m_i = take(alloc)  # steps in k's interval
    r = k - take(starts)  # rank of k within its interval
    width = (hi - lo) / n
    off = {"midpoint": 0.5, "left": 0.0, "right": 1.0}[rule]
    a = lo + (iv + (r + off) / m_i) * width
    w = width / m_i
    return Schedule(a.astype(jnp.float32), w.astype(jnp.float32))


def paper(
    boundary_vals: jax.Array,
    m: int,
    *,
    power: float = 0.5,
    min_steps: int = 1,
    rule: str = "midpoint",
) -> Schedule:
    """Faithful NUIG schedule from stage-1 probe values (paper §III)."""
    imp = normalized_deltas(boundary_vals, power)
    alloc = allocate_steps(imp, m, min_steps)
    return from_allocation(alloc, m, rule=rule)


# ----------------------------------------------------------- warp (beyond)


def warp(boundary_vals: jax.Array, m: int, *, power: float = 0.5) -> Schedule:
    """Continuous limit of `paper`: α_k = G⁻¹((k+½)/m) with piecewise-linear
    CDF G whose density on interval i is ∝ |Δf_i|^power.

    Removes integer-rounding pathologies (the paper's n_int>8 regression) and
    keeps weights piecewise-constant-in-interval — so it IS the paper's scheme
    with fractional step counts.

    A density floor (blend with uniform, λ = n/m) is the continuous analogue
    of the paper's ``min_steps=1``: it guarantees every interval's CDF span
    is ≥ 1/m, hence receives ≥ 1 of the m grid points, hence Σw == 1 exactly
    (a zero-density interval would otherwise be silently dropped from the
    quadrature — unbounded error if f moves there).
    """
    imp = normalized_deltas(boundary_vals, power)  # (..., n)
    n = imp.shape[-1]
    lam = min(1.0, n / m)
    imp = (1.0 - lam) * imp + lam / n
    cdf = jnp.cumsum(imp, axis=-1)  # G at right boundaries
    t = (jnp.arange(m) + 0.5) / m  # (m,)
    iv = (t[..., None, :] >= cdf[..., :, None]).sum(-2)  # (..., m)
    iv = jnp.clip(iv, 0, n - 1)
    take = lambda v: jnp.take_along_axis(v, iv, axis=-1)
    left_cdf = take(cdf - imp)
    dens = take(imp)  # mass of k's interval
    frac = (t - left_cdf) / jnp.maximum(dens, 1e-12)
    a = (iv + frac) / n  # sorted inverse-CDF nodes
    # Voronoi-cell weights: w_k = (midpoint to next node) − (midpoint to
    # previous node), with 0/1 at the ends. Telescopes to Σw == 1 exactly and
    # is second-order on smooth integrands — per-interval-uniform weights at
    # non-midpoint nodes would degrade to O(1/m).
    mid = 0.5 * (a[..., 1:] + a[..., :-1])
    lo = jnp.concatenate([jnp.zeros_like(a[..., :1]), mid], axis=-1)
    hi = jnp.concatenate([mid, jnp.ones_like(a[..., :1])], axis=-1)
    w = hi - lo
    return Schedule(a.astype(jnp.float32), w.astype(jnp.float32))


# ---------------------------------------------------------- gauss (beyond)


def _gauss_legendre(m: int) -> tuple[np.ndarray, np.ndarray]:
    x, w = np.polynomial.legendre.leggauss(m)  # nodes on [-1,1]
    return (x + 1.0) / 2.0, w / 2.0  # map to [0,1]


def gauss(
    boundary_vals: jax.Array, m: int, *, power: float = 0.5, order: int = 8
) -> Schedule:
    """Composite Gauss–Legendre in the importance-allocated intervals.

    m steps = (m/order) Gauss cells of fixed ``order``; cells are distributed
    across intervals ∝ |Δf|^power (largest remainder, ≥1), sub-cells are equal
    within an interval. A *global* Gauss rule would lose its order at the
    piecewise-linear warp kinks; the composite rule is exact per smooth piece
    (degree 2·order−1). Beyond-paper.
    """
    imp = normalized_deltas(boundary_vals, power)
    n = imp.shape[-1]
    # shrink order if needed so every interval can get >= 1 cell
    order = min(order, m // n)
    while m % order:
        order -= 1
    assert order >= 1, (m, n)
    cells = m // order
    nodes, gw = _gauss_legendre(order)  # static, tiny
    alloc = allocate_steps(imp, cells, min_steps=1)  # cells per interval
    csum = jnp.cumsum(alloc, axis=-1)
    k = jnp.arange(m)
    cell = k // order
    node = k % order
    iv = (cell[..., None, :] >= csum[..., :, None]).sum(-2)  # (..., m)
    starts = csum - alloc
    take = lambda t_: jnp.take_along_axis(t_, iv, axis=-1)
    cells_i = take(alloc)
    r = cell - take(starts)  # sub-cell rank within interval
    width = 1.0 / n
    sub = width / cells_i
    a = (iv * width) + (r + jnp.asarray(nodes, jnp.float32)[node]) * sub
    w = jnp.asarray(gw, jnp.float32)[node] * sub
    return Schedule(a.astype(jnp.float32), w.astype(jnp.float32))


# ------------------------------------------- refined boundaries (beyond)


def from_boundaries(
    bounds: jax.Array, vals: jax.Array, m: int, *, power: float = 0.5
) -> Schedule:
    """Schedule over *non-uniform* interval boundaries (secant-refine stage 1).

    bounds/vals: (..., K) sorted probe positions and f values; zero-width
    (padding) intervals receive zero importance and zero steps.
    """
    widths = jnp.diff(bounds, axis=-1)  # (..., n)
    d = jnp.abs(jnp.diff(vals, axis=-1)) ** power
    d = jnp.where(widths > 1e-9, d, 0.0)
    s = d.sum(-1, keepdims=True)
    live = (widths > 1e-9).astype(jnp.float32)
    imp = jnp.where(s > 1e-12, d / jnp.maximum(s, 1e-12), live / jnp.maximum(live.sum(-1, keepdims=True), 1))
    alloc = allocate_steps(imp, m, min_steps=0)
    csum = jnp.cumsum(alloc, axis=-1)
    k = jnp.arange(m)
    iv = (k[..., None, :] >= csum[..., :, None]).sum(-2)
    starts = csum - alloc
    take = lambda t: jnp.take_along_axis(t, iv, axis=-1)
    m_i = jnp.maximum(take(alloc), 1)
    r = k - take(starts)
    left = take(bounds[..., :-1])
    w_int = take(widths)
    a = left + (r + 0.5) / m_i * w_int
    w = w_int / m_i
    # With min_steps=0 a live interval can receive zero nodes; its width
    # would then be silently dropped from the quadrature (Σw < 1 — a
    # completeness gap that no m can close). Renormalize: a no-op when every
    # live interval got a node, and a uniform rescale (keeping nodes at
    # their sub-interval midpoints) in the starved m < n_live corner.
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-12)
    return Schedule(a.astype(jnp.float32), w.astype(jnp.float32))


# ------------------------------------------- nested refinement (adaptive)


def refine_nested(sched: Schedule) -> Schedule:
    """Double a schedule's node count while keeping every old node — the
    escalation step of adaptive iso-convergence serving (DESIGN.md §7).

    Each node owns a *cell*: sort nodes by α and partition [0, 1] by the
    cumulative weights (for midpoint/paper/warp the weights ARE the path-cell
    widths, so these are the true cells). Split every cell at its center and
    drop one child node at the center of the half the old node does not
    occupy. Old weights halve EXACTLY (power-of-two scaling is exact in
    IEEE-754 away from subnormals), which is the property that makes a
    resumed accumulator bit-identical to a fresh run over the refined
    schedule: ``ig.attribute(state=prior, state_scale=0.5)`` over the new
    nodes equals one fixed-m run over the whole refined schedule.

    Storage order is load-bearing: the refined schedule is
    ``[old nodes (original order), child nodes (parent order)]`` — NOT
    sorted — so a chunked scan over the refined schedule visits exactly the
    prefix an earlier rung already accumulated. Quadrature does not care
    about node order; resumability does.

    Works batched on (..., m) schedules; Σw == 1 is preserved exactly:

        >>> s = uniform(4)
        >>> r = refine_nested(s)
        >>> r.alphas.shape, bool((r.alphas[:4] == s.alphas).all())
        ((8,), True)
        >>> bool((r.weights[:4] == 0.5 * s.weights).all())
        True
    """
    a, w = sched.alphas, sched.weights
    order = jnp.argsort(a, axis=-1)  # stable (jnp default)
    inv = jnp.argsort(order, axis=-1)
    take = lambda t, i: jnp.take_along_axis(t, i, axis=-1)
    a_s, w_s = take(a, order), take(w, order)
    right = jnp.cumsum(w_s, axis=-1)
    left = right - w_s
    center = left + 0.5 * w_s
    # Child placement. Off-center parents (left/right rules, warp tails):
    # reflect through the cell center — the pair's first moment matches the
    # cell's exactly, so the composite rule stays second order. Near-centered
    # parents (midpoint-style schedules) would reflect onto themselves
    # (duplicate node = wasted gradient), so treat adjacent cells as PAIRS:
    # the even cell's child goes β·w left of its center, the odd cell's
    # β·w right. Any symmetric offset matches the pair's first moment;
    # β = (√(5/3) − 1)/2 also matches its second moment (solve
    # d² − wd − w²/6 = 0 for adjacent equal-width cells), giving third-order
    # pair error — measured ~10-40× lower quadrature error than naive
    # half-cell placement, and within ~10× of a fresh midpoint grid.
    beta = jnp.float32((np.sqrt(5.0 / 3.0) - 1.0) / 2.0)
    off = a_s - center
    near = jnp.abs(off) < 0.25 * w_s
    parity = (jnp.arange(a.shape[-1]) % 2) == 0
    pair_child = jnp.where(parity, center - beta * w_s, center + beta * w_s)
    child_s = jnp.where(near, pair_child, 2.0 * center - a_s)
    child = take(child_s, inv)  # parent-aligned storage order
    a2 = jnp.concatenate([a, child], axis=-1)
    w2 = jnp.concatenate([0.5 * w, 0.5 * w], axis=-1)
    return Schedule(a2.astype(jnp.float32), w2.astype(jnp.float32))


def m_ladder(m: int, m_max: int) -> tuple[int, ...]:
    """Escalation rungs m, 2m, 4m, ... up to (at most) m_max.

        >>> m_ladder(16, 64)
        (16, 32, 64)
        >>> m_ladder(8, 100)  # never overshoots m_max
        (8, 16, 32, 64)
    """
    assert m >= 1 and m_max >= m, (m, m_max)
    out = [m]
    while out[-1] * 2 <= m_max:
        out.append(out[-1] * 2)
    return tuple(out)


# ------------------------------------------------------------------ registry


class Probe(NamedTuple):
    """Stage-1 output, schedule-family agnostic.

    bounds: (..., K) sorted probe positions in [0, 1];
    vals:   (..., K) f at those positions.
    For the plain boundary probe the bounds are the uniform grid; the
    secant-refine probe returns non-uniform (possibly duplicated) bounds.
    """

    bounds: jax.Array
    vals: jax.Array


@dataclass(frozen=True)
class ScheduleFamily:
    """One schedule family = a probe spec + a uniform-signature builder.

    ``probe`` names the stage-1 pass the caller must run ("none" |
    "boundary" | "refine" — see ``repro.core.probes.run_probe``); ``build``
    maps its result to a Schedule. Every family rides the same call shape,
    so engines dispatch by name with no per-method special cases
    (``refine`` included — DESIGN.md §2).

    ``refine`` is the family's nested-refinement step for adaptive serving
    (DESIGN.md §7): ``refine(sched) -> sched'`` doubles the node count while
    reusing the prior grid, so ladder escalation never discards work. The
    generic cell-splitting ``refine_nested`` is correct for every family
    (Σw == 1; old nodes kept with exactly-halved weights); families with a
    sharper nested rule can override it.
    """

    name: str
    probe: str  # "none" | "boundary" | "refine"
    build: Callable[..., Schedule]
    refine: Callable[[Schedule], Schedule] = refine_nested


def _build_uniform(
    probe: Optional[Probe], m: int, *, power: float, min_steps: int, rule: str
) -> Schedule:
    return uniform(m, rule)


def _build_paper(
    probe: Optional[Probe], m: int, *, power: float, min_steps: int, rule: str
) -> Schedule:
    return paper(probe.vals, m, power=power, min_steps=min_steps, rule=rule)


def _build_warp(
    probe: Optional[Probe], m: int, *, power: float, min_steps: int, rule: str
) -> Schedule:
    return warp(probe.vals, m, power=power)


def _build_gauss(
    probe: Optional[Probe], m: int, *, power: float, min_steps: int, rule: str
) -> Schedule:
    return gauss(probe.vals, m, power=power)


def _build_refine(
    probe: Optional[Probe], m: int, *, power: float, min_steps: int, rule: str
) -> Schedule:
    return from_boundaries(probe.bounds, probe.vals, m, power=power)


SCHEDULES: dict[str, ScheduleFamily] = {
    "uniform": ScheduleFamily("uniform", "none", _build_uniform),
    "paper": ScheduleFamily("paper", "boundary", _build_paper),
    "warp": ScheduleFamily("warp", "boundary", _build_warp),
    "gauss": ScheduleFamily("gauss", "boundary", _build_gauss),
    "refine": ScheduleFamily("refine", "refine", _build_refine),
}


def family(name: str) -> ScheduleFamily:
    """Look up a registered ``ScheduleFamily`` by name.

        >>> sorted(SCHEDULES)
        ['gauss', 'paper', 'refine', 'uniform', 'warp']
        >>> family("paper").probe
        'boundary'
    """
    if name not in SCHEDULES:
        raise ValueError(f"unknown method {name!r}; known: {sorted(SCHEDULES)}")
    return SCHEDULES[name]
