from repro.models.registry import Model, input_specs

__all__ = ["Model", "input_specs"]
