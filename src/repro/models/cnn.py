"""Inception-style small convnet — the paper's vision reproduction model.

The paper runs IG on InceptionV3/ImageNet; this is the same *shape* of model
(conv stem -> mixed blocks with parallel 1x1/3x3/5x5/pool towers -> GAP head)
at CPU scale. IG interpolates raw pixels, exactly as in the paper.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.paper_cnn import CnnConfig
from repro.models import common
from repro.models.common import ParamDef


def _conv_def(cin: int, cout: int, k: int) -> ParamDef:
    return ParamDef((k, k, cin, cout), (None, None, None, None))


def param_defs(cfg: CnnConfig) -> dict:
    defs: dict[str, Any] = {
        "stem": {"w": _conv_def(cfg.channels, cfg.stem_features, 3),
                 "b": ParamDef((cfg.stem_features,), (None,), init="zeros")}
    }
    cin = cfg.stem_features
    for i, (f1, f3, f5, fp) in enumerate(cfg.blocks):
        defs[f"block{i}"] = {
            "t1": _conv_def(cin, f1, 1),
            "t3a": _conv_def(cin, f3 // 2, 1),
            "t3b": _conv_def(f3 // 2, f3, 3),
            "t5a": _conv_def(cin, f5 // 2, 1),
            "t5b": _conv_def(f5 // 2, f5, 5),
            "tp": _conv_def(cin, fp, 1),
        }
        cin = f1 + f3 + f5 + fp
    defs["head"] = {
        "w": ParamDef((cin, cfg.num_classes), (None, None)),
        "b": ParamDef((cfg.num_classes,), (None,), init="zeros"),
    }
    return defs


def init(cfg: CnnConfig, key: jax.Array) -> Any:
    return common.init_params(key, param_defs(cfg))


def _conv(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _pool(x: jax.Array, k: int = 3, stride: int = 1) -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, stride, stride, 1), "SAME"
    )


def forward(cfg: CnnConfig, params: Any, images: jax.Array) -> jax.Array:
    """images: (B, H, W, C) -> logits (B, num_classes)."""
    x = jax.nn.relu(_conv(images, params["stem"]["w"], 2) + params["stem"]["b"])
    for i in range(len(cfg.blocks)):
        p = params[f"block{i}"]
        t1 = jax.nn.relu(_conv(x, p["t1"]))
        t3 = jax.nn.relu(_conv(jax.nn.relu(_conv(x, p["t3a"])), p["t3b"]))
        t5 = jax.nn.relu(_conv(jax.nn.relu(_conv(x, p["t5a"])), p["t5b"]))
        tp = jax.nn.relu(_conv(_pool(x), p["tp"]))
        x = jnp.concatenate([t1, t3, t5, tp], axis=-1)
        x = _pool(x, 3, 2)
    x = x.mean(axis=(1, 2))  # GAP
    return x @ params["head"]["w"] + params["head"]["b"]


def prob_fn(cfg: CnnConfig, params: Any, images: jax.Array, target: jax.Array) -> jax.Array:
    """Target-class probability — the paper's IG output function f."""
    p = jax.nn.softmax(forward(cfg, params, images), axis=-1)
    return jnp.take_along_axis(p, target[:, None], axis=-1)[:, 0]
