"""Core layers: RMSNorm, RoPE, SwiGLU MLP, embeddings, chunked loss."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ParamDef
from repro.models import common as _common
from repro.sharding.context import constrain

# --------------------------------------------------------------------- norm


def rmsnorm_def(d: int) -> dict:
    return {"scale": ParamDef((d,), (None,), init="ones")}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------- rope


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    angles = angles[..., :, None, :]  # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- mlp


def mlp_def(d: int, f: int) -> dict:
    return {
        "wi_gate": ParamDef((d, f), ("embed", "mlp")),
        "wi_up": ParamDef((d, f), ("embed", "mlp")),
        "wo": ParamDef((f, d), ("mlp", "embed")),
    }


def mlp(p: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    g = jnp.einsum("...d,df->...f", x, p["wi_gate"].astype(dt))
    u = jnp.einsum("...d,df->...f", x, p["wi_up"].astype(dt))
    h = jax.nn.silu(g) * u
    h = constrain(h, "batch", "seq", "model")  # keep hidden TP-sharded
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(dt))


# --------------------------------------------------------------- embeddings


def embed_def(cfg: ArchConfig) -> dict:
    d = {"embedding": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), init="embed")}
    if not cfg.tie_embeddings:
        d["unembed"] = ParamDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    if cfg.frontend:
        d["frontend_proj"] = ParamDef(
            ((cfg.frontend_dim or cfg.d_model), cfg.d_model), ("frontend", "embed")
        )
    return d


def embed(p: dict, tokens: jax.Array, cfg: ArchConfig, dtype) -> jax.Array:
    e = jnp.take(p["embedding"], tokens, axis=0).astype(dtype)
    if cfg.name.startswith("gemma"):
        e = e * jnp.asarray(cfg.d_model**0.5, dtype)
    return e


def project_frontend(p: dict, feats: jax.Array, dtype) -> jax.Array:
    """Project stub frontend embeddings (audio frames / vision patches)."""
    return jnp.einsum("...f,fd->...d", feats.astype(dtype), p["frontend_proj"].astype(dtype))


def unembed(p: dict, h: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        w = p["embedding"].astype(h.dtype)  # (V, d)
        return jnp.einsum("...d,vd->...v", h, w)
    return jnp.einsum("...d,dv->...v", h, p["unembed"].astype(h.dtype))


# ----------------------------------------------------------- chunked loss


def softmax_xent_chunked(
    p_embed: dict,
    h: jax.Array,  # (B, S, d) final hidden states
    labels: jax.Array,  # (B, S) int32
    cfg: ArchConfig,
    chunk: int = 512,
) -> jax.Array:
    """Cross-entropy without materializing full (B, S, V) logits.

    Scans over sequence chunks; each chunk's logits live only inside the scan
    body (remat-friendly; vocab stays sharded on the 'model' mesh axis).
    """
    B, S, _ = h.shape
    if _common.COSTING:
        chunk = S  # costing mode: no scan, true flop count
    chunk = min(chunk, S)
    n = S // chunk
    hs = h[:, : n * chunk].reshape(B, n, chunk, -1).swapaxes(0, 1)
    ls = labels[:, : n * chunk].reshape(B, n, chunk).swapaxes(0, 1)

    def body(tot, xs):
        hc, lc = xs
        logits = unembed(p_embed, hc, cfg).astype(jnp.float32)  # (B, c, V)
        logits = constrain(logits, "batch", None, "model")  # vocab stays TP
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(logz - gold), None

    total, _ = _common.scan_or_unroll(body, jnp.zeros((), jnp.float32), (hs, ls))
    rem = S - n * chunk
    if rem:
        logits = unembed(p_embed, h[:, n * chunk :], cfg).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, n * chunk :, None], axis=-1)[..., 0]
        total = total + jnp.sum(logz - gold)
    return total / (B * S)
