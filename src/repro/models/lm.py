"""Unified language model (dense / MoE / SSM / hybrid / enc-dec / VLM).

Layers are grouped into *periods* (one repetition of ``cfg.pattern``) and the
period stack is driven by ``lax.scan`` so the lowered HLO stays small for
62–94-layer configs; trailing remainder layers run unscanned.

Entry points (all pure functions of (cfg, params, ...)):
  param_defs / init / abstract_params        — parameters
  forward_hidden(tokens|embeds) -> (h, aux)  — backbone
  loss                                        — chunked softmax xent
  prefill -> (logits_last, cache)             — build decode cache
  decode_step(cache, token) -> (logits, cache)
  embed_inputs / hidden_from_embeds           — embedding-space hooks for IG
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.models import blocks
from repro.models import common
from repro.models.common import ParamDef, scan_or_unroll, stack_defs
from repro.sharding.context import constrain
from repro.models.layers import (
    embed,
    embed_def,
    project_frontend,
    rmsnorm,
    rmsnorm_def,
    softmax_xent_chunked,
    unembed,
)

# ---------------------------------------------------------------- parameters


def param_defs(cfg: ArchConfig) -> dict:
    cross = cfg.is_encdec
    defs: dict[str, Any] = {
        "embed": embed_def(cfg),
        "final_norm": rmsnorm_def(cfg.d_model),
        "layers": tuple(
            stack_defs(blocks.layer_def(cfg, spec, cross=cross), cfg.num_periods)
            for spec in cfg.pattern
        ),
        "rem": tuple(blocks.layer_def(cfg, spec, cross=cross) for spec in cfg.remainder_specs),
    }
    if cfg.is_encdec:
        enc_spec = LayerSpec("attn", "dense")
        defs["encoder"] = {
            "layers": stack_defs(blocks.layer_def(cfg, enc_spec), cfg.encoder_layers),
            "final_norm": rmsnorm_def(cfg.d_model),
        }
    return defs


def init(cfg: ArchConfig, key: jax.Array) -> Any:
    return common.init_params(key, param_defs(cfg))


def abstract_params(cfg: ArchConfig) -> Any:
    return common.abstract_params(param_defs(cfg))


# ---------------------------------------------------------------- embeddings


def embed_inputs(cfg: ArchConfig, params: Any, batch: dict) -> jax.Array:
    """Token (+ stub frontend) inputs -> backbone embeddings (B, S, d).

    VLM: projected patch embeddings are prepended to the token embeddings.
    Audio (whisper): frontend feeds the *encoder*; see ``encode``.
    """
    dt = jnp.dtype(cfg.compute_dtype)
    e = embed(params["embed"], batch["tokens"], cfg, dt)
    if cfg.frontend == "vision" and "frontend" in batch:
        fe = project_frontend(params["embed"], batch["frontend"], dt)
        e = jnp.concatenate([fe, e], axis=1)
    return constrain(e, "batch", "seq", None)


def encode(cfg: ArchConfig, params: Any, frontend: jax.Array) -> jax.Array:
    """Encoder stack over stub frontend embeddings (whisper)."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = project_frontend(params["embed"], frontend, dt)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    enc_spec = LayerSpec("attn", "dense")

    def body(carry, lp):
        y, _ = blocks.apply_layer(cfg, enc_spec, lp, carry, positions=pos, causal=False)
        return y, None

    x, _ = scan_or_unroll(body, x, params["encoder"]["layers"])
    return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


# ------------------------------------------------------------------ backbone


def hidden_from_embeds(
    cfg: ArchConfig,
    params: Any,
    e: jax.Array,
    *,
    enc_out: Optional[jax.Array] = None,
    remat: bool = False,
    lengths: Optional[jax.Array] = None,  # (B,) ragged valid lengths
) -> tuple[jax.Array, jax.Array]:
    """Backbone over embeddings. Returns (hidden (B,S,d), moe_aux)."""
    pos = jnp.broadcast_to(jnp.arange(e.shape[1]), e.shape[:2])

    def period(x, period_params):
        aux = jnp.zeros((), jnp.float32)
        for spec, lp in zip(cfg.pattern, period_params):
            x, a = blocks.apply_layer(
                cfg, spec, lp, x, positions=pos, causal=True, enc_out=enc_out,
                kv_len=lengths,
            )
            x = constrain(x, "batch", "seq", None)  # residual stays DP/SP
            aux = aux + a
        return x, aux

    body = jax.checkpoint(period) if remat else period

    def scan_body(x, period_params):
        return body(x, period_params)

    x, auxs = scan_or_unroll(scan_body, e, params["layers"])
    aux = auxs.sum()
    for spec, lp in zip(cfg.remainder_specs, params["rem"]):
        x, a = blocks.apply_layer(
            cfg, spec, lp, x, positions=pos, causal=True, enc_out=enc_out,
            kv_len=lengths,
        )
        aux = aux + a
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def forward_hidden(
    cfg: ArchConfig, params: Any, batch: dict, *, remat: bool = False
) -> tuple[jax.Array, jax.Array]:
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(cfg, params, batch["frontend"])
    e = embed_inputs(cfg, params, batch)
    return hidden_from_embeds(cfg, params, e, enc_out=enc_out, remat=remat)


def logits(cfg: ArchConfig, params: Any, h: jax.Array) -> jax.Array:
    return unembed(params["embed"], h, cfg)


def loss(cfg: ArchConfig, params: Any, batch: dict, *, remat: bool = False) -> jax.Array:
    """Next-token xent (+ MoE aux). labels: (B, S_text)."""
    h, aux = forward_hidden(cfg, params, batch, remat=remat)
    if cfg.frontend == "vision":  # only text positions carry labels
        h = h[:, -batch["labels"].shape[1] :]
    return softmax_xent_chunked(params["embed"], h, batch["labels"], cfg) + aux


# ----------------------------------------------------------------- serving


def init_cache(
    cfg: ArchConfig, batch: int, max_len: int, *, kv_slots: int = 0
) -> dict:
    dt = jnp.dtype(cfg.compute_dtype)
    cache: dict[str, Any] = {
        "layers": tuple(
            jax.tree.map(
                lambda x: jnp.zeros((cfg.num_periods,) + x.shape, x.dtype),
                blocks.layer_cache(cfg, spec, batch, max_len, dt, kv_slots=kv_slots),
            )
            for spec in cfg.pattern
        ),
        "rem": tuple(
            blocks.layer_cache(cfg, spec, batch, max_len, dt, kv_slots=kv_slots)
            for spec in cfg.remainder_specs
        ),
        "len": jnp.zeros((), jnp.int32),
    }
    return cache


def prefill(
    cfg: ArchConfig, params: Any, batch: dict, max_len: int, *, kv_slots: int = 0
) -> tuple[jax.Array, dict]:
    """Run the prompt, build the cache, return last-position logits."""
    enc_out = encode(cfg, params, batch["frontend"]) if cfg.is_encdec else None
    e = embed_inputs(cfg, params, batch)
    B, S, _ = e.shape
    # S includes prepended frontend tokens; a too-small cache would silently
    # clamp decode writes (dynamic_update_slice semantics) and corrupt.
    assert S <= max_len, f"prefill length {S} exceeds cache max_len {max_len}"
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    cache = init_cache(cfg, B, max_len, kv_slots=kv_slots)

    def period(x, xs):
        period_params, period_cache = xs
        new_caches = []
        for spec, lp, lc in zip(cfg.pattern, period_params, period_cache):
            x, nc = blocks.apply_layer_prefill(
                cfg, spec, lp, x, lc, positions=pos, enc_out=enc_out
            )
            x = constrain(x, "batch", "seq", None)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, layer_caches = scan_or_unroll(period, e, (params["layers"], cache["layers"]))
    new_rem = []
    for spec, lp, lc in zip(cfg.remainder_specs, params["rem"], cache["rem"]):
        x, nc = blocks.apply_layer_prefill(cfg, spec, lp, x, lc, positions=pos, enc_out=enc_out)
        new_rem.append(nc)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    lg = logits(cfg, params, x[:, -1:])
    new_cache = {"layers": layer_caches, "rem": tuple(new_rem), "len": jnp.asarray(S, jnp.int32)}
    return lg, new_cache


def decode_step(
    cfg: ArchConfig, params: Any, cache: dict, token: jax.Array
) -> tuple[jax.Array, dict]:
    """token: (B, 1) int32 -> (logits (B, 1, V), updated cache)."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = embed(params["embed"], token, cfg, dt)
    pos = cache["len"]

    def period(x, xs):
        period_params, period_cache = xs
        new_caches = []
        for spec, lp, lc in zip(cfg.pattern, period_params, period_cache):
            x, nc = blocks.apply_layer_decode(cfg, spec, lp, x, lc, pos)
            x = constrain(x, "batch", "seq", None)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, layer_caches = scan_or_unroll(period, x, (params["layers"], cache["layers"]))
    new_rem = []
    for spec, lp, lc in zip(cfg.remainder_specs, params["rem"], cache["rem"]):
        x, nc = blocks.apply_layer_decode(cfg, spec, lp, x, lc, pos)
        new_rem.append(nc)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    lg = logits(cfg, params, x)
    new_cache = {"layers": layer_caches, "rem": tuple(new_rem), "len": pos + 1}
    return lg, new_cache
