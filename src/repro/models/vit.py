"""ViT encoder — patch-level attributions on the attention hot path.

Pre-norm transformer over patch embeddings (linear patch projection + learned
position embedding, no CLS token — masked mean-pool head), built from the
same blocks as the LM (rmsnorm / GQA qkv / SwiGLU mlp) so
``dispatch_attention`` — and therefore the flash custom-VJP kernel — is
shared between model families.

IG path note: the patch projection is affine, so a straight line in pixel
space maps to a straight line in embedding space — attributing in embedding
space (what ``ExplainEngine`` buckets) is exactly the paper's pixel-space IG
with per-patch aggregation built in.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.vit import VitConfig
from repro.models import attention as attn
from repro.models import common
from repro.models.common import ParamDef, scan_or_unroll, stack_defs
from repro.models.layers import mlp, mlp_def, rmsnorm, rmsnorm_def

# ---------------------------------------------------------------- parameters


def _layer_def(cfg: VitConfig) -> dict:
    return {
        "norm1": rmsnorm_def(cfg.d_model),
        "mixer": attn.attn_def(cfg),  # duck-typed VitConfig (see configs/vit.py)
        "norm2": rmsnorm_def(cfg.d_model),
        "ffn": mlp_def(cfg.d_model, cfg.d_ff),
    }


def param_defs(cfg: VitConfig) -> dict:
    d = cfg.d_model
    return {
        "patch_proj": ParamDef((cfg.patch_dim, d), ("frontend", "embed")),
        "patch_bias": ParamDef((d,), (None,), init="zeros"),
        "pos_embed": ParamDef((cfg.num_patches, d), (None, "embed"), scale=0.02),
        "layers": stack_defs(_layer_def(cfg), cfg.num_layers),
        "final_norm": rmsnorm_def(d),
        "head": {
            "w": ParamDef((d, cfg.num_classes), ("embed", None)),
            "b": ParamDef((cfg.num_classes,), (None,), init="zeros"),
        },
    }


def init(cfg: VitConfig, key: jax.Array) -> Any:
    return common.init_params(key, param_defs(cfg))


# ---------------------------------------------------------------- embedding


def patchify(cfg: VitConfig, images: jax.Array) -> jax.Array:
    """(B, H, W, C) -> (B, num_patches, patch_dim) row-major patch features."""
    B, H, W, C = images.shape
    p = cfg.patch_size
    x = images.reshape(B, H // p, p, W // p, p, C)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(B, (H // p) * (W // p), p * p * C)


def embed_features(cfg: VitConfig, params: Any, feats: jax.Array) -> jax.Array:
    """Patch features -> backbone embeddings (the IG interpolation space)."""
    dt = jnp.dtype(cfg.compute_dtype)
    e = feats.astype(dt) @ params["patch_proj"].astype(dt) + params["patch_bias"].astype(dt)
    S, pe = e.shape[1], params["pos_embed"].astype(dt)
    if S <= pe.shape[0]:
        pe = pe[:S]
    else:  # bucket padded past the patch grid: padded slots carry no posemb
        pe = jnp.pad(pe, ((0, S - pe.shape[0]), (0, 0)))
    return e + pe[None]


# ------------------------------------------------------------------ backbone


def encode(
    cfg: VitConfig,
    params: Any,
    e: jax.Array,  # (B, S, d)
    *,
    lengths: Optional[jax.Array] = None,  # (B,) valid patch counts
) -> jax.Array:
    dt = e.dtype

    def body(x, lp):
        h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
        q, k, v = attn.qkv(lp["mixer"], h, dt)
        o = attn.dispatch_attention(
            cfg, q, k, v, mixer="attn", causal=False, kv_len=lengths
        )
        x = x + attn.out_proj(lp["mixer"], o, dt)
        h = rmsnorm(lp["norm2"], x, cfg.norm_eps)
        return x + mlp(lp["ffn"], h), None

    x, _ = scan_or_unroll(body, e, params["layers"])
    return rmsnorm(params["final_norm"], x, cfg.norm_eps)


def pool_logits(
    cfg: VitConfig,
    params: Any,
    h: jax.Array,  # (B, S, d)
    *,
    lengths: Optional[jax.Array] = None,
) -> jax.Array:
    """Masked mean-pool over valid patches -> (B, num_classes) logits."""
    if lengths is None:
        pooled = h.mean(axis=1)
    else:
        m = (jnp.arange(h.shape[1])[None, :] < lengths[:, None]).astype(h.dtype)
        pooled = (h * m[..., None]).sum(1) / jnp.maximum(m.sum(1, keepdims=True), 1.0)
    dt = h.dtype
    return pooled @ params["head"]["w"].astype(dt) + params["head"]["b"].astype(dt)


def forward(cfg: VitConfig, params: Any, images: jax.Array) -> jax.Array:
    """images: (B, H, W, C) -> logits (B, num_classes)."""
    e = embed_features(cfg, params, patchify(cfg, images))
    return pool_logits(cfg, params, encode(cfg, params, e))


def prob_fn(cfg: VitConfig, params: Any, images: jax.Array, target: jax.Array) -> jax.Array:
    """Target-class probability — the paper's IG output function f."""
    p = jax.nn.softmax(forward(cfg, params, images), axis=-1)
    return jnp.take_along_axis(p, target[:, None], axis=-1)[:, 0]


# ------------------------------------------------------------------- facade


class VitModel:
    """ExplainEngine-facing facade (the feature-request counterpart of
    ``registry.Model``): requests carry patchified images in ``features``."""

    def __init__(self, cfg: VitConfig):
        self.cfg = cfg

    def param_defs(self):
        return param_defs(self.cfg)

    def init(self, key: jax.Array):
        return init(self.cfg, key)

    def embed_inputs(self, params, batch):
        raise TypeError(
            "VitModel has no token embedding: ExplainRequests for a ViT must "
            "carry features=patchify(cfg, image) (see models/vit.patchify)"
        )

    def embed_features(self, params, feats: jax.Array) -> jax.Array:
        return embed_features(self.cfg, params, feats)

    def target_logprob_at_fn(self, params):
        """f(embeds, aux) -> (B,) target-class log-prob; aux["pos"] is the
        last valid patch index, so lengths = pos + 1 masks bucket padding."""

        def f(e: jax.Array, aux: dict) -> jax.Array:
            lengths = aux["pos"] + 1
            h = encode(self.cfg, params, e, lengths=lengths)
            lg = pool_logits(self.cfg, params, h, lengths=lengths).astype(jnp.float32)
            rows = jnp.arange(e.shape[0])
            return jax.nn.log_softmax(lg, axis=-1)[rows, aux["target"]]

        return f
