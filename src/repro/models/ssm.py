"""Mamba-2 (SSD — state-space duality) block: chunked train/prefill path +
single-step decode recurrence.

TPU adaptation: the SSD chunked algorithm is already MXU-shaped (intra-chunk
work is batched matmuls). Intra-chunk terms are computed for ALL chunks at
once (chunk axis = batch axis), and the inter-chunk state recurrence is a
log-depth ``lax.associative_scan`` — fully parallel on TPU, unlike the
sequential per-chunk lax.scan a straight GPU port would use. Nothing O(S^2)
is ever materialized; SSD heads shard on the 'model' mesh axis (head-parallel
== TP for SSMs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ParamDef
from repro.sharding.context import constrain


def ssm_def(cfg: ArchConfig) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    G, N, H, W = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    return {
        "in_z": ParamDef((d, di), ("embed", "inner")),
        "in_x": ParamDef((d, di), ("embed", "inner")),
        "in_B": ParamDef((d, G * N), ("embed", None)),
        "in_C": ParamDef((d, G * N), ("embed", None)),
        "in_dt": ParamDef((d, H), ("embed", "ssm_heads")),
        "conv_x": ParamDef((W, di), (None, "inner"), scale=0.5),
        "conv_B": ParamDef((W, G * N), (None, None), scale=0.5),
        "conv_C": ParamDef((W, G * N), (None, None), scale=0.5),
        "A_log": ParamDef((H,), ("ssm_heads",), init="zeros"),
        "D": ParamDef((H,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamDef((H,), ("ssm_heads",), init="zeros"),
        "norm": ParamDef((di,), ("inner",), init="ones"),
        "out": ParamDef((di, d), ("inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds (width is tiny, e.g. 4)."""
    W = w.shape[0]
    out = x * w[-1]
    for i in range(1, W):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[W - 1 - i]
    return out


def _gated_norm(scale: jax.Array, y: jax.Array, z: jax.Array, eps: float) -> jax.Array:
    g = y * jax.nn.silu(z)
    g32 = g.astype(jnp.float32)
    var = jnp.mean(jnp.square(g32), axis=-1, keepdims=True)
    return (g32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def _proj_inputs(p: dict, u: jax.Array, cfg: ArchConfig):
    dt_ = u.dtype
    z = jnp.einsum("bsd,de->bse", u, p["in_z"].astype(dt_))
    x = jnp.einsum("bsd,de->bse", u, p["in_x"].astype(dt_))
    Bm = jnp.einsum("bsd,de->bse", u, p["in_B"].astype(dt_))
    Cm = jnp.einsum("bsd,de->bse", u, p["in_C"].astype(dt_))
    dt = jnp.einsum("bsd,dh->bsh", u, p["in_dt"].astype(dt_))
    return z, x, Bm, Cm, dt


def ssm_forward(p: dict, u: jax.Array, cfg: ArchConfig, eps: float = 1e-6) -> jax.Array:
    """Full-sequence SSD. u: (B, S, d_model) -> (B, S, d_model)."""
    return _ssd(p, u, cfg, eps, return_state=False)


def ssm_forward_with_state(
    p: dict, u: jax.Array, cfg: ArchConfig, eps: float = 1e-6
) -> tuple[jax.Array, dict]:
    """Prefill: full-sequence SSD returning the decode cache (state + conv tail)."""
    return _ssd(p, u, cfg, eps, return_state=True)


def _ssd(p: dict, u: jax.Array, cfg: ArchConfig, eps: float, return_state: bool):
    Bb, S, _ = u.shape
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    # largest chunk <= cfg.ssm_chunk that divides S (SSD is exact for any
    # chunking; odd prefill lengths just get slightly smaller chunks)
    cl = min(cfg.ssm_chunk, S)
    while S % cl:
        cl -= 1
    nc = S // cl

    z, x, Bm, Cm, dt = _proj_inputs(p, u, cfg)
    raw_xbc = jnp.concatenate([x, Bm, Cm], axis=-1) if return_state else None
    x = jax.nn.silu(_causal_conv(x, p["conv_x"].astype(x.dtype)))
    Bm = jax.nn.silu(_causal_conv(Bm, p["conv_B"].astype(x.dtype)))
    Cm = jax.nn.silu(_causal_conv(Cm, p["conv_C"].astype(x.dtype)))

    xh = constrain(x.reshape(Bb, S, H, P), "batch", "seq", "model", None)
    rep = H // G
    Bh = jnp.repeat(Bm.reshape(Bb, S, G, N), rep, axis=2)  # (B, S, H, N)
    Ch = jnp.repeat(Cm.reshape(Bb, S, G, N), rep, axis=2)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    # chunked layout: (nc, B, cl, ...)
    def chunked(t):
        return t.reshape(Bb, nc, cl, *t.shape[2:]).swapaxes(0, 1)

    xc, Bc, Cc, dtc = map(chunked, (xh, Bh, Ch, dt))
    dA = dtc * A  # (nc, B, cl, H) fp32
    cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative decay

    # ---- intra-chunk (diag) term, batched over ALL chunks (no scan):
    # L[l, s] = exp(cum_l - cum_s), causal within the chunk.
    L = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (nc, B, l, s, H)
    l_idx = jnp.arange(cl)
    causal = l_idx[:, None] >= l_idx[None, :]
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(L), 0.0)
    xdt = xc.astype(jnp.float32) * (dA / A)[..., None]  # x*dt (dA = dt*A)
    Cf, Bf = Cc.astype(jnp.float32), Bc.astype(jnp.float32)
    y_diag = jnp.einsum("cblhn,cbshn,cblsh,cbshp->cblhp", Cf, Bf, L, xdt)

    # ---- per-chunk state contribution and decay (still no scan)
    in_decay = jnp.exp(cum[:, :, -1:, :] - cum)  # (nc, B, l, H)
    new_contrib = jnp.einsum("cblhn,cblh,cblhp->cbhpn", Bf, in_decay, xdt)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (nc, B, H)

    # ---- inter-chunk state recurrence: s_k = s_{k-1} * d_k + c_k.
    # Log-depth associative scan over chunks — parallel on TPU (vs the
    # sequential lax.scan a straight port would use) and visible in full to
    # HLO cost analysis (no while loop).
    def combine(lhs, rhs):
        d_l, c_l = lhs
        d_r, c_r = rhs
        return d_l * d_r, c_l * d_r[..., None, None] + c_r

    ds, cs = jax.lax.associative_scan(combine, (chunk_decay, new_contrib), axis=0)
    final_state = cs[-1]
    states_in = jnp.concatenate(
        [jnp.zeros_like(cs[:1]), cs[:-1]], axis=0
    )  # state entering chunk k (exclusive scan)

    out_decay = jnp.exp(cum)  # (nc, B, l, H)
    y_off = jnp.einsum("cblhn,cbhpn,cblh->cblhp", Cf, states_in, out_decay)

    yc = (y_diag + y_off).astype(xc.dtype)
    y = yc.swapaxes(0, 1).reshape(Bb, S, H, P)
    y = y + xh * p["D"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(Bb, S, H * P)
    y = _gated_norm(p["norm"], y, z, eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out"].astype(y.dtype))
    if not return_state:
        return out
    W = cfg.ssm_conv
    tail = raw_xbc[:, max(S - (W - 1), 0) :]
    if S < W - 1:  # left-pad with zeros to W-1 entries
        tail = jnp.pad(tail, ((0, 0), (W - 1 - S, 0), (0, 0)))
    return out, {"state": final_state, "conv": tail}


# ------------------------------------------------------------------- decode


def ssm_init_cache(cfg: ArchConfig, batch: int, dtype) -> dict:
    H, P, N, G, W = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_conv
    ch = cfg.d_inner + 2 * G * N
    return {
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, W - 1, ch), dtype),  # last W-1 conv inputs
    }


def ssm_decode_step(
    p: dict, u: jax.Array, cache: dict, cfg: ArchConfig, eps: float = 1e-6
) -> tuple[jax.Array, dict]:
    """u: (B, 1, d_model); single-token recurrent update."""
    Bb = u.shape[0]
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    di = cfg.d_inner
    z, x, Bm, Cm, dt = _proj_inputs(p, u, cfg)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)[:, 0]  # (B, ch)
    hist = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # (B, W, ch)
    wfull = jnp.concatenate(
        [p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1
    ).astype(xbc.dtype)  # (W, ch)
    conv_out = jnp.einsum("bwc,wc->bc", hist, wfull)
    conv_out = jax.nn.silu(conv_out)
    x = conv_out[:, :di]
    Bm = conv_out[:, di : di + G * N]
    Cm = conv_out[:, di + G * N :]

    xh = x.reshape(Bb, H, P).astype(jnp.float32)
    rep = H // G
    Bh = jnp.repeat(Bm.reshape(Bb, G, N), rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cm.reshape(Bb, G, N), rep, axis=1).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    decay = jnp.exp(dtv * A)  # (B, H)
    state = cache["state"] * decay[..., None, None] + jnp.einsum(
        "bhp,bhn,bh->bhpn", xh, Bh, dtv
    )
    y = jnp.einsum("bhn,bhpn->bhp", Ch, state)  # (B, H, P)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(Bb, 1, H * P).astype(u.dtype)
    y = _gated_norm(p["norm"], y, z, eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out"].astype(y.dtype))
    new_cache = {"state": state, "conv": hist[:, 1:]}
    return out, new_cache
