"""Layer assembly: (mixer, ffn) pairs per LayerSpec, forward + decode paths."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.models import attention as attn
from repro.models import ssm
from repro.models.common import ParamDef
from repro.models.layers import mlp, mlp_def, rmsnorm, rmsnorm_def, rope
from repro.models.moe import moe, moe_def


def layer_def(cfg: ArchConfig, spec: LayerSpec, *, cross: bool = False) -> dict:
    d: dict[str, Any] = {}
    if spec.mixer in ("attn", "local"):
        d["norm1"] = rmsnorm_def(cfg.d_model)
        d["mixer"] = attn.attn_def(cfg)
    elif spec.mixer == "mamba":
        d["norm1"] = rmsnorm_def(cfg.d_model)
        d["mixer"] = ssm.ssm_def(cfg)
    if cross:
        d["norm_x"] = rmsnorm_def(cfg.d_model)
        d["cross"] = attn.attn_def(cfg, cross=True)
    if spec.ffn == "dense":
        d["norm2"] = rmsnorm_def(cfg.d_model)
        d["ffn"] = mlp_def(cfg.d_model, cfg.d_ff)
    elif spec.ffn == "moe":
        d["norm2"] = rmsnorm_def(cfg.d_model)
        d["ffn"] = moe_def(cfg)
    return d


# ------------------------------------------------------------------ forward


def apply_layer(
    cfg: ArchConfig,
    spec: LayerSpec,
    p: dict,
    x: jax.Array,
    *,
    positions: jax.Array,
    causal: bool = True,
    enc_out: Optional[jax.Array] = None,
    kv_len: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence layer. Returns (x, moe_aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    dt = x.dtype
    if spec.mixer in ("attn", "local"):
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        q, k, v = attn.qkv(p["mixer"], h, dt)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        o = attn.dispatch_attention(
            cfg, q, k, v, mixer=spec.mixer, causal=causal, kv_len=kv_len
        )
        x = x + attn.out_proj(p["mixer"], o, dt)
    elif spec.mixer == "mamba":
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        x = x + ssm.ssm_forward(p["mixer"], h, cfg, cfg.norm_eps)
    if "cross" in p and enc_out is not None:
        h = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["wq"].astype(dt))
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"].astype(dt))
        o = attn.full_attention(q, k, v, causal=False)
        x = x + attn.out_proj(p["cross"], o, dt)
    if spec.ffn == "dense":
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + mlp(p["ffn"], h)
    elif spec.ffn == "moe":
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        y, aux = moe(p["ffn"], h, cfg)
        x = x + y
    return x, aux


# ------------------------------------------------------------- cache create


def layer_cache(
    cfg: ArchConfig,
    spec: LayerSpec,
    batch: int,
    max_len: int,
    dtype,
    *,
    kv_slots: int = 0,
) -> dict:
    """Empty decode cache for one layer. kv_slots: TP-expanded KV head count."""
    hd = cfg.resolved_head_dim
    kh = max(cfg.num_kv_heads, kv_slots or cfg.num_kv_heads)
    if spec.mixer == "attn":
        shape = (batch, max_len, kh, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if spec.mixer == "local":
        w = min(cfg.sliding_window or max_len, max_len)
        shape = (batch, w, kh, hd)  # ring buffer
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if spec.mixer == "mamba":
        return ssm.ssm_init_cache(cfg, batch, dtype)
    return {}


# ---------------------------------------------------------- prefill (+cache)


def apply_layer_prefill(
    cfg: ArchConfig,
    spec: LayerSpec,
    p: dict,
    x: jax.Array,
    cache: dict,
    *,
    positions: jax.Array,
    enc_out: Optional[jax.Array] = None,
) -> tuple[jax.Array, dict]:
    """Forward over the prompt AND populate the decode cache."""
    dt = x.dtype
    if spec.mixer in ("attn", "local"):
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        q, k, v = attn.qkv(p["mixer"], h, dt)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        o = attn.dispatch_attention(cfg, q, k, v, mixer=spec.mixer, causal=True)
        x = x + attn.out_proj(p["mixer"], o, dt)
        slots = cache["k"].shape[2]
        ke, ve = attn.expand_kv(k, slots), attn.expand_kv(v, slots)
        if spec.mixer == "local":
            w = cache["k"].shape[1]
            S = k.shape[1]
            if S >= w:  # last w tokens, rotated so slot = pos % w
                tail_k, tail_v = ke[:, S - w :], ve[:, S - w :]
                shift = S % w  # oldest tail element belongs at slot (S-w)%w == S%w
                cache = {
                    "k": jnp.roll(tail_k, shift, axis=1),
                    "v": jnp.roll(tail_v, shift, axis=1),
                }
            else:
                cache = {
                    "k": cache["k"].at[:, :S].set(ke),
                    "v": cache["v"].at[:, :S].set(ve),
                }
        else:
            cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], ke, 0, axis=1),
                "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], ve, 0, axis=1),
            }
    elif spec.mixer == "mamba":
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        y, new_state = ssm.ssm_forward_with_state(p["mixer"], h, cfg, cfg.norm_eps)
        x = x + y
        cache = new_state
    if "cross" in p and enc_out is not None:
        h = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["wq"].astype(dt))
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"].astype(dt))
        o = attn.full_attention(q, k, v, causal=False)
        x = x + attn.out_proj(p["cross"], o, dt)
        cache = dict(cache) if cache else {}
        cache["xk"], cache["xv"] = k, v  # cross KV reused every decode step
    if spec.ffn == "dense":
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + mlp(p["ffn"], h)
    elif spec.ffn == "moe":
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        y, _ = moe(p["ffn"], h, cfg)
        x = x + y
    return x, cache


# -------------------------------------------------------------------- decode


def apply_layer_decode(
    cfg: ArchConfig,
    spec: LayerSpec,
    p: dict,
    x: jax.Array,  # (B, 1, d)
    cache: dict,
    pos: jax.Array,  # () int32 — position of the incoming token
) -> tuple[jax.Array, dict]:
    dt = x.dtype
    new_cache = dict(cache)
    if spec.mixer in ("attn", "local"):
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        q, k, v = attn.qkv(p["mixer"], h, dt)
        posv = pos[None] if pos.ndim == 0 else pos
        q = rope(q, jnp.broadcast_to(posv, (x.shape[0], 1)), cfg.rope_theta)
        k = rope(k, jnp.broadcast_to(posv, (x.shape[0], 1)), cfg.rope_theta)
        slots = cache["k"].shape[2]
        ke, ve = attn.expand_kv(k, slots), attn.expand_kv(v, slots)
        if spec.mixer == "local":
            w = cache["k"].shape[1]
            slot = pos % w
            kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], ke, slot, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], ve, slot, axis=1)
            o = attn.decode_attention(q, kc, vc, pos + 1, ring=True)
        else:
            kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], ke, pos, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], ve, pos, axis=1)
            o = attn.decode_attention(q, kc, vc, pos + 1)
        new_cache["k"], new_cache["v"] = kc, vc
        x = x + attn.out_proj(p["mixer"], o, dt)
    elif spec.mixer == "mamba":
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        y, sc = ssm.ssm_decode_step(
            p["mixer"], h, {"state": cache["state"], "conv": cache["conv"]}, cfg, cfg.norm_eps
        )
        x = x + y
        new_cache["state"], new_cache["conv"] = sc["state"], sc["conv"]
    if "cross" in p and "xk" in cache:
        h = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["wq"].astype(dt))
        o = attn.full_attention(q, cache["xk"], cache["xv"], causal=False)
        x = x + attn.out_proj(p["cross"], o, dt)
    if spec.ffn == "dense":
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + mlp(p["ffn"], h)
    elif spec.ffn == "moe":
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        y, _ = moe(p["ffn"], h, cfg)
        x = x + y
    return x, new_cache
