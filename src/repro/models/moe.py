"""Mixture-of-Experts: top-k token-choice routing, sort-based capacity dispatch.

TPU-native design notes (vs a CUDA grouped-GEMM):
  * dispatch = argsort by expert id + rank-within-expert scatter into a dense
    (E, C, d) buffer -> one batched einsum over experts hits the MXU;
  * under pjit the expert axis is sharded on the 'model' mesh axis (EP); the
    scatter/gather lower to the all-to-all pattern a hand-written MoE layer
    would issue;
  * capacity keeps every shape static (XLA requirement); dropped tokens fall
    back to the residual stream, standard for capacity-based MoE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ParamDef
from repro.sharding.context import constrain


def moe_def(cfg: ArchConfig) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.num_experts
    return {
        "router": ParamDef((d, e), ("embed", None), scale=0.02),
        "wi_gate": ParamDef((e, d, f), ("experts", "embed", "mlp")),
        "wi_up": ParamDef((e, d, f), ("experts", "embed", "mlp")),
        "wo": ParamDef((e, f, d), ("experts", "mlp", "embed")),
    }


def capacity(tokens: int, cfg: ArchConfig) -> int:
    c = int(tokens * cfg.experts_per_tok * cfg.capacity_factor / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly tiling


def moe(p: dict, x: jax.Array, cfg: ArchConfig) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss). Top-k routing with capacity dropping."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.num_experts, cfg.experts_per_tok
    C = capacity(T, cfg)
    xt = x.reshape(T, d)
    dt = x.dtype

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate, eid = jax.lax.top_k(probs, k)  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux loss (Switch-style)
    me = probs.mean(0)  # mean router prob per expert
    ce = jnp.zeros((E,), jnp.float32).at[eid.reshape(-1)].add(1.0) / (T * k)
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    # ---- sort-based dispatch
    flat_e = eid.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_e)  # stable
    se = flat_e[order]  # sorted expert ids
    st = order // k  # token index of each sorted slot
    sg = gate.reshape(-1)[order].astype(dt)
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts  # first sorted slot of each expert
    rank = jnp.arange(T * k) - starts[se]  # position within expert
    keep = rank < C

    buf = jnp.zeros((E, C, d), dt)
    buf = buf.at[jnp.where(keep, se, E - 1), jnp.where(keep, rank, C - 1)].add(
        jnp.where(keep[:, None], xt[st], 0)
    )
    buf = constrain(buf, "model", None, None)  # EP: experts stay sharded

    # ---- expert computation (batched einsum over the expert axis; EP-sharded)
    g = jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf, p["wi_up"].astype(dt))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))  # (E, C, d)
    out = constrain(out, "model", None, None)

    # ---- combine
    gathered = out[jnp.where(keep, se, 0), jnp.where(keep, rank, 0)]  # (T*k, d)
    contrib = jnp.where(keep[:, None], gathered * sg[:, None], 0)
    y = jnp.zeros((T, d), dt).at[st].add(contrib)
    return y.reshape(B, S, d), aux
