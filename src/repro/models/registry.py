"""Model facade + dry-run input specs.

``Model`` binds an ArchConfig to the functional model code; ``input_specs``
returns ``jax.ShapeDtypeStruct`` stand-ins for every input of the step being
lowered (weak-type-correct, shardable, no device allocation).
"""
from __future__ import annotations

from functools import cached_property, partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import lm


class Model:
    """Thin namespace binding cfg -> the functional model API."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # -- params ------------------------------------------------------------
    def param_defs(self):
        return lm.param_defs(self.cfg)

    def init(self, key: jax.Array):
        return lm.init(self.cfg, key)

    def abstract_params(self):
        return lm.abstract_params(self.cfg)

    # -- training ----------------------------------------------------------
    def loss(self, params, batch, *, remat: bool = False):
        return lm.loss(self.cfg, params, batch, remat=remat)

    # -- inference ---------------------------------------------------------
    def forward_hidden(self, params, batch, **kw):
        return lm.forward_hidden(self.cfg, params, batch, **kw)

    def logits(self, params, h):
        return lm.logits(self.cfg, params, h)

    def prefill(self, params, batch, max_len: int, *, kv_slots: int = 0):
        return lm.prefill(self.cfg, params, batch, max_len, kv_slots=kv_slots)

    def decode_step(self, params, cache, token):
        return lm.decode_step(self.cfg, params, cache, token)

    def init_cache(self, batch: int, max_len: int, *, kv_slots: int = 0):
        return lm.init_cache(self.cfg, batch, max_len, kv_slots=kv_slots)

    # -- IG hooks (embedding-space path) ------------------------------------
    def embed_inputs(self, params, batch):
        return lm.embed_inputs(self.cfg, params, batch)

    def hidden_from_embeds(self, params, e, **kw):
        return lm.hidden_from_embeds(self.cfg, params, e, **kw)

    def target_logprob_fn(self, params, *, target_pos: int = -1):
        """Returns f(embeds, target_token) -> (B,) log-prob — the IG output.

        The paper uses target-class probability of a classifier; the LM
        analogue is the next-token probability at ``target_pos``.
        """

        def f(e: jax.Array, target: jax.Array) -> jax.Array:
            h, _ = lm.hidden_from_embeds(self.cfg, params, e)
            lg = lm.logits(self.cfg, params, h[:, target_pos]).astype(jnp.float32)
            return jax.nn.log_softmax(lg, axis=-1)[jnp.arange(e.shape[0]), target]

        return f

    def target_logprob_at_fn(self, params):
        """Per-example-position variant for shape-bucketed serving.

        Returns f(embeds, aux) -> (B,) with aux = {"target": (B,) token ids,
        "pos": (B,) position of each example's last REAL token}. Right-padded
        batches read their logits at pos = len-1, so a causal model produces
        the same value as the unpadded forward. The flash path additionally
        threads per-row lengths so the kernel's kvlen block-skip does no work
        on padding (the XLA path needs no mask: causal right-padding is
        already exact, and leaving it unmasked keeps its HLO — and the
        hotpath bytes baselines — unchanged).
        """
        flash = getattr(self.cfg, "attn_impl", "auto") == "flash"

        def f(e: jax.Array, aux: dict) -> jax.Array:
            lengths = aux["pos"] + 1 if flash else None
            h, _ = lm.hidden_from_embeds(self.cfg, params, e, lengths=lengths)
            rows = jnp.arange(e.shape[0])
            lg = lm.logits(self.cfg, params, h[rows, aux["pos"]]).astype(jnp.float32)
            return jax.nn.log_softmax(lg, axis=-1)[rows, aux["target"]]

        return f


def model_for(cfg):
    """Config -> model facade: ArchConfig -> Model, VitConfig -> VitModel.

    Both facades expose the explain-engine surface: ``init``,
    ``target_logprob_at_fn`` and an embedding hook (``embed_inputs`` for
    token models, ``embed_features`` for patch models).
    """
    if isinstance(cfg, ArchConfig):
        return Model(cfg)
    if getattr(cfg, "patch_size", 0):
        from repro.models.vit import VitModel

        return VitModel(cfg)
    raise TypeError(f"no model facade for config type {type(cfg).__name__}")


def input_specs(cfg: ArchConfig, shape: ShapeConfig, *, kv_slots: int = 0) -> dict:
    """ShapeDtypeStruct stand-ins for the step lowered by the dry-run."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.dtype(cfg.compute_dtype)
    sds = jax.ShapeDtypeStruct

    def frontend_spec():
        return sds((B, cfg.frontend_tokens, cfg.frontend_dim or cfg.d_model), f32)

    if shape.kind == "train":
        if cfg.frontend == "vision":
            s_text = S - cfg.frontend_tokens
            return {
                "tokens": sds((B, s_text), i32),
                "labels": sds((B, s_text), i32),
                "frontend": frontend_spec(),
            }
        if cfg.frontend == "audio":
            return {
                "tokens": sds((B, S), i32),
                "labels": sds((B, S), i32),
                "frontend": sds((B, cfg.encoder_seq, cfg.frontend_dim), f32),
            }
        return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}

    if shape.kind == "prefill":
        batch = {"tokens": sds((B, S if cfg.frontend != "vision" else S - cfg.frontend_tokens), i32)}
        if cfg.frontend == "vision":
            batch["frontend"] = frontend_spec()
        if cfg.frontend == "audio":
            batch["frontend"] = sds((B, cfg.encoder_seq, cfg.frontend_dim), f32)
        return batch

    # decode: one new token against a cache of seq_len
    cache = jax.eval_shape(
        partial(lm.init_cache, cfg, B, S, kv_slots=kv_slots)
    )
    if cfg.is_encdec:  # cross-KV entries exist after prefill; add them
        hd = cfg.resolved_head_dim
        kh = cfg.num_kv_heads
        xspec = sds((cfg.num_periods, B, cfg.encoder_seq, kh, hd), f32)

        def add_cross(layer_cache):
            lc = dict(layer_cache)
            lc["xk"] = xspec
            lc["xv"] = xspec
            return lc

        cache = dict(cache)
        cache["layers"] = tuple(add_cross(lc) for lc in cache["layers"])
        cache["rem"] = tuple(
            {**lc, "xk": sds((B, cfg.encoder_seq, kh, hd), f32),
             "xv": sds((B, cfg.encoder_seq, kh, hd), f32)}
            for lc in cache["rem"]
        )
    return {"token": sds((B, 1), i32), "cache": cache}
