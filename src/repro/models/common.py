"""Param-definition machinery (no flax — substrate built here).

A model is described as a pytree of ``ParamDef``s. From that single source of
truth we derive:
  * materialized parameters            (``init_params`` — smoke tests, examples)
  * ``jax.ShapeDtypeStruct`` stand-ins (``abstract_params`` — the dry-run;
    never allocates)
  * logical sharding axes              (``param_axes`` — consumed by
    ``repro.sharding.partition``)
"""
from __future__ import annotations

import contextlib
import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------- costing mode
#
# XLA's HLO cost analysis counts a while-loop body ONCE regardless of trip
# count (verified: an 8-step lax.scan of a 512x512 matmul reports 268M flops
# vs 2147M unrolled). Production code keeps lax.scan (small HLO, fast
# compile); the dry-run's *measurement* lower runs under ``costing_mode()``,
# which unrolls every scan into straight-line HLO so cost_analysis and the
# collective parser see true totals. Costing lowers are never executed.

COSTING = False


@contextlib.contextmanager
def costing_mode():
    global COSTING
    prev = COSTING
    COSTING = True
    try:
        yield
    finally:
        COSTING = prev


def scan_or_unroll(body, init, xs, *, length: Optional[int] = None):
    """lax.scan normally; a Python loop (stacked outputs) under costing_mode.

    Mirrors lax.scan semantics for the subset used in this codebase:
    xs is a pytree stacked on the leading axis (or None with ``length``).
    """
    if not COSTING:
        return jax.lax.scan(body, init, xs, length=length)
    n = length if xs is None else jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        x_i = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is None:
        return carry, None
    stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys) if ys else None
    return carry, stacked


@dataclass(frozen=True)
class ParamDef:
    """Declarative description of one parameter tensor."""

    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]  # logical axis names, len == len(shape)
    init: str = "normal"  # normal | zeros | ones | embed
    scale: Optional[float] = None  # stddev override (normal); default fan-in
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _fan_in(shape: tuple[int, ...]) -> int:
    # convention: last axis is the output axis for >=2D weights
    return int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]


def _materialize(key: jax.Array, d: ParamDef) -> jax.Array:
    dt = jnp.dtype(d.dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dt)
    if d.init == "ones":
        return jnp.ones(d.shape, dt)
    if d.init == "embed":
        return (jax.random.normal(key, d.shape, jnp.float32) * (d.scale or 1.0)).astype(dt)
    scale = d.scale if d.scale is not None else 1.0 / np.sqrt(max(_fan_in(d.shape), 1))
    return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(dt)


def is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def init_params(key: jax.Array, defs: Any) -> Any:
    """Materialize a ParamDef tree into a parameter tree (real allocation)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [_materialize(k, d) for k, d in zip(keys, leaves)])


def abstract_params(defs: Any) -> Any:
    """ShapeDtypeStruct tree — used by the dry-run, zero allocation."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)), defs, is_leaf=is_def
    )


def param_axes(defs: Any) -> Any:
    """Tree of logical-axis tuples matching the param tree structure."""
    return jax.tree.map(lambda d: tuple(d.axes), defs, is_leaf=is_def)


def stack_defs(defs: Any, n: int, axis_name: Optional[str] = "layers") -> Any:
    """Stack a ParamDef tree along a new leading 'layers' axis (for lax.scan)."""

    def _stack(d: ParamDef) -> ParamDef:
        return dataclasses.replace(d, shape=(n,) + d.shape, axes=(axis_name,) + d.axes)

    return jax.tree.map(_stack, defs, is_leaf=is_def)


def param_count_tree(params: Any) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def cast_tree(params: Any, dtype) -> Any:
    """Cast floating-point leaves (compute-dtype policy)."""
    def _c(p):
        if jnp.issubdtype(p.dtype, jnp.floating):
            return p.astype(dtype)
        return p

    return jax.tree.map(_c, params)
