"""GQA attention: full, blocked (online-softmax), sliding-window, decode.

Layouts:  q (B, S, NQ, D)   k/v (B, S, NKV, D)   grouped as NQ = NKV * G.
The blocked paths never materialize an (S, S) score matrix — they are the
pure-jnp counterpart of the Pallas flash kernel in ``repro.kernels``; the XLA
path is what the multi-pod dry-run lowers (Pallas-TPU does not lower on the
CPU placeholder backend), and the kernel is validated in interpret mode.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ParamDef
from repro.models import common as _common
from repro.sharding.context import constrain
from repro.models.layers import rope

NEG_INF = -1e30


def attn_def(cfg: ArchConfig, *, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "wq": ParamDef((d, cfg.num_heads, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, cfg.num_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, cfg.num_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((cfg.num_heads, hd, d), ("heads", "head_dim", "embed")),
    }


def qkv(p: dict, x: jax.Array, dtype) -> tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dtype))
    # pin heads on TP axis (kv heads fall back to replicated if indivisible)
    q = constrain(q, "batch", "seq", "model", None)
    k = constrain(k, "batch", "seq", "model", None)
    v = constrain(v, "batch", "seq", "model", None)
    return q, k, v


def out_proj(p: dict, o: jax.Array, dtype) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dtype))


def _group(q: jax.Array, nkv: int) -> jax.Array:
    """(B, S, NQ, D) -> (B, S, NKV, G, D)."""
    B, S, NQ, D = q.shape
    return q.reshape(B, S, nkv, NQ // nkv, D)


# ------------------------------------------------------------- full (small S)


def full_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
    window: int = 0,
    kv_len: Optional[jax.Array] = None,  # (B,) valid K lengths (ragged batch)
) -> jax.Array:
    """Reference einsum attention; materializes (Sq, Sk) scores. Small-S path.

    GQA K/V are EXPANDED to the full Q-head count before the einsum. The
    grouped (B,S,kv,G,D) layout looks cheaper but is a TP trap: with kv=8 or
    G=4 on a 16-way 'model' axis neither head factor is divisible, so the
    SPMD partitioner replicates attention over the model axis (measured 16x
    flops/chip on llama3 train_4k). With the expanded layout the head axis
    shards cleanly; XLA fuses the repeat into the matmul operand load.
    """
    B, Sq, NQ, D = q.shape
    nkv = k.shape[2]
    ke, ve = expand_kv(k, NQ), expand_kv(v, NQ)
    s = jnp.einsum("bqhd,bkhd->bhqk", q * (D**-0.5), ke).astype(jnp.float32)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    if kv_len is not None:  # per-row ragged mask: (B, 1, Sq, Sk)
        valid = kpos[None, :] < kv_len.reshape(-1, 1)  # (B, Sk)
        full = mask[None, None] & valid[:, None, None, :]
        s = jnp.where(full, s, NEG_INF)
    else:
        s = jnp.where(mask, s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", a, ve)
    return o


# ----------------------------------------------------- blocked online-softmax


def blocked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 1024,
    block_k: int = 1024,
) -> jax.Array:
    """Flash-style attention in pure jnp: lax.map over Q blocks, lax.scan over
    K blocks with running (max, sum, acc). Peak memory O(block_q * block_k)."""
    B, Sq, NQ, D = q.shape
    Sk, nkv = k.shape[1], k.shape[2]
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    ke, ve = expand_kv(k, NQ), expand_kv(v, NQ)  # TP-shardable head axis
    qb = q.reshape(B, nq, bq, NQ, D).swapaxes(0, 1)  # (nq, B, bq, NQ, D)
    kb = ke.reshape(B, nk, bk, NQ, D).swapaxes(0, 1)
    vb = ve.reshape(B, nk, bk, NQ, D).swapaxes(0, 1)
    scale = D**-0.5

    def q_block(args):
        qi, qblk = args  # scalar index, (B, bq, NQ, D)
        qs = qblk * scale

        def kv_step(carry, xs):
            m, l, acc = carry
            ki, kblk, vblk = xs
            s = jnp.einsum("bqhd,bkhd->bhqk", qs, kblk).astype(jnp.float32)
            if causal:
                qpos = qi * bq + jnp.arange(bq)
                kpos = ki * bk + jnp.arange(bk)
                s = jnp.where(qpos[:, None] >= kpos[None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(qblk.dtype), vblk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        shape = (B, NQ, bq)
        init = (
            jnp.full(shape, NEG_INF, jnp.float32),
            jnp.zeros(shape, jnp.float32),
            jnp.zeros(shape + (D,), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, (jnp.arange(nk), kb, vb))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return o.transpose(0, 2, 1, 3).astype(q.dtype)  # (B, bq, NQ, D)

    out = jax.lax.map(q_block, (jnp.arange(nq), qb))  # (nq, B, bq, NQ, D)
    return out.swapaxes(0, 1).reshape(B, Sq, NQ, D)


# -------------------------------------------------------------- sliding window


def local_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, window: int
) -> jax.Array:
    """Causal sliding-window attention, vectorized over window-sized blocks.

    Each Q block attends its own block + the previous block with a band mask:
    compute is O(S * 2w) instead of O(S^2).
    """
    B, S, NQ, D = q.shape
    w = window
    if S <= 2 * w:  # small sequences: mask path is cheaper than blocking
        return full_attention(q, k, v, causal=True, window=w)
    assert S % w == 0, (S, w)
    nb = S // w
    kx, vx = expand_kv(k, NQ), expand_kv(v, NQ)  # TP-shardable head axis
    qb = q.reshape(B, nb, w, NQ, D) * (D**-0.5)

    def ext(x):  # (B, S, H, D) -> (B, nb, 2w, H, D): [prev block | own block]
        xb = x.reshape(B, nb, w, NQ, D)
        prev = jnp.concatenate([jnp.zeros_like(xb[:, :1]), xb[:, :-1]], axis=1)
        return jnp.concatenate([prev, xb], axis=2)

    ke, ve = ext(kx), ext(vx)
    s = jnp.einsum("bnqhd,bnkhd->bnhqk", qb, ke).astype(jnp.float32)
    qpos = jnp.arange(w)[:, None]
    kpos = jnp.arange(2 * w)[None, :] - w  # relative to block start
    mask = (qpos >= kpos) & (qpos - kpos < w)  # causal & within window
    first = jnp.arange(nb) == 0  # first block has no prev block
    mask = jnp.where(first[:, None, None], mask & (kpos >= 0), mask)  # (nb, w, 2w)
    s = jnp.where(mask[None, :, None], s, NEG_INF)  # align to (B, nb, h, q, k)
    a = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bnhqk,bnkhd->bnqhd", a, ve)
    return o.reshape(B, S, NQ, D)


# ------------------------------------------------------------------- decode


def decode_attention(
    q: jax.Array,  # (B, 1, NQ, D)
    k_cache: jax.Array,  # (B, Smax, KH, D)  (KH may be TP-expanded)
    v_cache: jax.Array,
    cache_len: jax.Array,  # () current valid length (== new token position + 1)
    *,
    window: int = 0,
    ring: bool = False,
) -> jax.Array:
    """Single-token attention against a (possibly ring-buffer) KV cache.

    Expanded-KV head layout (see full_attention): the cache may already be
    TP-expanded via ``kv_slots``; any remaining group factor is expanded
    here so the head axis stays shardable.
    """
    B, Smax, KH, D = k_cache.shape
    NQ = q.shape[2]
    ke, ve = expand_kv(k_cache, NQ), expand_kv(v_cache, NQ)
    s = jnp.einsum("bqhd,bkhd->bhqk", q * (D**-0.5), ke).astype(jnp.float32)
    idx = jnp.arange(Smax)
    if ring:
        valid = idx < jnp.minimum(cache_len, Smax)  # ring: whole buffer once full
    else:
        valid = idx < cache_len
        if window:
            valid &= idx >= cache_len - window
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", a, ve)
    return o


def expand_kv(k: jax.Array, target_heads: int) -> jax.Array:
    """Repeat KV heads so the cache head axis is shardable by TP.

    GQA configs have 4–16 KV heads but the 'model' mesh axis is 16; repeating
    KV heads to ``target_heads`` slots lets each TP shard hold exactly the KV
    group its Q heads consume (4x less memory than full replication).
    """
    B, S, KH, D = k.shape
    if KH >= target_heads:
        return k
    rep = target_heads // KH
    return jnp.repeat(k, rep, axis=2)


def dispatch_attention(
    cfg: ArchConfig,
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    mixer: str,
    causal: bool,
    kv_len: Optional[jax.Array] = None,  # (B,) ragged valid K lengths
    block_threshold: int = 4096,
) -> jax.Array:
    """Pick the attention algorithm for a (layer kind, seq length) pair.

    ``cfg.attn_impl == "flash"`` routes full-attention layers through the
    Pallas kernel (custom-VJP backward, no (B, H, S, S) score tensor in
    either direction); everything else stays on the XLA paths. Costing mode
    always materializes: Pallas flops/bytes are invisible to cost_analysis.
    """
    S = q.shape[1]
    if mixer == "local" and cfg.sliding_window:
        return local_attention(q, k, v, window=cfg.sliding_window)
    if _common.COSTING:  # costing mode: straight-line HLO, same flops
        return full_attention(q, k, v, causal=causal, kv_len=kv_len)
    if getattr(cfg, "attn_impl", "auto") == "flash":
        from repro.kernels.flash_attention.ops import flash_attention

        return flash_attention(
            q, k, v, causal=causal, lengths=kv_len,
            block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
        )
    if S > block_threshold and kv_len is None:
        return blocked_attention(q, k, v, causal=causal)
    return full_attention(q, k, v, causal=causal, kv_len=kv_len)
