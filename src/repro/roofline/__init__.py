from repro.roofline.analyze import (
    HW_V5E,
    Hardware,
    RooflineReport,
    cost_analysis_dict,
    hardware_for,
    hotpath_terms,
    parse_collective_bytes,
    roofline_report,
    model_flops,
)

__all__ = [
    "HW_V5E",
    "Hardware",
    "RooflineReport",
    "cost_analysis_dict",
    "hardware_for",
    "hotpath_terms",
    "parse_collective_bytes",
    "roofline_report",
    "model_flops",
]
