"""Three-term roofline from a compiled dry-run artifact (deliverable g).

    compute    = FLOPs_per_chip       / peak_FLOP/s
    memory     = HBM_bytes_per_chip   / HBM_bw
    collective = coll_bytes_per_chip  / link_bw

Sources: ``compiled.cost_analysis()`` (flops, bytes accessed) — on an SPMD
partitioned module these are PER-PARTITION numbers (one partition == one
chip), verified empirically in tests/test_roofline.py by comparing 1- vs
N-device lowers. collective bytes come from parsing the post-SPMD HLO
(``compiled.as_text()``): we sum *operand* bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction.

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (one link per mesh hop; we charge each collective its
operand bytes over one link, the standard bandwidth-optimal-ring estimate).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float  # per chip, bf16
    hbm_bw: float  # bytes/s per chip
    link_bw: float  # bytes/s per ICI link
    hbm_bytes: float  # capacity per chip


HW_V5E = Hardware(
    name="tpu_v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9, hbm_bytes=16e9
)

# Serving-host hardware models beyond the paper's v5e target. The numbers
# are deliberately round generic-class figures — the autotuner
# (repro.serve.autotune) only uses them to RANK candidate configs by their
# roofline terms before the measured sweep, so class-accurate ratios matter,
# absolute calibration does not.
HW_GENERIC_GPU = Hardware(
    name="generic_gpu", peak_flops=300e12, hbm_bw=2000e9, link_bw=300e9,
    hbm_bytes=80e9,
)
HW_CPU_HOST = Hardware(
    name="cpu_host", peak_flops=2e12, hbm_bw=100e9, link_bw=25e9,
    hbm_bytes=64e9,
)

# substring match (lowercased device_kind) -> hardware model; first hit wins
HW_BY_KIND: tuple[tuple[str, Hardware], ...] = (
    ("tpu v5 lite", HW_V5E),
    ("tpu", HW_V5E),
    ("cpu", HW_CPU_HOST),
    ("gpu", HW_GENERIC_GPU),
    ("cuda", HW_GENERIC_GPU),
    ("nvidia", HW_GENERIC_GPU),
)


def hardware_for(device_kind: str) -> Hardware:
    """Resolve a ``jax.Device.device_kind`` string to a hardware model.

    Unknown kinds fall back to the GPU-class model (an accelerator we have
    no table entry for is more accelerator-like than CPU-like).

        >>> hardware_for("cpu").name
        'cpu_host'
        >>> hardware_for("TPU v5 lite").name
        'tpu_v5e'
    """
    kind = device_kind.lower()
    for sub, hw in HW_BY_KIND:
        if sub in kind:
            return hw
    return HW_GENERIC_GPU


def hotpath_terms(cost: dict, hw: Hardware) -> dict:
    """Roofline terms for one stage-2 executable's ``cost_analysis`` dict.

    Returns ``{bytes_accessed, flops, memory_s, compute_s, bound_s,
    dominant}`` — the per-bucket budget the serving-path autotuner ranks
    candidate (chunk, block) configs with (DESIGN.md §10): ``bound_s`` is
    the roofline step-time estimate max(memory_s, compute_s), ``dominant``
    names the binding term.
    """
    nbytes = float(cost.get("bytes accessed", 0.0))
    flops = float(cost.get("flops", 0.0))
    memory_s = nbytes / hw.hbm_bw
    compute_s = flops / hw.peak_flops
    return {
        "bytes_accessed": nbytes,
        "flops": flops,
        "memory_s": memory_s,
        "compute_s": compute_s,
        "bound_s": max(memory_s, compute_s),
        "dominant": "memory" if memory_s >= compute_s else "compute",
    }

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

# '%name = bf16[128,4096]{1,0} op-name(%a, %b), ...'
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<type>\([^)]*\)|[\w\[\],{}/ ]+?)\s+"
    r"(?P<op>[\w\-]+)\((?P<operands>[^)]*)\)"
)
_SHAPE_RE = re.compile(r"(?P<dt>\w+)\[(?P<dims>[\d,]*)\]")

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions.

    Older jax returns ``[dict]``, newer returns ``dict``; either may be
    empty. Always returns a plain dict.
    """
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return dict(c) if c else {}


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (sums tuple elements)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = int(np.prod([int(d) for d in dims.split(",") if d])) if dims else 1
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum operand bytes per collective kind from (post-SPMD) HLO text.

    Returns {kind: bytes, ..., 'total': bytes}. ``-start`` variants (async
    collectives) are counted; their ``-done`` halves are not (zero operands
    moved twice).
    """
    shapes: dict[str, str] = {}
    pending: list[tuple[str, str]] = []  # (kind, operand names str)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        shapes[m.group("name")] = m.group("type")
        op = m.group("op")
        kind = next(
            (c for c in COLLECTIVE_OPS if op == c or op == c + "-start"), None
        )
        if kind is not None:
            pending.append((kind, m.group("operands")))

    out = {c: 0 for c in COLLECTIVE_OPS}
    opname = re.compile(r"%?([\w.\-]+)")
    for kind, operands in pending:
        for tok in operands.split(","):
            tok = tok.strip()
            mm = _SHAPE_RE.search(tok)
            if mm:  # operand written with inline type
                out[kind] += _shape_bytes(tok)
                continue
            nm = opname.match(tok)
            if nm and nm.group(1) in shapes:
                out[kind] += _shape_bytes(shapes[nm.group(1)])
    out["total"] = sum(out[c] for c in COLLECTIVE_OPS)
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    hbm_bytes_per_chip: float
    coll_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    peak_bytes_per_chip: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time estimate = max of the three overlapped terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * chips) — remat/redundancy waste catcher."""
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else float("nan")

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU at the roofline: useful flops / (chips*peak*step_time)."""
        denom = self.chips * HW_V5E.peak_flops * self.step_time_s
        return self.model_flops / denom if denom else float("nan")

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_bytes_per_chip": self.peak_bytes_per_chip,
        }


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6·N_active·D for training, 2·N_active·D for inference steps.

    D = tokens processed by one step: train/prefill = B*S; decode = B*1.
    """
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per example


def roofline_report(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost: dict,
    coll_bytes_per_chip: float,
    mflops: float,
    hw: Hardware = HW_V5E,
    peak_bytes_per_chip: float = 0.0,
) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        flops_per_chip=flops,
        hbm_bytes_per_chip=nbytes,
        coll_bytes_per_chip=coll_bytes_per_chip,
        compute_s=flops / hw.peak_flops,
        memory_s=nbytes / hw.hbm_bw,
        collective_s=coll_bytes_per_chip / hw.link_bw,
        model_flops=mflops,
        peak_bytes_per_chip=peak_bytes_per_chip,
    )
