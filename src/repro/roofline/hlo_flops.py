"""Per-instruction dot/conv FLOP attribution from HLO text.

``cost_analysis()`` gives one aggregate number; this parser breaks it down by
instruction so the §Perf loop can see WHICH matmuls dominate (and whether the
SPMD partitioner inflated any of them — e.g. a contracting-dim sharding that
forced a replicated matmul).

flops(dot) = 2 * prod(output_shape) * prod(lhs_contracting_dim_sizes)
(batch dims are already part of the output shape).
"""
from __future__ import annotations

import re
from collections import defaultdict

import numpy as np

_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_DOT = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<out>\w+\[[\d,]*\][^\s]*)\s+dot\("
    r"(?P<operands>[^)]*)\)"
    r".*?lhs_contracting_dims=\{(?P<lhs_c>[\d,]*)\}",
)
_DEF = re.compile(r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<type>\w+\[[\d,]*\][^\s]*)\s+(?P<op>[\w\-]+)\(")


def _dims(type_str: str) -> list[int]:
    m = _SHAPE.search(type_str)
    if not m:
        return []
    return [int(x) for x in m.group(2).split(",") if x]


def dot_flops_by_instruction(hlo_text: str) -> list[tuple[str, float, str]]:
    """[(instruction name, flops, fingerprint)] for every dot, descending."""
    shapes: dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF.match(line)
        if m:
            shapes[m.group("name")] = m.group("type")

    out = []
    opname = re.compile(r"%?([\w.\-]+)")
    for line in hlo_text.splitlines():
        m = _DOT.match(line)
        if not m:
            continue
        out_dims = _dims(m.group("out"))
        ops = [t.strip() for t in m.group("operands").split(",")]
        lhs_name = opname.match(ops[0]).group(1) if ops else ""
        lhs_type = shapes.get(lhs_name, ops[0] if ops else "")
        lhs_dims = _dims(lhs_type)
        c_idx = [int(i) for i in m.group("lhs_c").split(",") if i]
        contract = int(np.prod([lhs_dims[i] for i in c_idx])) if lhs_dims else 1
        flops = 2.0 * float(np.prod(out_dims) if out_dims else 0) * contract
        fingerprint = f"{lhs_type} . rhs -> {m.group('out')}"
        out.append((m.group("name"), flops, fingerprint))
    out.sort(key=lambda t: -t[1])
    return out


def dot_flops_summary(hlo_text: str, top: int = 12) -> dict:
    per = dot_flops_by_instruction(hlo_text)
    total = sum(f for _, f, _ in per)
    by_shape: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    for _, f, fp in per:
        by_shape[fp] += f
        counts[fp] += 1
    rows = sorted(by_shape.items(), key=lambda kv: -kv[1])[:top]
    return {
        "total_dot_flops": total,
        "num_dots": len(per),
        "top": [
            {"shape": fp, "flops": f, "count": counts[fp], "frac": f / total if total else 0}
            for fp, f in rows
        ],
    }


# ------------------------------------------------------- kernel-level bytes

_ENTRY_RE = re.compile(r"^ENTRY\s")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<type>\([^=]*?\)|[\w\[\],{}/ ]+?)\s+(?P<op>[\w\-]+)\("
)
_SHAPE_ALL = re.compile(r"(\w+)\[([\d,]*)\]")
_FREE_OPS = {
    # no HBM traffic of their own (aliasing / metadata / layout-free)
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
    # async -done halves: traffic charged on the -start op
    "all-reduce-done", "all-gather-done", "collective-permute-done",
    "async-done", "copy-done",
}
_DTYPE_B = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_ALL.findall(type_str):
        if dt not in _DTYPE_B:
            continue
        n = int(np.prod([int(d) for d in dims.split(",") if d])) if dims else 1
        total += n * _DTYPE_B[dt]
    return total


# ops whose operands/outputs genuinely stream through HBM on TPU (a tiled
# matmul / reduce / data-movement kernel); pure elementwise chains fuse into
# their neighbors' loads/stores and move no extra HBM bytes.
_HEAVY_OPS = {
    "dot", "convolution", "reduce", "reduce-window", "sort", "gather",
    "scatter", "dynamic-slice", "dynamic-update-slice", "fusion", "pad",
    "concatenate", "reverse", "cumsum", "rng", "rng-bit-generator",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "copy", "copy-start", "select-and-scatter",
    "triangular-solve", "cholesky", "fft",
}


_COMP_RE = re.compile(r"^%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$|^%?([\w.\-]+)\s+\(")
_LAYOUT_ONLY = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "convert", "copy", "transpose", "reshape", "broadcast", "slice",
    "bitcast-convert",
}


def _computation_ops(hlo_text: str) -> dict:
    """computation name -> set of ops inside (for fusion-body inspection)."""
    comps: dict[str, set] = {}
    current = None
    header = re.compile(r"^%?([\w.\-]+)\s*\(.*\)\s*->")
    for line in hlo_text.splitlines():
        h = header.match(line.strip())
        if h and "{" in line:
            current = h.group(1)
            comps[current] = set()
            continue
        if current is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[current].add(m.group("op"))
        if line.strip() == "}":
            current = None
    return comps


def _free_fusions(hlo_text: str) -> set:
    """Fusions whose body is pure layout/convert work.

    XLA:CPU materializes f32 copies of bf16 matmul operands as convert-only
    fusions (no native bf16 dot); a TPU fuses the convert into the operand
    load. Charging them would count the whole KV cache / weight tensor twice
    per matmul in f32 — measured as 60% of decode 'memory' on qwen3-235b.
    """
    comps = _computation_ops(hlo_text)
    return {
        name
        for name, ops in comps.items()
        if ops and ops <= _LAYOUT_ONLY
    }


def _parse_entry(hlo_text: str):
    """Yield (name, type, op, operand names) for ENTRY instructions."""
    in_entry = False
    depth = 0
    opname = re.compile(r"%?([\w.\-]+)")
    for line in hlo_text.splitlines():
        if _ENTRY_RE.match(line):
            in_entry = True
            depth = 0
        if not in_entry:
            continue
        depth += line.count("{") - line.count("}")
        m = _INSTR_RE.match(line)
        if m:
            op = m.group("op")
            operands = []
            paren = line.split(f"{op}(", 1)
            if len(paren) == 2:
                for tok in paren[1].split(")", 1)[0].split(","):
                    tok = tok.strip()
                    nm = opname.match(tok)
                    if nm:
                        operands.append(nm.group(1))
            yield m.group("name"), m.group("type"), op, operands
        if in_entry and depth <= 0 and "}" in line and not _ENTRY_RE.match(line):
            break


def entry_bytes(hlo_text: str, *, fusion_aware: bool = True) -> int:
    """HBM traffic estimate of the ENTRY computation.

    fusion_aware=True models TPU fusion: only HEAVY ops (matmuls, reduces,
    data movement, collectives) stream operands+outputs through HBM; a pure
    elementwise/layout op is charged only when its result feeds >1 consumer
    (it must materialize once) — otherwise it fuses into its neighbor.
    fusion_aware=False charges every top-level instruction (kernel-per-op,
    XLA:CPU-like; pessimistic upper bound).
    """
    instrs = list(_parse_entry(hlo_text))
    shapes = {n: t for n, t, _, _ in instrs}
    if not fusion_aware:
        total = 0
        for _, t, op, operands in instrs:
            if op in _FREE_OPS:
                continue
            total += _type_bytes(t)
            total += sum(_type_bytes(shapes[o]) for o in operands if o in shapes)
        return total

    consumers: dict[str, int] = {}
    for _, _, op, operands in instrs:
        for o in operands:
            consumers[o] = consumers.get(o, 0) + 1
    free_fus = _free_fusions(hlo_text)
    calls_re = re.compile(r"calls=%?([\w.\-]+)")
    fusion_calls: dict[str, str] = {}
    fusion_first_operand: dict[str, str] = {}
    for n, t, op, operands in instrs:
        if op == "fusion":
            if operands:
                fusion_first_operand[n] = operands[0]
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m and m.group("op") == "fusion":
            c = calls_re.search(line)
            if c:
                fusion_calls[m.group("name")] = c.group(1)

    def operand_bytes(o: str) -> int:
        # look through convert-only fusions: a TPU reads the ORIGINAL dtype
        # and converts in the matmul's operand pipeline. Charge min(fusion
        # output, original input): slice-like bodies read less than their
        # input, convert bodies less than their f32 output.
        best = _type_bytes(shapes.get(o, ""))
        seen = 0
        while (
            o in fusion_calls
            and fusion_calls[o] in free_fus
            and o in fusion_first_operand
            and seen < 4
        ):
            o = fusion_first_operand[o]
            b = _type_bytes(shapes.get(o, ""))
            if b:
                best = min(best, b) if best else b
            seen += 1
        return best

    total = 0
    for name, t, op, operands in instrs:
        if op in _FREE_OPS:
            continue
        if op == "fusion" and fusion_calls.get(name) in free_fus:
            continue  # layout/convert-only fusion: free on TPU
        if op in _HEAVY_OPS:
            total += _type_bytes(t)
            total += sum(operand_bytes(o) for o in operands)
        elif consumers.get(name, 0) > 1:
            total += _type_bytes(t)  # multi-use intermediate materializes once
    return total


def entry_bytes_by_op(hlo_text: str, top: int = 15) -> list[dict]:
    """Top ENTRY instructions by kernel-level bytes (memory-term attribution).

    Groups by (op, output type) fingerprint, same accounting as entry_bytes.
    """
    in_entry = False
    shapes: dict[str, str] = {}
    agg: dict[str, list] = {}
    opname = re.compile(r"%?([\w.\-]+)")
    for line in hlo_text.splitlines():
        if _ENTRY_RE.match(line):
            in_entry = True
        if not in_entry:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        shapes[m.group("name")] = m.group("type")
        op = m.group("op")
        if op in _FREE_OPS:
            continue
        b = _type_bytes(m.group("type"))
        paren = line.split(f"{op}(", 1)
        if len(paren) == 2:
            for tok in paren[1].split(")", 1)[0].split(","):
                tok = tok.strip()
                if _SHAPE_ALL.search(tok):
                    b += _type_bytes(tok)
                    continue
                nm = opname.match(tok)
                if nm and nm.group(1) in shapes:
                    b += _type_bytes(shapes[nm.group(1)])
        key = f"{op} -> {m.group('type').strip()[:80]}"
        if key not in agg:
            agg[key] = [0, 0]
        agg[key][0] += b
        agg[key][1] += 1
    rows = sorted(agg.items(), key=lambda kv: -kv[1][0])[:top]
    total = sum(v[0] for v in agg.values())
    return [
        {"op": k, "bytes": v[0], "count": v[1], "frac": v[0] / total if total else 0}
        for k, v in rows
    ]
