"""Logical-axis -> PartitionSpec rules (MaxText-style).

Every parameter carries logical axis names (``ParamDef.axes``); a ``MeshRules``
table maps each logical axis to an ordered preference list of mesh axes. Spec
construction walks the tensor's axes, assigning the first mesh axis that (a)
is still unused by this tensor and (b) divides the dimension size. Anything
else stays replicated — so one rule table serves every architecture (GQA with
4 KV heads simply leaves ``kv_heads`` replicated on a 16-way model axis).

Two standard tables:
  DEFAULT_RULES — TP on 'model', batch on ('pod','data'); params replicated
                  across 'data' (pure DP — small/medium configs).
  FSDP_RULES    — adds ZeRO-3: the 'embed' axis of every weight is sharded on
                  'data' too, so optimizer state scales with 1/(data*model).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import common


@dataclass(frozen=True)
class MeshRules:
    """Ordered logical-axis -> candidate-mesh-axes mapping."""

    rules: dict[str, tuple[str, ...]]
    # logical axes whose mesh assignment may be a *tuple* of axes (megasharding)
    batch_axes: tuple[str, ...] = ("pod", "data")

    def candidates(self, logical: Optional[str]) -> tuple[str, ...]:
        if logical is None:
            return ()
        return self.rules.get(logical, ())


# TP everything wide on 'model'; experts EP on 'model'; batch on ('pod','data').
DEFAULT_RULES = MeshRules(
    rules={
        "vocab": ("model",),
        "mlp": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),  # falls back to replicated when not divisible
        "experts": ("model",),
        "inner": ("model",),
        "ssm_heads": ("model",),
        "frontend": (),
        "embed": (),
        "head_dim": (),
        "layers": (),
        "batch": ("pod", "data"),
        "seq": (),
        "kv_seq": (),
    }
)

# ZeRO-3 / FSDP: additionally shard the 'embed' (contracting) axis on 'data'.
FSDP_RULES = replace(
    DEFAULT_RULES,
    rules={**DEFAULT_RULES.rules, "embed": ("data",), "layers": ()},
)

# Sequence-parallel activations (long-context): shard seq on 'data'.
SP_RULES = replace(
    DEFAULT_RULES,
    rules={**DEFAULT_RULES.rules, "seq": ("data",), "kv_seq": ("data",)},
)


def logical_to_spec(
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: MeshRules,
) -> P:
    """Greedy assignment: first fitting unused mesh axis per tensor dim."""
    used: set[str] = set()
    out: list[Any] = []
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for logical, dim in zip(axes, shape):
        # batch axis spans ALL its mesh axes jointly (e.g. ('pod','data'))
        if logical == "batch":
            multi = [a for a in rules.batch_axes if a in mesh_sizes and a not in used]
            prod = int(np.prod([mesh_sizes[a] for a in multi])) if multi else 1
            if multi and dim % prod == 0 and dim >= prod:
                for a in multi:
                    used.add(a)
                out.append(tuple(multi) if len(multi) > 1 else multi[0])
            else:
                out.append(None)
            continue
        assigned = None
        for cand in rules.candidates(logical):
            if cand in used or cand not in mesh_sizes:
                continue
            if dim % mesh_sizes[cand] == 0 and dim >= mesh_sizes[cand]:
                assigned = cand
                used.add(cand)
                break
        out.append(assigned)
    return P(*out)


def param_specs(defs: Any, mesh: Mesh, rules: MeshRules = DEFAULT_RULES) -> Any:
    """ParamDef tree -> PartitionSpec tree."""
    return jax.tree.map(
        lambda d: logical_to_spec(d.axes, d.shape, mesh, rules),
        defs,
        is_leaf=common.is_def,
    )


def param_shardings(defs: Any, mesh: Mesh, rules: MeshRules = DEFAULT_RULES) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_specs(defs, mesh, rules),
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_spec(mesh: Mesh, rules: MeshRules = DEFAULT_RULES) -> P:
    """Spec for a (global_batch, ...) input: batch over ('pod','data')."""
    axes = [a for a in rules.batch_axes if a in mesh.axis_names]
    if not axes:
        return P(None)
    return P(tuple(axes) if len(axes) > 1 else axes[0])


def activation_specs(
    mesh: Mesh,
    rules: MeshRules = DEFAULT_RULES,
    *,
    seq_sharded: bool = False,
) -> dict[str, P]:
    """Named activation specs consumed by the step factories."""
    b = batch_spec(mesh, rules)
    bax = b[0] if len(b) else None
    seq = None
    if seq_sharded:
        # long-context: batch=1 -> put the sequence on the data axis instead
        seq_axes = [a for a in rules.batch_axes if a in mesh.axis_names and a != "pod"]
        seq = seq_axes[0] if seq_axes else None
    return {
        "batch": P(bax),
        "tokens": P(bax, seq),
        "hidden": P(bax, seq, "model" if "model" in mesh.axis_names else None),
        "kv_cache": P(None, bax, seq, "model" if "model" in mesh.axis_names else None, None),
    }


def explain_specs(mesh: Mesh, rules: MeshRules = DEFAULT_RULES) -> tuple:
    """PartitionSpecs for the ExplainEngine's bucketed stage-2 inputs.

    Stage 2 folds the interpolation-step axis into the request batch inside
    ``repro.core.ig.attribute`` (the (B·c, S, D) gradient batch), so sharding
    the leading dim of every engine input — embeds, baseline, aux ids/pos,
    mask — shards the folded (batch × step) axis across the mesh's data
    axes; XLA propagates it through the fold. Feature dims stay replicated:
    the per-position gradient is local to its position.

    Returns the spec tree matching the engine's (embeds, baseline, aux, mask)
    argument tuple.
    """
    b = batch_spec(mesh, rules)
    bax = b[0] if len(b) else None
    return (
        P(bax, None, None),  # embeds (B, S, D)
        P(bax, None, None),  # baseline (B, S, D)
        {"target": P(bax), "pos": P(bax)},  # aux (B,)
        P(bax, None),  # mask (B, S)
    )


def dp_size(mesh: Optional[Mesh], rules: MeshRules = DEFAULT_RULES) -> int:
    """Total data-parallel extent of a mesh under ``rules.batch_axes``.

    This is the divisor every bucket batch must be padded up to before the
    engine can shard it (``batching.plan_buckets(batch_multiple=...)``) —
    the mesh-divisible-padding contract of DESIGN.md §9. Returns 1 for
    ``mesh=None`` (single-device serving).
    """
    if mesh is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = [a for a in rules.batch_axes if a in sizes]
    return int(np.prod([sizes[a] for a in axes])) if axes else 1


def mesh_cache_key(mesh: Optional[Mesh]) -> tuple:
    """Hashable mesh fingerprint for executable-cache keys.

    ``ExplainEngine`` folds this into every cache key so single-device and
    sharded entries coexist in one cache (and a mesh swap can never hand
    back an executable compiled for different device placement). ``()`` for
    ``mesh=None``.
    """
    if mesh is None:
        return ()
    return tuple(zip(mesh.axis_names, mesh.devices.shape))


def explain_shardings(
    mesh: Mesh, *, batch: int, rules: MeshRules = DEFAULT_RULES
) -> Optional[tuple]:
    """NamedShardings for ``explain_specs``, or None when the bucket's batch
    does not divide the mesh's data axes.

    None is a *fallback the serving path is not supposed to reach*: the
    engine pads every bucket batch up to a multiple of ``dp_size`` at plan
    time (DESIGN.md §9), so a None here at serving time means mesh-divisible
    padding was bypassed — ``ExplainEngine`` serves the bucket replicated and
    counts it in ``EngineStats.mesh_fallbacks`` instead of failing.
    """
    dp = dp_size(mesh, rules)
    if dp <= 1 or batch % dp != 0:
        return None
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        explain_specs(mesh, rules),
        is_leaf=lambda x: isinstance(x, P),
    )


def explain_arg_shardings(
    mesh: Mesh, args: Any, rules: MeshRules = DEFAULT_RULES
) -> Optional[Any]:
    """Per-bucket rule resolution for an *arbitrary* engine argument tree.

    The fixed-m call takes exactly the 4-tuple ``explain_specs`` describes,
    but the adaptive start/hop executables carry extra leaves (the
    materialized ``Schedule``, the resumable ``IGState``). This resolves a
    NamedSharding per leaf with one rule: a leaf whose leading dim is the
    (dp-divisible) bucket batch shards on the data axes, everything else —
    scalars, shared (m,) schedules — replicates. Returns None when the mesh
    has no data parallelism or the tree's batch dim does not divide it
    (same fallback contract as ``explain_shardings``).
    """
    dp = dp_size(mesh, rules)
    if dp <= 1:
        return None
    leaves = jax.tree.leaves(args)
    batch = max((l.shape[0] for l in leaves if getattr(l, "ndim", 0) >= 1), default=0)
    if batch == 0 or batch % dp != 0:
        return None
    b = batch_spec(mesh, rules)
    bax = b[0] if len(b) else None

    def one(x):
        if getattr(x, "ndim", 0) >= 1 and x.shape[0] == batch:
            return NamedSharding(mesh, P(bax, *([None] * (x.ndim - 1))))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, args)


def explain_reduce_specs(mesh: Mesh, rules: MeshRules = DEFAULT_RULES) -> dict:
    """shard_map-friendly specs for the engine's per-row reductions.

    Every reduction the serving path takes a decision on — the completeness
    gap δ, and IDGI's inner products ⟨g, g⟩ / ⟨g, x − x′⟩ — contracts over
    *feature* axes only, which stay replicated under ``explain_specs``. Under
    ``shard_map`` along the folded (batch × step) axis each device therefore
    reduces its own rows with no collective, in the same order as the
    unsharded program: device-local reduction ⇒ bit-identical δ ⇒ identical
    adaptive escalation traces (DESIGN.md §9). These specs name that layout:

      folded      — a (B·c, *F) stage-2 gradient block: rows on data axes.
      row_scalar  — a (B,) per-row reduction output (δ, ⟨g,g⟩, ⟨g,x−x′⟩).
    """
    b = batch_spec(mesh, rules)
    bax = b[0] if len(b) else None
    return {"folded": P(bax, None), "row_scalar": P(bax)}


def spec_for_batch_tree(batch: Any, mesh: Mesh, rules: MeshRules = DEFAULT_RULES, *, seq_sharded: bool = False) -> Any:
    """PartitionSpec tree matching a batch dict: dim0 = batch, rest replicated.

    When ``seq_sharded`` (long-context decode with batch=1), dim1 of rank>=2
    inputs is sharded on 'data' instead of the batch dim.
    """
    b = batch_spec(mesh, rules)

    def one(x):
        ndim = len(x.shape)
        if ndim == 0:
            return P()
        if seq_sharded and ndim >= 2:
            seq_axes = [a for a in ("data",) if a in mesh.axis_names]
            mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            if seq_axes and x.shape[1] % mesh_sizes[seq_axes[0]] == 0:
                return P(None, seq_axes[0], *([None] * (ndim - 2)))
        bb = b[0] if len(b) else None
        mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        nb = int(np.prod([mesh_sizes[a] for a in (bb if isinstance(bb, tuple) else (bb,))])) if bb else 1
        if x.shape[0] % max(nb, 1) == 0 and x.shape[0] >= nb:
            return P(bb, *([None] * (ndim - 1)))
        return P(*([None] * ndim))

    return jax.tree.map(one, batch)
