"""Logical-axis -> PartitionSpec rules (MaxText-style).

Every parameter carries logical axis names (``ParamDef.axes``); a ``MeshRules``
table maps each logical axis to an ordered preference list of mesh axes. Spec
construction walks the tensor's axes, assigning the first mesh axis that (a)
is still unused by this tensor and (b) divides the dimension size. Anything
else stays replicated — so one rule table serves every architecture (GQA with
4 KV heads simply leaves ``kv_heads`` replicated on a 16-way model axis).

Two standard tables:
  DEFAULT_RULES — TP on 'model', batch on ('pod','data'); params replicated
                  across 'data' (pure DP — small/medium configs).
  FSDP_RULES    — adds ZeRO-3: the 'embed' axis of every weight is sharded on
                  'data' too, so optimizer state scales with 1/(data*model).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import common


@dataclass(frozen=True)
class MeshRules:
    """Ordered logical-axis -> candidate-mesh-axes mapping."""

    rules: dict[str, tuple[str, ...]]
    # logical axes whose mesh assignment may be a *tuple* of axes (megasharding)
    batch_axes: tuple[str, ...] = ("pod", "data")

    def candidates(self, logical: Optional[str]) -> tuple[str, ...]:
        if logical is None:
            return ()
        return self.rules.get(logical, ())


# TP everything wide on 'model'; experts EP on 'model'; batch on ('pod','data').
DEFAULT_RULES = MeshRules(
    rules={
        "vocab": ("model",),
        "mlp": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),  # falls back to replicated when not divisible
        "experts": ("model",),
        "inner": ("model",),
        "ssm_heads": ("model",),
        "frontend": (),
        "embed": (),
        "head_dim": (),
        "layers": (),
        "batch": ("pod", "data"),
        "seq": (),
        "kv_seq": (),
    }
)

# ZeRO-3 / FSDP: additionally shard the 'embed' (contracting) axis on 'data'.
FSDP_RULES = replace(
    DEFAULT_RULES,
    rules={**DEFAULT_RULES.rules, "embed": ("data",), "layers": ()},
)

# Sequence-parallel activations (long-context): shard seq on 'data'.
SP_RULES = replace(
    DEFAULT_RULES,
    rules={**DEFAULT_RULES.rules, "seq": ("data",), "kv_seq": ("data",)},
)


def logical_to_spec(
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: MeshRules,
) -> P:
    """Greedy assignment: first fitting unused mesh axis per tensor dim."""
    used: set[str] = set()
    out: list[Any] = []
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for logical, dim in zip(axes, shape):
        # batch axis spans ALL its mesh axes jointly (e.g. ('pod','data'))
        if logical == "batch":
            multi = [a for a in rules.batch_axes if a in mesh_sizes and a not in used]
            prod = int(np.prod([mesh_sizes[a] for a in multi])) if multi else 1
            if multi and dim % prod == 0 and dim >= prod:
                for a in multi:
                    used.add(a)
                out.append(tuple(multi) if len(multi) > 1 else multi[0])
            else:
                out.append(None)
            continue
        assigned = None
        for cand in rules.candidates(logical):
            if cand in used or cand not in mesh_sizes:
                continue
            if dim % mesh_sizes[cand] == 0 and dim >= mesh_sizes[cand]:
                assigned = cand
                used.add(cand)
                break
        out.append(assigned)
    return P(*out)


def param_specs(defs: Any, mesh: Mesh, rules: MeshRules = DEFAULT_RULES) -> Any:
    """ParamDef tree -> PartitionSpec tree."""
    return jax.tree.map(
        lambda d: logical_to_spec(d.axes, d.shape, mesh, rules),
        defs,
        is_leaf=common.is_def,
    )


def param_shardings(defs: Any, mesh: Mesh, rules: MeshRules = DEFAULT_RULES) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_specs(defs, mesh, rules),
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_spec(mesh: Mesh, rules: MeshRules = DEFAULT_RULES) -> P:
    """Spec for a (global_batch, ...) input: batch over ('pod','data')."""
    axes = [a for a in rules.batch_axes if a in mesh.axis_names]
    if not axes:
        return P(None)
    return P(tuple(axes) if len(axes) > 1 else axes[0])


def activation_specs(
    mesh: Mesh,
    rules: MeshRules = DEFAULT_RULES,
    *,
    seq_sharded: bool = False,
) -> dict[str, P]:
    """Named activation specs consumed by the step factories."""
    b = batch_spec(mesh, rules)
    bax = b[0] if len(b) else None
    seq = None
    if seq_sharded:
        # long-context: batch=1 -> put the sequence on the data axis instead
        seq_axes = [a for a in rules.batch_axes if a in mesh.axis_names and a != "pod"]
        seq = seq_axes[0] if seq_axes else None
    return {
        "batch": P(bax),
        "tokens": P(bax, seq),
        "hidden": P(bax, seq, "model" if "model" in mesh.axis_names else None),
        "kv_cache": P(None, bax, seq, "model" if "model" in mesh.axis_names else None, None),
    }


def explain_specs(mesh: Mesh, rules: MeshRules = DEFAULT_RULES) -> tuple:
    """PartitionSpecs for the ExplainEngine's bucketed stage-2 inputs.

    Stage 2 folds the interpolation-step axis into the request batch inside
    ``repro.core.ig.attribute`` (the (B·c, S, D) gradient batch), so sharding
    the leading dim of every engine input — embeds, baseline, aux ids/pos,
    mask — shards the folded (batch × step) axis across the mesh's data
    axes; XLA propagates it through the fold. Feature dims stay replicated:
    the per-position gradient is local to its position.

    Returns the spec tree matching the engine's (embeds, baseline, aux, mask)
    argument tuple.
    """
    b = batch_spec(mesh, rules)
    bax = b[0] if len(b) else None
    return (
        P(bax, None, None),  # embeds (B, S, D)
        P(bax, None, None),  # baseline (B, S, D)
        {"target": P(bax), "pos": P(bax)},  # aux (B,)
        P(bax, None),  # mask (B, S)
    )


def explain_shardings(
    mesh: Mesh, *, batch: int, rules: MeshRules = DEFAULT_RULES
) -> Optional[tuple]:
    """NamedShardings for ``explain_specs``, or None when the bucket's batch
    does not divide the mesh's data axes (replicate rather than error — small
    buckets on big meshes)."""
    axes = [a for a in rules.batch_axes if a in mesh.axis_names]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    prod = int(np.prod([sizes[a] for a in axes])) if axes else 1
    if prod <= 1 or batch % prod != 0:
        return None
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        explain_specs(mesh, rules),
        is_leaf=lambda x: isinstance(x, P),
    )


def spec_for_batch_tree(batch: Any, mesh: Mesh, rules: MeshRules = DEFAULT_RULES, *, seq_sharded: bool = False) -> Any:
    """PartitionSpec tree matching a batch dict: dim0 = batch, rest replicated.

    When ``seq_sharded`` (long-context decode with batch=1), dim1 of rank>=2
    inputs is sharded on 'data' instead of the batch dim.
    """
    b = batch_spec(mesh, rules)

    def one(x):
        ndim = len(x.shape)
        if ndim == 0:
            return P()
        if seq_sharded and ndim >= 2:
            seq_axes = [a for a in ("data",) if a in mesh.axis_names]
            mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            if seq_axes and x.shape[1] % mesh_sizes[seq_axes[0]] == 0:
                return P(None, seq_axes[0], *([None] * (ndim - 2)))
        bb = b[0] if len(b) else None
        mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        nb = int(np.prod([mesh_sizes[a] for a in (bb if isinstance(bb, tuple) else (bb,))])) if bb else 1
        if x.shape[0] % max(nb, 1) == 0 and x.shape[0] >= nb:
            return P(bb, *([None] * (ndim - 1)))
        return P(*([None] * ndim))

    return jax.tree.map(one, batch)
