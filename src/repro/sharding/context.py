"""Activation sharding constraints (MaxText-style logical constraints).

Why this exists: with FSDP-sharded weights and batch-sharded activations the
SPMD partitioner may legally choose to REPLICATE activations and all-reduce
partial sums instead of all-gathering weights — measured on llama3-8b
train_4k as a 1.1 TB/chip all-reduce and full-global-batch matmuls on every
chip. Pinning activations with ``with_sharding_constraint`` removes that
degree of freedom.

Model code is mesh-agnostic: it calls ``constrain(x, "batch", "seq",
"model")`` with LOGICAL names; the active ``ActivationPolicy`` (installed by
the cell builder / launcher via ``activation_sharding(mesh, ...)``) maps them
to mesh axes, checks divisibility, and applies the constraint. With no
policy installed (unit tests, single-device training) it is a no-op.
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

_POLICY: contextvars.ContextVar[Optional["ActivationPolicy"]] = contextvars.ContextVar(
    "activation_policy", default=None
)


@dataclass(frozen=True)
class ActivationPolicy:
    mapping: dict  # logical name -> tuple of mesh axis names
    sizes: dict  # mesh axis name -> size


def make_policy(mesh: Mesh, *, seq_sharded: bool = False) -> ActivationPolicy:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
    return ActivationPolicy(
        mapping={
            "batch": () if seq_sharded else batch_axes,
            "seq": (("data",) if "data" in sizes else ()) if seq_sharded else (),
            "model": ("model",) if "model" in sizes else (),
        },
        sizes=sizes,
    )


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, *, seq_sharded: bool = False):
    token = _POLICY.set(make_policy(mesh, seq_sharded=seq_sharded))
    try:
        yield
    finally:
        _POLICY.reset(token)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Pin ``x``'s sharding by logical dim names; no-op without a policy.

    ``logical`` has one entry per dim: "batch" / "seq" / "model" / None.
    Indivisible dims fall back to replicated (never an error).
    """
    pol = _POLICY.get()
    if pol is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    used: set[str] = set()
    spec = []
    nontrivial = False
    for dim, name in zip(x.shape, logical):
        axes = tuple(
            a for a in pol.mapping.get(name, ()) if a in pol.sizes and a not in used
        )
        prod = int(np.prod([pol.sizes[a] for a in axes])) if axes else 1
        if axes and dim % prod == 0 and dim >= prod:
            used.update(axes)
            spec.append(axes if len(axes) > 1 else axes[0])
            nontrivial = True
        else:
            spec.append(None)
    if not nontrivial:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))
