"""PartitionSpecs for runtime trees (TrainState, KV caches) by leaf path.

Cache/state leaf names are stable model contracts ("k", "v", "xk", "xv",
"state", "conv", "len"), so specs pattern-match on the path — more robust
than rank heuristics and independent of which arch produced the tree.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import common
from repro.sharding.partition import MeshRules, DEFAULT_RULES, param_specs, batch_spec


def train_state_specs(defs: Any, mesh: Mesh, rules: MeshRules, state_like: Any) -> Any:
    """Specs for TrainState(params, OptState(step, m, v), err)."""
    pspecs = param_specs(defs, mesh, rules)
    opt = type(state_like.opt)(step=P(), m=pspecs, v=pspecs)
    err = pspecs if state_like.err is not None else None
    return type(state_like)(params=pspecs, opt=opt, err=err)


def _divisible(dim: int, mesh: Mesh, axis: str) -> bool:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return axis in sizes and dim % sizes[axis] == 0 and dim >= sizes[axis]


def cache_specs(
    cache: Any, mesh: Mesh, rules: MeshRules = DEFAULT_RULES, *, seq_sharded: bool = False
) -> Any:
    """Specs for a decode cache tree (lm.init_cache structure).

    KV leaves: (periods?, B, S, KH, HD) — batch on ('pod','data'), KH on
    'model' when divisible; long-context (seq_sharded) moves S onto 'data'.
    SSM leaves: state (periods?, B, H, P, N) / conv (periods?, B, W, di) —
    H / di on 'model'.
    """
    b = batch_spec(mesh, rules)
    bax = b[0] if len(b) else None

    def leaf_spec(path, x) -> P:
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = x.shape
        nd = len(shape)
        if name == "len":
            return P()
        scanned = nd >= 1 and name in ("k", "v", "xk", "xv", "state", "conv") and nd in (4, 5)
        # leading periods axis present when the leaf sits under cache["layers"]
        has_periods = any(
            (hasattr(p, "key") and p.key == "layers") for p in path
        )
        off = 1 if has_periods else 0
        spec: list[Any] = [None] * nd
        if name in ("k", "v", "xk", "xv"):
            # (periods?, B, S, KH, HD)
            B, S, KH = shape[off], shape[off + 1], shape[off + 2]
            if bax is not None and not seq_sharded and _div_multi(B, mesh, bax):
                spec[off] = bax
            if seq_sharded and _divisible(S, mesh, "data"):
                spec[off + 1] = "data"
            if _divisible(KH, mesh, "model"):
                spec[off + 2] = "model"
            return P(*spec)
        if name == "state":
            # (periods?, B, H, P, N)
            B, H = shape[off], shape[off + 1]
            if bax is not None and _div_multi(B, mesh, bax):
                spec[off] = bax
            if _divisible(H, mesh, "model"):
                spec[off + 1] = "model"
            return P(*spec)
        if name == "conv":
            # (periods?, B, W, di)
            B, di = shape[off], shape[-1]
            if bax is not None and _div_multi(B, mesh, bax):
                spec[off] = bax
            if _divisible(di, mesh, "model"):
                spec[-1] = "model"
            return P(*spec)
        return P(*spec)

    def _div_multi(dim: int, mesh: Mesh, ax) -> bool:
        axes = ax if isinstance(ax, tuple) else (ax,)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        prod = int(np.prod([sizes[a] for a in axes]))
        return dim % prod == 0 and dim >= prod

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def to_shardings(specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
