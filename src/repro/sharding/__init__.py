from repro.sharding.partition import (
    MeshRules,
    DEFAULT_RULES,
    FSDP_RULES,
    SP_RULES,
    logical_to_spec,
    param_specs,
    param_shardings,
    batch_spec,
    activation_specs,
    explain_specs,
    explain_shardings,
    spec_for_batch_tree,
)
from repro.sharding.trees import train_state_specs, cache_specs, to_shardings

__all__ = [
    "MeshRules",
    "DEFAULT_RULES",
    "FSDP_RULES",
    "SP_RULES",
    "logical_to_spec",
    "param_specs",
    "param_shardings",
    "batch_spec",
    "activation_specs",
    "explain_specs",
    "explain_shardings",
    "spec_for_batch_tree",
    "train_state_specs",
    "cache_specs",
    "to_shardings",
]
