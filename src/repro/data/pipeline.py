"""Synthetic deterministic data pipeline.

Design (mirrors a production tokenized-shard reader):
  * deterministic: batch for global step s is a pure function of (seed, s) —
    restart/resume replays identically, elastic re-shards deterministically;
  * per-host sharding: each host materializes only its slice of the global
    batch (``host_index/host_count``), the global array is assembled by the
    runtime via ``jax.make_array_from_process_local_data`` in multi-host runs
    (single-process here: the slice is the whole batch);
  * prefetch: a depth-2 background thread keeps the next batches ready so the
    accelerator never waits on host-side generation (straggler mitigation for
    the input side).

The synthetic distribution is a mixture of Zipf-like token draws and a copy
task so the LM loss has learnable structure (used by examples/train).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    copy_frac: float = 0.25  # fraction of the sequence that is a copy task


class SyntheticLM:
    """Deterministic (seed, step) -> batch generator, host-sharded."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.host_count == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.host_count
        # Zipf-ish token marginal, fixed by seed
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1)
        p = 1.0 / ranks**1.1
        self._p = p / p.sum()
        self._perm = rng.permutation(cfg.vocab_size)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_index])
        )
        B, S = self.local_batch, cfg.seq_len
        toks = self._perm[
            rng.choice(cfg.vocab_size, size=(B, S + 1), p=self._p)
        ].astype(np.int32)
        # copy task: second half of a prefix window repeats the first half
        w = int(S * cfg.copy_frac)
        if w > 1:
            toks[:, w : 2 * w] = toks[:, :w]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class _Prefetcher:
    """Depth-N background prefetch over any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


def make_pipeline(
    cfg: DataConfig, *, start_step: int = 0, prefetch: int = 2
) -> Iterator[dict[str, np.ndarray]]:
    """Resumable prefetching pipeline starting at ``start_step``."""
    ds = SyntheticLM(cfg)

    def gen():
        step = start_step
        while True:
            yield ds.batch_at(step)
            step += 1

    return _Prefetcher(gen(), depth=prefetch) if prefetch else gen()
