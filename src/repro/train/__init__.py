from repro.train.step import TrainConfig, TrainState, make_train_step, make_train_state

__all__ = ["TrainConfig", "TrainState", "make_train_step", "make_train_state"]
