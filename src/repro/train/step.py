"""train_step factory: microbatch grad-accum, remat, mixed precision,
optional int8 gradient compression on the DP all-reduce.

The returned step is a pure function ``(state, batch) -> (state, metrics)``
suitable for ``jax.jit`` with in/out shardings from ``repro.sharding`` —
the dry-run lowers exactly this function for train_* cells.

Distributed-optimization tricks wired here:
  * grad accumulation over microbatches via ``lax.scan`` (keeps peak
    activation memory at one microbatch; XLA overlaps the per-microbatch
    reduce-scatter with the next microbatch's compute);
  * remat (``jax.checkpoint``) of each layer period — activation memory
    O(sqrt-ish) for the 62–94 layer configs;
  * int8 gradient compression + error feedback: the DP all-reduce moves 4x
    fewer bytes; the quantization error is carried into the next step
    (standard EF-SGD trick, exact in expectation).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.registry import Model
from repro.optim import AdamWConfig, OptState, adamw_init, adamw_update


@dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1  # grad-accum factor (divides the per-step batch)
    remat: bool = True
    grad_compression: bool = False  # int8 + error feedback
    compute_dtype: str = "bfloat16"


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    err: Optional[Any]  # error-feedback buffers (grad compression) or None


def make_train_state(cfg: ArchConfig, tcfg: TrainConfig, key: jax.Array) -> TrainState:
    model = Model(cfg)
    params = model.init(key)
    err = (
        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if tcfg.grad_compression
        else None
    )
    return TrainState(params, adamw_init(params), err)


def abstract_train_state(cfg: ArchConfig, tcfg: TrainConfig) -> TrainState:
    """ShapeDtypeStruct state for the dry-run (no allocation)."""
    model = Model(cfg)
    params = model.abstract_params()
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    opt = OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        m=jax.tree.map(f32, params),
        v=jax.tree.map(f32, params),
    )
    err = jax.tree.map(f32, params) if tcfg.grad_compression else None
    return TrainState(params, opt, err)


# ------------------------------------------------------- grad compression


def _quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads: Any, err: Any) -> tuple[Any, Any]:
    """int8-quantize (grad + carried error); return (dequantized, new error).

    The all-reduce in the surrounding pjit moves the int8 payload; we model
    that here by quantize->dequantize with error feedback so numerics match
    what the collective would deliver.
    """

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize_int8(g32)
        deq = q.astype(jnp.float32) * scale
        return deq, g32 - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(treedef, [o[0] for o in out]),
        jax.tree.unflatten(treedef, [o[1] for o in out]),
    )


# ------------------------------------------------------------- step factory


def make_train_step(
    cfg: ArchConfig, tcfg: TrainConfig
) -> Callable[[TrainState, dict], tuple[TrainState, dict]]:
    model = Model(cfg)

    def loss_fn(params, mb):
        return model.loss(params, mb, remat=tcfg.remat)

    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        n_mb = tcfg.microbatches
        if n_mb > 1:
            # (B, ...) -> (n_mb, B/n_mb, ...): scan accumulates grads
            def split(x):
                return x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def accum(carry, mb):
                loss, grads = grad_fn(state.params, mb)
                tot_loss, tot_grads = carry
                return (
                    tot_loss + loss,
                    jax.tree.map(lambda a, g: a + g.astype(jnp.float32), tot_grads, grads),
                ), None

            zero = (
                jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params),
            )
            from repro.models.common import scan_or_unroll
            (loss, grads), _ = scan_or_unroll(accum, zero, mbs)
            loss = loss / n_mb
            grads = jax.tree.map(lambda g: g / n_mb, grads)
        else:
            loss, grads = grad_fn(state.params, batch)

        err = state.err
        if tcfg.grad_compression:
            grads, err = compress_grads(grads, err)

        new_params, new_opt, metrics = adamw_update(
            tcfg.optimizer, grads, state.opt, state.params
        )
        metrics = {"loss": loss, **metrics}
        return TrainState(new_params, new_opt, err), metrics

    return train_step
