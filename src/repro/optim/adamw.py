"""AdamW + cosine LR schedule + global-norm clipping (pytree-native).

Built here rather than imported (substrate requirement). The optimizer state
mirrors the parameter tree, so the same ``param_specs`` PartitionSpecs shard
it — with FSDP rules the m/v moments scale as 1/(data*model) per device.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jax.Array  # () int32
    m: Any  # first-moment tree
    v: Any  # second-moment tree


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac * lr."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Any, max_norm: float) -> tuple[Any, jax.Array]:
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), gn


def adamw_init(params: Any) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_update(
    cfg: AdamWConfig, grads: Any, state: OptState, params: Any
) -> tuple[Any, OptState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * g32
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        # decoupled weight decay only on >=2D weights (skip norms/biases)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        p_new = p32 - lr * (delta + wd * p32)
        return p_new.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step, new_m, new_v), metrics
