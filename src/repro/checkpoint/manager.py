"""Fault-tolerant sharded checkpointing.

Layout (one directory per step):
    <dir>/step_000123.tmp-<nonce>/   — written first
        shard_00000.npz ...          — leaves, chunked ~512MB per shard file
        manifest.json                — treedef, leaf->shard map, sha256 per shard
    <dir>/step_000123/               — atomic rename when complete

Guarantees exercised by tests/test_checkpoint.py:
  * atomicity: a crash mid-write leaves only .tmp dirs, never a half-valid
    step dir; restore ignores .tmp;
  * integrity: per-shard sha256 in the manifest; a corrupted shard fails
    validation and restore falls back to the previous step;
  * resume: ``latest_step`` picks the newest *valid* checkpoint;
  * async save: ``CheckpointManager(save_async=True)`` hands the host copy to
    a background thread (training continues; ``wait()`` joins).

Multi-host note: each host writes only the shards of its addressable data
(here single-process = everything); the manifest records the global treedef.
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import os
import shutil
import tempfile
import threading
import time
from typing import Any, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"
_SHARD_BYTES = 512 * 1024 * 1024

# npz cannot store ml_dtypes (bfloat16, fp8); byte-view them and record the
# real dtype in the manifest.
_VIEW_AS = {2: np.uint16, 1: np.uint8, 4: np.uint32}


def _savable(a: np.ndarray) -> tuple[np.ndarray, str]:
    name = a.dtype.name
    try:
        np.dtype(name)  # native numpy dtype?
        if a.dtype.kind != "V":
            return a, name
    except TypeError:
        pass
    return np.ascontiguousarray(a).view(_VIEW_AS[a.dtype.itemsize]), name


def _unview(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if a.dtype.name == dtype_name:
        return a
    import ml_dtypes

    return a.view(getattr(ml_dtypes, dtype_name))


def _tree_paths(tree: Any) -> list[str]:
    paths, _ = zip(*jax.tree.flatten_with_path(tree)) if jax.tree.leaves(tree) else ((), None)
    return [jax.tree_util.keystr(p) for p in paths]


def sha256_file(path: str) -> str:
    """Streaming sha256 hex digest of one file (the manifest's shard hash)."""
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


_sha256 = sha256_file  # internal alias, kept for callers of the old name


@contextlib.contextmanager
def atomic_dir(final: str) -> Iterator[str]:
    """tmp-dir + atomic-rename write discipline, shared with warm-start
    persistence (``serve.warm_state``): yields a temp directory next to
    ``final``; on clean exit it REPLACES ``final`` in one ``os.replace``,
    on exception the temp dir is removed and ``final`` is untouched — a
    crash mid-write can never leave a half-valid directory behind."""
    parent = os.path.dirname(final) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=os.path.basename(final) + ".tmp-", dir=parent)
    try:
        yield tmp
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic on POSIX


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Write a sharded, content-hashed, atomically-renamed checkpoint."""
    final = os.path.join(directory, f"step_{step:08d}")
    with atomic_dir(final) as tmp:
        _write_checkpoint_files(tmp, step, tree)
    return final


def _write_checkpoint_files(tmp: str, step: int, tree: Any) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    names = [f"leaf_{i:05d}" for i in range(len(leaves))]
    # greedy pack leaves into ~_SHARD_BYTES shard files
    shards: list[list[int]] = [[]]
    size = 0
    for i, leaf in enumerate(leaves):
        nbytes = int(np.asarray(jax.eval_shape(lambda: leaf).size)) * np.dtype(leaf.dtype).itemsize
        if size + nbytes > _SHARD_BYTES and shards[-1]:
            shards.append([])
            size = 0
        shards[-1].append(i)
        size += nbytes

    leaf_to_shard = {}
    leaf_dtypes = {}
    shard_hashes = {}
    for si, idxs in enumerate(shards):
        fname = f"shard_{si:05d}.npz"
        arrs = {}
        for i in idxs:
            arr, dtype_name = _savable(np.asarray(leaves[i]))
            arrs[names[i]] = arr
            leaf_dtypes[names[i]] = dtype_name
        np.savez(os.path.join(tmp, fname), **arrs)
        for i in idxs:
            leaf_to_shard[names[i]] = fname
        shard_hashes[fname] = _sha256(os.path.join(tmp, fname))

    manifest = {
        "step": step,
        "num_leaves": len(leaves),
        "leaf_to_shard": leaf_to_shard,
        "leaf_dtypes": leaf_dtypes,
        "shard_hashes": shard_hashes,
        "time": time.time(),
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)


def _validate(path: str) -> bool:
    mpath = os.path.join(path, _MANIFEST)
    if not os.path.exists(mpath):
        return False
    try:
        with open(mpath) as f:
            manifest = json.load(f)
        for fname, digest in manifest["shard_hashes"].items():
            fpath = os.path.join(path, fname)
            if not os.path.exists(fpath) or _sha256(fpath) != digest:
                return False
        return True
    except (json.JSONDecodeError, KeyError, OSError):
        return False


def latest_step(directory: str) -> Optional[int]:
    """Newest step with a *valid* checkpoint (corrupted ones are skipped)."""
    if not os.path.isdir(directory):
        return None
    steps = sorted(
        (
            int(d.split("_")[1])
            for d in os.listdir(directory)
            if d.startswith("step_") and ".tmp-" not in d
        ),
        reverse=True,
    )
    for s in steps:
        if _validate(os.path.join(directory, f"step_{s:08d}")):
            return s
    return None


def restore_checkpoint(directory: str, step: int, like: Any) -> Any:
    """Restore into the structure (and shardings, if jitted) of ``like``."""
    path = os.path.join(directory, f"step_{step:08d}")
    if not _validate(path):
        raise ValueError(f"checkpoint at {path} is missing or corrupt")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    leaves_like, treedef = jax.tree.flatten(like)
    assert manifest["num_leaves"] == len(leaves_like), "tree structure mismatch"
    cache: dict[str, Any] = {}
    out = []
    for i, leaf in enumerate(leaves_like):
        name = f"leaf_{i:05d}"
        fname = manifest["leaf_to_shard"][name]
        if fname not in cache:
            cache[fname] = np.load(os.path.join(path, fname))
        arr = _unview(cache[fname][name], manifest["leaf_dtypes"][name])
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree.unflatten(treedef, out)


class CheckpointManager:
    """keep_n retention + optional async save + resume."""

    def __init__(self, directory: str, *, keep_n: int = 3, save_async: bool = False):
        self.directory = directory
        self.keep_n = keep_n
        self.save_async = save_async
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any) -> None:
        host_tree = jax.tree.map(np.asarray, tree)  # device->host copy now
        if self.save_async:
            self.wait()
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(step, host_tree), daemon=True
            )
            self._thread.start()
        else:
            self._save_and_gc(step, host_tree)

    def _save_and_gc(self, step: int, tree: Any) -> None:
        save_checkpoint(self.directory, step, tree)
        self._gc()

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and ".tmp-" not in d
        )
        for s in steps[: -self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, like: Any) -> tuple[Optional[int], Any]:
        step = latest_step(self.directory)
        if step is None:
            return None, like
        return step, restore_checkpoint(self.directory, step, like)
