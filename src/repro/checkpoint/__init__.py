from repro.checkpoint.manager import (
    CheckpointManager,
    atomic_dir,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
    sha256_file,
)

__all__ = [
    "CheckpointManager",
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "atomic_dir",
    "sha256_file",
]
