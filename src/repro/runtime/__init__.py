from repro.runtime.fault import (
    FaultConfig,
    RetryPolicy,
    StragglerMonitor,
    ElasticMesh,
    run_with_recovery,
)

__all__ = [
    "FaultConfig",
    "RetryPolicy",
    "StragglerMonitor",
    "ElasticMesh",
    "run_with_recovery",
]
