"""Fault tolerance & elasticity for 1000+ node runs.

This module is the control-plane logic; at datacenter scale the *signals*
(node death, slow step) come from the cluster manager / per-host heartbeats,
but the *decisions* — retry, restore, remesh, rescale — are exactly what is
implemented and unit-tested here against simulated failures.

Components:
  RetryPolicy       — bounded exponential backoff for transient step failures.
  StragglerMonitor  — per-step wall-time EWMA; flags steps slower than
                      ``threshold`` x the running mean (the signal used to
                      evict/replace a slow host and to dispatch backup data
                      tasks).
  ElasticMesh       — rebuilds a (pod, data, model) mesh after losing nodes:
                      the data axis shrinks to the largest size the surviving
                      device count supports with model parallelism intact;
                      batch is rescaled checkpoint-consistently.
  run_with_recovery — the driver loop glue: step -> on failure restore from
                      the checkpoint manager and continue (tested with
                      injected failures in tests/test_fault.py).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np


@dataclass(frozen=True)
class FaultConfig:
    max_retries: int = 3
    backoff_base_s: float = 0.1
    backoff_cap_s: float = 30.0
    straggler_threshold: float = 2.0
    straggler_ewma: float = 0.9
    # EWMA seed warmup: the mean seeds from the MEDIAN of the first k
    # observations instead of the first one alone — step 0 is typically a
    # cold-compile step (10–100× steady state) and, because stragglers never
    # update the mean, a first-step seed would leave the monitor blind for
    # the whole run (every steady-state step looks "fast", no straggler can
    # ever exceed threshold × the inflated mean).
    straggler_warmup: int = 3


class RetryPolicy:
    def __init__(self, cfg: FaultConfig):
        self.cfg = cfg

    def __call__(self, fn: Callable, *args, on_retry: Optional[Callable] = None, **kw):
        last = None
        for attempt in range(self.cfg.max_retries + 1):
            try:
                return fn(*args, **kw)
            except Exception as e:  # noqa: BLE001 — transient-fault boundary
                last = e
                if attempt == self.cfg.max_retries:
                    raise
                if on_retry is not None:
                    on_retry(attempt, e)
                time.sleep(
                    min(self.cfg.backoff_base_s * 2**attempt, self.cfg.backoff_cap_s)
                )
        raise last  # unreachable


class StragglerMonitor:
    """EWMA of step wall-time; ``observe`` returns True for straggler steps.

    The first ``cfg.straggler_warmup`` observations are warmup: they are
    collected but never flagged, and the EWMA mean seeds from their MEDIAN.
    A first-observation seed would let a cold-compile step (10–100× steady
    state) poison the mean permanently — stragglers never update the mean,
    so every later step would look fast and the monitor would stay blind.
    """

    def __init__(self, cfg: FaultConfig):
        self.cfg = cfg
        self.mean: Optional[float] = None
        self.flagged: list[int] = []
        self._step = 0
        self._warm: list[float] = []

    def observe(self, wall_s: float) -> bool:
        self._step += 1
        if self.mean is None:
            self._warm.append(wall_s)
            if len(self._warm) >= max(self.cfg.straggler_warmup, 1):
                self.mean = float(np.median(self._warm))
            return False
        is_straggler = wall_s > self.cfg.straggler_threshold * self.mean
        if is_straggler:
            self.flagged.append(self._step)
        else:  # stragglers do not poison the running mean
            a = self.cfg.straggler_ewma
            self.mean = a * self.mean + (1 - a) * wall_s
        return is_straggler


@dataclass
class ElasticMesh:
    """Elastic remeshing after node loss.

    ``model_size`` is preserved (TP groups cannot shrink without resharding
    weights); the data axis absorbs the loss. Global batch is rescaled to
    keep per-device batch constant, and the caller replays data from the last
    checkpoint step so sample order stays deterministic.
    """

    model_size: int
    data_size: int
    pod_size: int = 1

    @property
    def device_count(self) -> int:
        return self.model_size * self.data_size * self.pod_size

    def after_loss(self, surviving_devices: int) -> "ElasticMesh":
        if surviving_devices >= self.device_count:
            return self
        per_pod = surviving_devices // max(self.pod_size, 1)
        new_data = per_pod // self.model_size
        # drop pods before starving the data axis entirely
        pods = self.pod_size
        while new_data < 1 and pods > 1:
            pods -= 1
            per_pod = surviving_devices // pods
            new_data = per_pod // self.model_size
        if new_data < 1:
            raise RuntimeError(
                f"cannot rebuild mesh: {surviving_devices} devices < "
                f"model_size {self.model_size}"
            )
        return ElasticMesh(self.model_size, new_data, pods)

    def rescale_batch(self, global_batch: int, old: "ElasticMesh") -> int:
        """Keep per-device batch fixed; round to a multiple of the new DP size."""
        dp_old = old.data_size * old.pod_size
        dp_new = self.data_size * self.pod_size
        per_dp = global_batch // dp_old
        return max(per_dp * dp_new, dp_new)

    def make_mesh(self, devices=None) -> jax.sharding.Mesh:
        devices = devices if devices is not None else jax.devices()
        n = self.device_count
        arr = np.asarray(devices[:n])
        if self.pod_size > 1:
            shape = (self.pod_size, self.data_size, self.model_size)
            names = ("pod", "data", "model")
        else:
            shape = (self.data_size, self.model_size)
            names = ("data", "model")
        return jax.sharding.Mesh(arr.reshape(shape), names)


def run_with_recovery(
    step_fn: Callable[[Any, Any], tuple[Any, dict]],
    state: Any,
    batches: Any,
    *,
    num_steps: int,
    ckpt_manager=None,
    ckpt_every: int = 0,
    fault_cfg: FaultConfig = FaultConfig(),
    monitor: Optional[StragglerMonitor] = None,
    start_step: int = 0,
) -> tuple[Any, list[dict]]:
    """Driver loop: step, checkpoint, and on failure restore + replay.

    ``batches`` is indexable by global step (the deterministic pipeline
    contract) so replay-after-restore is exact.
    """
    history: list[dict] = []
    step = start_step
    failures = 0
    while step < num_steps:
        batch = batches.batch_at(step) if hasattr(batches, "batch_at") else batches[step]
        t0 = time.perf_counter()
        try:
            state, metrics = step_fn(state, batch)
        except Exception:  # noqa: BLE001 — transient-fault boundary
            failures += 1
            if failures > fault_cfg.max_retries:
                raise
            time.sleep(
                min(fault_cfg.backoff_base_s * 2 ** (failures - 1), fault_cfg.backoff_cap_s)
            )
            if ckpt_manager is not None:
                restored_step, restored = ckpt_manager.restore_latest(state)
                if restored_step is not None:
                    # roll back and REPLAY: the deterministic pipeline
                    # re-serves identical batches for the replayed steps.
                    # The checkpoint may predate start_step (a manager shared
                    # across drivers): clamp the history cut to 0 — a negative
                    # slice would silently KEEP the wrong suffix.
                    state = restored
                    history = history[: max(restored_step - start_step, 0)]
                    step = restored_step
            continue
        failures = 0
        wall = time.perf_counter() - t0
        if monitor is not None:
            metrics = dict(metrics)
            metrics["straggler"] = monitor.observe(wall)
        history.append(metrics)
        step += 1
        if ckpt_manager is not None and ckpt_every and step % ckpt_every == 0:
            ckpt_manager.save(step, state)
    if ckpt_manager is not None:
        ckpt_manager.wait()
    return state, history
