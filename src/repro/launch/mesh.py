"""Production mesh construction (deliverable e).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (smoke tests must keep seeing 1 CPU device).

Meshes:
  single pod:  (data=16, model=16)                 — 256 chips (one v5e pod)
  multi-pod:   (pod=2, data=16, model=16)          — 512 chips

Axis semantics across the stack:
  pod    — outermost data parallelism; gradient all-reduce crosses DCN here.
  data   — in-pod data parallelism (+ FSDP shard axis, + sequence-parallel
           axis for long-context decode).
  model  — tensor parallelism: heads / mlp / vocab / experts (EP) / SSM heads.
"""
from __future__ import annotations

import os

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many real devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


def parse_mesh_arg(spec: str) -> tuple[int, int]:
    """``--mesh dp,tp`` -> (dp, tp). A bare ``dp`` means tp=1."""
    parts = [int(p) for p in spec.split(",") if p.strip()]
    if not 1 <= len(parts) <= 2 or any(p < 1 for p in parts):
        raise ValueError(f"--mesh wants 'dp' or 'dp,tp' with positive ints, got {spec!r}")
    return (parts[0], parts[1] if len(parts) == 2 else 1)


def ensure_host_devices(n: int) -> None:
    """Request ``n`` virtual CPU devices for multi-device demos on one host.

    Appends ``--xla_force_host_platform_device_count=n`` to ``XLA_FLAGS``,
    which only takes effect if the JAX backend has not initialized yet — call
    this before the first array op / ``jax.devices()``. Raises with the
    manual-override instruction if the backend beat us to it (DESIGN.md §9;
    docs/sharding.md shows the end-to-end demo).
    """
    if n <= 1:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip()
        )
    if jax.device_count() < n:  # initializes the backend — the final word
        raise RuntimeError(
            f"need {n} devices but the JAX backend already initialized with "
            f"{jax.device_count()}; relaunch with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n}"
        )


def make_explain_mesh(dp: int, tp: int = 1):
    """(data=dp, model=tp) mesh for mesh-sharded explanation serving.

    ``data`` carries the folded (batch × step) stage-2 axis
    (``repro.sharding.explain_specs``); ``model`` is plumbed for backbone
    tensor parallelism and may be 1.
    """
    return jax.make_mesh((dp, tp), ("data", "model"))
