"""Production mesh construction (deliverable e).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (smoke tests must keep seeing 1 CPU device).

Meshes:
  single pod:  (data=16, model=16)                 — 256 chips (one v5e pod)
  multi-pod:   (pod=2, data=16, model=16)          — 512 chips

Axis semantics across the stack:
  pod    — outermost data parallelism; gradient all-reduce crosses DCN here.
  data   — in-pod data parallelism (+ FSDP shard axis, + sequence-parallel
           axis for long-context decode).
  model  — tensor parallelism: heads / mlp / vocab / experts (EP) / SSM heads.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many real devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))
