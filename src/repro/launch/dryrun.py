import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture × input-shape) cell on the production
meshes and records memory/cost/collective analysis:

    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells, 1-pod
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod     # 2-pod mesh
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k

Results append to results/dryrun_<mesh>.json (incremental; safe to re-run a
subset). EXPERIMENTS.md §Dry-run / §Roofline are generated from these files.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, LM_SHAPES, SHAPES_BY_NAME, shape_applicable
from repro.launch.cells import build_cell, lower_cell
from repro.launch.mesh import make_production_mesh
from repro.roofline import (
    HW_V5E,
    cost_analysis_dict,
    model_flops,
    parse_collective_bytes,
    roofline_report,
)
from repro.roofline.hlo_flops import entry_bytes

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results")


def _cost_dict(compiled) -> dict:
    return cost_analysis_dict(compiled)


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for attr in (
        "generated_code_size_in_bytes",
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "host_generated_code_size_in_bytes",
        "host_argument_size_in_bytes",
        "host_output_size_in_bytes",
        "host_temp_size_in_bytes",
    ):
        if hasattr(ma, attr):
            out[attr] = int(getattr(ma, attr))
    if not out:
        out["repr"] = str(ma)
    return out


def run_cell(
    arch_name: str, shape_name: str, mesh, mesh_name: str, *, costing: bool = True, **kw
) -> dict:
    """Up to two lowers per cell:

    1. the DEPLOYABLE artifact (lax.scan layers, microbatched) — proves the
       sharding compiles and yields memory_analysis (the fits-in-HBM proof);
    2. the COSTING artifact (``costing_mode()``: every scan unrolled,
       microbatches=1) — yields true per-chip flops/bytes/collective-bytes,
       since XLA cost analysis counts a while-loop body only once. Expensive
       to compile; the multi-pod pass (sharding proof only, §Roofline is
       single-pod) runs with ``costing=False``.
    """
    from repro.models.common import costing_mode

    cfg = ARCHS[arch_name]
    shape = SHAPES_BY_NAME[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    t0 = time.time()
    try:
        with mesh:
            cell = build_cell(cfg, shape, mesh, **kw)
            lowered = lower_cell(cell)
            compiled = lowered.compile()
            memory = _memory_dict(compiled)
            if costing:
                # costing lower: unrolled scans, single macro-batch
                kw_cost = dict(kw)
                if "microbatches" in kw_cost:
                    kw_cost["microbatches"] = 1
                with costing_mode():
                    cost_cell = build_cell(cfg, shape, mesh, **kw_cost)
                    cost_compiled = lower_cell(cost_cell).compile()
            else:
                cost_compiled = compiled
        cost = _cost_dict(cost_compiled)
        hlo = cost_compiled.as_text()
        del cost_compiled
        coll = parse_collective_bytes(hlo)
        # memory term from kernel-level ENTRY traffic (fusion-aware), not
        # cost_analysis 'bytes accessed' (which descends into fusion bodies
        # and over-counts ~20x vs what a TPU actually moves through HBM)
        kbytes = entry_bytes(hlo)
        cost = dict(cost)
        cost["bytes accessed raw"] = cost.get("bytes accessed", 0.0)
        cost["bytes accessed"] = float(kbytes)
        mflops = model_flops(cfg, shape)
        chips = mesh.devices.size
        report = roofline_report(
            arch=arch_name,
            shape=shape_name,
            mesh_name=mesh_name,
            chips=chips,
            cost=cost,
            coll_bytes_per_chip=coll["total"],
            mflops=mflops,
            peak_bytes_per_chip=float(
                memory.get("argument_size_in_bytes", 0)
                + memory.get("temp_size_in_bytes", 0)
                - memory.get("alias_size_in_bytes", 0)
            ),
        )
        rec.update(
            status="ok",
            seconds=round(time.time() - t0, 1),
            chips=chips,
            cost={k: cost[k] for k in ("flops", "bytes accessed", "bytes accessed raw") if k in cost},
            memory=memory,
            collectives=coll,
            roofline=report.row(),
        )
    except Exception as e:  # noqa: BLE001 — failures ARE the dry-run output
        rec.update(
            status="error",
            seconds=round(time.time() - t0, 1),
            error=f"{type(e).__name__}: {e}",
            traceback=traceback.format_exc()[-2000:],
        )
    return rec


def load_results(path: str) -> dict:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape name (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--force", action="store_true", help="re-run cached cells")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = args.out or os.path.join(RESULTS_DIR, f"dryrun_{mesh_name}.json")
    results = load_results(out_path)

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else [s.name for s in LM_SHAPES]

    failures = 0
    for a in archs:
        for s in shapes:
            key = f"{a}:{s}"
            if key in results and results[key].get("status") in ("ok", "skipped") and not args.force:
                print(f"[cached ] {key:48s} {results[key]['status']}")
                continue
            kw = {"microbatches": args.microbatches} if SHAPES_BY_NAME[s].kind == "train" else {}
            rec = run_cell(a, s, mesh, mesh_name, costing=not args.multi_pod, **kw)
            results[key] = rec
            with open(out_path, "w") as f:
                json.dump(results, f, indent=1)
            status = rec["status"]
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (
                    f"dom={r['dominant']:10s} "
                    f"t={max(r['compute_s'], r['memory_s'], r['collective_s']):.4f}s "
                    f"frac={r['roofline_fraction']:.3f}"
                )
            elif status == "error":
                extra = rec["error"][:120]
                failures += 1
            print(f"[{status:7s}] {key:48s} {extra}")
    print(f"\n{mesh_name}: {len(results)} cells, {failures} failures -> {out_path}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
