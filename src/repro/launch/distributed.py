"""Multi-host runtime initialization for real TPU pods.

On a v5e pod each host sees 4 local chips; ``init_distributed()`` wires
jax.distributed so ``jax.devices()`` is the global 256/512-chip view the
meshes in ``mesh.py`` expect. On this CPU container it is a no-op (single
process) — the dry-run emulates the device count with XLA_FLAGS instead.

Launch contract (see launch/run_pod.sh):
  COORDINATOR_ADDR host:port of process 0
  NUM_PROCESSES    total host count (pod: 64, 2 pods: 128)
  PROCESS_ID       this host's index
"""
from __future__ import annotations

import os
from typing import Optional

import jax


def init_distributed() -> dict:
    """Initialize jax.distributed from env; returns a summary dict."""
    addr = os.environ.get("COORDINATOR_ADDR")
    num = int(os.environ.get("NUM_PROCESSES", "1"))
    pid = int(os.environ.get("PROCESS_ID", "0"))
    if addr and num > 1:
        jax.distributed.initialize(
            coordinator_address=addr, num_processes=num, process_id=pid
        )
    return {
        "process_index": jax.process_index(),
        "process_count": jax.process_count(),
        "local_devices": jax.local_device_count(),
        "global_devices": jax.device_count(),
    }


def global_batch_from_process(global_batch: int) -> tuple[int, int]:
    """(local_batch, offset) for this host's slice of the data pipeline."""
    n, i = jax.process_count(), jax.process_index()
    assert global_batch % n == 0, (global_batch, n)
    local = global_batch // n
    return local, i * local


def assemble_global(mesh, specs, host_arrays):
    """Build global jax.Arrays from per-host numpy slices (input path).

    host_arrays: pytree of per-host numpy arrays (the local slice along
    batch). Uses ``jax.make_array_from_process_local_data`` so each host
    only materializes its shard.
    """
    from jax.sharding import NamedSharding

    def one(spec, arr):
        return jax.make_array_from_process_local_data(
            NamedSharding(mesh, spec), arr
        )

    return jax.tree.map(one, specs, host_arrays)
