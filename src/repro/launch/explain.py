"""Explanation-serving driver — the paper's low-latency XAI under traffic.

    PYTHONPATH=src python -m repro.launch.explain --arch llama3-8b \
        --method idgi --schedule paper --m 64 --n-int 4 --requests 16 --rounds 3

Drives the shape-bucketed ExplainEngine with MIXED-LENGTH request traffic
(random prompt lengths in [--min-seq, --max-seq]): round 1 pays the per-bucket
compilations, later rounds ride the compiled-executable cache. Prints
per-bucket latency, compile time, and the cache hit-rate, then the chosen
schedule vs uniform convergence comparison at the same step budget.

Multi-device serving (DESIGN.md §9): ``--mesh dp,tp`` builds a
(data=dp, model=tp) mesh and shards the folded (batch × step) stage-2 axis
across the data axis. On a CPU-only host, ``--host-devices N`` forces N
virtual devices (it must win the race with backend init, so it is applied
before any jax call; the equivalent manual form is
``XLA_FLAGS=--xla_force_host_platform_device_count=N``):

    PYTHONPATH=src python -m repro.launch.explain --arch llama3-8b \
        --host-devices 4 --mesh 4,1 --requests 16 --rounds 3

``--method`` picks the attribution method from the ``repro.core.methods``
registry (see the table in ``--help``); ``--schedule`` picks the
interpolation schedule family — the two compose freely (DESIGN.md §8).

``--attn flash`` serves the model through the Pallas flash-attention
custom-VJP kernel (interpret mode on CPU) instead of materializing
attention; ``--workload`` picks what gets explained:

  traffic   mixed-length random token traffic (the default serving sweep)
  prompt    ONE fixed deterministic prompt — prints the per-token
            attribution table (LM prompt attribution)
  vit       the reduced ViT on a synthetic image — patch-feature requests
            through the same bucketed engine; prints the top attributed
            patches on the patch grid (docs/attention.md quickstarts)
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, reduced
from repro.core.methods import METHODS
from repro.core.schedule import SCHEDULES
from repro.models.registry import Model
from repro.serve import ExplainEngine, ExplainRequest


def make_traffic(cfg, n: int, lo: int, hi: int, rng) -> list[ExplainRequest]:
    return [
        ExplainRequest(
            tokens=rng.integers(1, cfg.vocab_size, size=int(s)).astype(np.int32),
            target=int(rng.integers(0, cfg.vocab_size)),
        )
        for s in rng.integers(lo, hi + 1, size=n)
    ]


def methods_table() -> str:
    """The registry, rendered for --help (DESIGN.md §8)."""
    lines = ["attribution methods (--method):"]
    for name in sorted(METHODS):
        spec = METHODS[name]
        if spec.forward_only:
            extra = f" [forward-only, n_masks={spec.n_masks}]"
        elif spec.expand is not None:
            extra = f" [accum={spec.accum}, n_samples={spec.n_samples}]"
        else:
            extra = f" [accum={spec.accum}]"
        lines.append(f"  {name:14s} {spec.description}{extra}")
    lines.append("schedule families (--schedule): " + ", ".join(sorted(SCHEDULES)))
    return "\n".join(lines)


def report(engine: ExplainEngine) -> None:
    st = engine.stats
    print(f"  executable cache: hits={st.hits} misses={st.misses} "
          f"hit_rate={st.hit_rate:.2f}")
    if engine.result_cache is not None:
        print(f"  result cache: hits={st.result_hits} misses={st.result_misses} "
              f"hit_rate={st.result_hit_rate:.2f} evictions={st.result_evictions} "
              f"bytes={st.result_bytes}")
    if st.degraded or st.preempted or st.queue_depth:
        print(f"  scheduler: degraded={st.degraded} preempted={st.preempted} "
              f"queue_depth={st.queue_depth}")
    if engine.mesh is not None:
        print(f"  mesh: {dict(zip(engine.mesh.axis_names, engine.mesh.devices.shape))} "
              f"dp={engine.dp} mesh_fallbacks={st.mesh_fallbacks}")
    for shape in sorted(st.buckets):
        b = st.buckets[shape]
        print(
            f"  bucket B={shape[0]:<3d} S={shape[1]:<5d} calls={b.calls:<3d} "
            f"reqs={b.requests:<4d} compile={b.compile_s:.2f}s "
            f"mean_latency={1e3 * b.mean_latency_s:.1f}ms "
            f"bytes={b.bytes_accessed:.2e} peak={b.peak_bytes:.2e}"
        )
    for shape in sorted(st.hop_buckets):
        b = st.hop_buckets[shape]
        print(
            f"  hop    B={shape[0]:<3d} S={shape[1]:<5d} calls={b.calls:<3d} "
            f"{'':9s} compile={b.compile_s:.2f}s "
            f"mean_latency={1e3 * b.mean_latency_s:.1f}ms"
        )
    a = st.adaptive
    if a.requests:
        print(
            f"  adaptive: ladder={engine.m_ladder} converged={a.converged}/{a.requests} "
            f"early_exits={a.early_exits} hops={a.hop_calls} "
            f"mean_m_used={a.mean_m_used:.1f} steps={a.total_steps} "
            f"(launched {a.launched_steps} incl. pad) probe_fwd={a.probe_forwards}"
        )
        print(f"  m_used histogram: {dict(sorted(a.m_used.items()))}")


def main() -> int:
    ap = argparse.ArgumentParser(
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog=methods_table(),
    )
    ap.add_argument("--arch", default="llama3-8b", choices=sorted(ARCHS))
    ap.add_argument(
        "--method", default="ig", choices=sorted(METHODS),
        help="attribution method (see table below)",
    )
    ap.add_argument(
        "--schedule", default="paper", choices=sorted(SCHEDULES),
        help="interpolation schedule family",
    )
    ap.add_argument("--m", type=int, default=64)
    ap.add_argument("--n-int", type=int, default=4)
    ap.add_argument(
        "--n-masks", type=int, default=0,
        help="perturbation mask budget P for forward-only methods "
        "(occlusion/rise/lime; 0 = method default)",
    )
    ap.add_argument("--requests", type=int, default=16, help="requests per round")
    ap.add_argument("--rounds", type=int, default=3, help="traffic rounds (round 1 compiles)")
    ap.add_argument("--min-seq", type=int, default=9)
    ap.add_argument("--max-seq", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--adaptive",
        action="store_true",
        help="δ-feedback early-exit: escalate unconverged requests up the m-ladder",
    )
    ap.add_argument("--tol", type=float, default=1e-2, help="relative δ tolerance")
    ap.add_argument("--m-max", type=int, default=0, help="ladder top (default 8·m)")
    ap.add_argument(
        "--n-samples", type=int, default=0,
        help="path-ensemble size for noise_tunnel/expected_grad (0 = method default)",
    )
    ap.add_argument(
        "--sigma", type=float, default=0.0,
        help="ensemble perturbation scale (0 = method default)",
    )
    ap.add_argument(
        "--fused", action="store_true",
        help="fused stage 2: interpolation composed into the VJP (DESIGN.md §10)",
    )
    ap.add_argument(
        "--attn", default="auto", choices=("auto", "flash"),
        help="attention implementation: flash = Pallas custom-VJP kernel "
        "(O(S·D) backward residuals; interpret mode on CPU)",
    )
    ap.add_argument(
        "--workload", default="traffic", choices=("traffic", "prompt", "vit"),
        help="traffic = mixed-length token traffic; prompt = one fixed LM "
        "prompt with a per-token attribution table; vit = reduced-ViT patch "
        "attribution demo (ignores --arch/--min-seq/--max-seq)",
    )
    ap.add_argument(
        "--use-kernels", action="store_true",
        help="inject the Pallas kernel set (interpret-mode on CPU)",
    )
    ap.add_argument(
        "--autotune", action="store_true",
        help="load per-(bucket, device) tuned configs from results/autotune_<device>.json",
    )
    ap.add_argument(
        "--result-cache", type=int, default=0, metavar="MB",
        help="content-addressed attribution cache budget in MB (0 = off); "
        "repeat requests replay bit-identically without touching the engine "
        "(docs/caching.md)",
    )
    ap.add_argument(
        "--warm-state", default="", metavar="DIR",
        help="warm-start persistence directory: restore the AOT executable "
        "set (+ autotune entries + hop-zero history) before serving and "
        "save it after — a restarted process reaches its first explanation "
        "with zero compiles (docs/caching.md)",
    )
    ap.add_argument(
        "--hop-zero", action="store_true",
        help="with --adaptive: start each bucket at the δ-history quantile "
        "rung instead of the base rung (repeat traffic skips known hops)",
    )
    ap.add_argument(
        "--mesh", default="",
        help="'dp,tp' device mesh for sharded serving (e.g. 4,1); empty = single-device",
    )
    ap.add_argument(
        "--host-devices", type=int, default=0,
        help="force N virtual CPU devices (multi-device demo on one host)",
    )
    ap.add_argument(
        "--scheduler", action="store_true",
        help="route traffic through the MixedScheduler admission queue "
        "(bounded, per-tenant rate limits — docs/serving.md); prints "
        "backpressure/rate rejections and degradation counters",
    )
    ap.add_argument(
        "--max-queue", type=int, default=64,
        help="scheduler queue bound (with --scheduler)",
    )
    ap.add_argument(
        "--tenant-rate", type=float, default=0.0,
        help="per-tenant token-bucket refill rate in req/s "
        "(0 = unlimited; with --scheduler)",
    )
    args = ap.parse_args()

    mesh = None
    if args.mesh:
        from repro.launch.mesh import ensure_host_devices, make_explain_mesh, parse_mesh_arg

        dp, tp = parse_mesh_arg(args.mesh)
        ensure_host_devices(args.host_devices or dp * tp)
        mesh = make_explain_mesh(dp, tp)
        print(f"mesh: data={dp} model={tp} over {jax.device_count()} devices")

    engine_kwargs: dict = {}
    fixed_reqs = None
    if args.workload == "vit":
        from repro.configs.vit import reduced_vit
        from repro.models import vit

        cfg = reduced_vit()
        params = vit.init(cfg, jax.random.PRNGKey(args.seed))
        img = jax.random.uniform(
            jax.random.PRNGKey(args.seed + 1),
            (1, cfg.image_size, cfg.image_size, cfg.channels),
        )
        target = int(jnp.argmax(vit.forward(cfg, params, img), -1)[0])
        feats = np.asarray(vit.patchify(cfg, img), np.float32)[0]
        fixed_reqs = [
            ExplainRequest(
                tokens=np.arange(cfg.num_patches, dtype=np.int32),
                target=target,
                features=feats,
            )
        ]
        engine_kwargs["seq_buckets"] = (cfg.num_patches,)
        print(f"vit workload: {cfg.num_patches} patches, predicted class {target}")
    else:
        cfg = reduced(get_config(args.arch))
        if cfg.frontend or cfg.is_encdec:
            print(f"note: {cfg.name} frontend is stubbed; explaining token stream only")
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(args.seed))
        if args.workload == "prompt":
            # one DETERMINISTIC prompt: same tokens every run, target fixed —
            # the per-token table below is reproducible output
            prompt = (np.arange(1, 13, dtype=np.int32) * 7) % (cfg.vocab_size - 1) + 1
            fixed_reqs = [ExplainRequest(tokens=prompt, target=int(prompt[-1]))]
            print(f"prompt workload: tokens={prompt.tolist()} target={prompt[-1]}")
    rng = np.random.default_rng(args.seed)

    out = None
    compare = (args.schedule,) if args.schedule == "uniform" else (args.schedule, "uniform")
    if METHODS[args.method].forward_only:
        # perturbation methods never touch the interpolation schedule — one
        # pass, no uniform comparison leg
        compare = (args.schedule,)
    for sched_name in compare:
        engine = ExplainEngine(
            cfg,
            params,
            method=args.method,
            schedule=sched_name,
            m=args.m,
            n_int=args.n_int,
            mesh=mesh,
            adaptive=args.adaptive,
            tol=args.tol,
            m_max=args.m_max,
            n_samples=args.n_samples,
            sigma=args.sigma,
            n_masks=args.n_masks,
            fused=args.fused,
            use_kernels=args.use_kernels,
            attn=args.attn,
            autotune=args.autotune,
            result_cache=args.result_cache * (1 << 20),
            hop_zero=args.hop_zero,
            **engine_kwargs,
        )
        # the warm state belongs to the primary --schedule engine only; the
        # sweep's comparison engines would just warn about a context mismatch
        if args.warm_state and sched_name == args.schedule:
            from repro.serve import load_warm_state

            rep = load_warm_state(engine, args.warm_state)
            if rep.restored:
                print(f"warm state: restored {rep.executables} executables "
                      f"via {rep.via}")
            else:
                print(f"warm state: cold start ({rep.reason})")
        if METHODS[args.method].forward_only:
            mode = f"P={engine.n_masks} masks (forward-only)"
        elif args.adaptive:
            mode = f"adaptive tol={args.tol} ladder={engine.m_ladder}"
        else:
            mode = f"m={args.m}"
        samples = f" samples={engine.n_samples}" if engine.n_samples > 1 else ""
        flags = (" fused" if args.fused else "") + (" kernels" if args.use_kernels else "") \
            + (" autotuned" if args.autotune else "")
        print(f"method={args.method} schedule={sched_name} {mode}{samples}{flags} "
              f"traffic={args.rounds}x{args.requests} reqs S∈[{args.min_seq},{args.max_seq}]")
        sched = None
        if args.scheduler and engine.n_samples == 1:
            from repro.serve import MixedScheduler, TenantPolicy

            tenants = (
                {"default": TenantPolicy(rate=args.tenant_rate)}
                if args.tenant_rate
                else None
            )
            sched = MixedScheduler(engine, max_queue=args.max_queue, tenants=tenants)
        elif args.scheduler:
            print("note: --scheduler serves per-row methods only; "
                  f"{args.method} (n_samples={engine.n_samples}) runs direct")
        for rnd in range(args.rounds):
            reqs = (
                fixed_reqs
                if fixed_reqs is not None
                else make_traffic(cfg, args.requests, args.min_seq, args.max_seq, rng)
            )
            t0 = time.perf_counter()
            if sched is not None:
                tickets = [sched.submit(r) for r in reqs]
                sched.run_until_idle()
                out = [t.result for t in tickets if t.result is not None]
                rej = sum(t.status.startswith("rejected") for t in tickets)
                if rej:
                    print(f"  round {rnd}: {rej} rejected "
                          f"(backpressure={sched.rejected_backpressure} "
                          f"rate={sched.rejected_rate})")
                if not out:
                    print(f" round {rnd}: all {len(reqs)} requests rejected")
                    continue
            else:
                out = engine.explain(reqs)
            wall = time.perf_counter() - t0
            deltas = [o["delta"] for o in out]
            line = (f" round {rnd}: wall={wall:.2f}s mean_delta={np.mean(deltas):.5f} "
                    f"max_delta={np.max(deltas):.5f}")
            if args.adaptive:
                line += (f" mean_m_used={np.mean([o.get('m_used', 0) for o in out]):.1f}"
                         f" conv={sum(o.get('converged', False) for o in out)}/{len(out)}")
            print(line)
        report(engine)
        if args.warm_state and sched_name == args.schedule:
            from repro.serve import save_warm_state

            save_warm_state(engine, args.warm_state)
            with open(os.path.join(args.warm_state, "manifest.json")) as fh:
                n_saved = json.load(fh)["n_executables"]
            print(f"warm state: saved {n_saved} executables "
                  f"to {args.warm_state}")
    scores = np.asarray(out[0]["token_scores"])
    if args.workload == "prompt":
        print("per-token attribution (pos, token, score):")
        for i, (t, s) in enumerate(zip(fixed_reqs[0].tokens, scores)):
            print(f"  {i:3d} {int(t):6d} {s:+.6f}")
    elif args.workload == "vit":
        g = cfg.image_size // cfg.patch_size
        grid = scores.reshape(g, g)
        flat = np.argsort(-np.abs(grid), axis=None)[:5]
        print(f"top-5 attributed patches on the {g}x{g} grid (row, col, score):")
        for idx in flat:
            r, c = divmod(int(idx), g)
            print(f"  ({r}, {c}) {grid[r, c]:+.6f}")
    else:
        top = np.argsort(-np.abs(scores))[:5]
        print("top-5 attributed positions (last round, req 0):", top)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
