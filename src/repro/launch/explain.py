"""Explanation-serving driver — the paper's low-latency XAI end to end.

    PYTHONPATH=src python -m repro.launch.explain --arch llama3-8b \
        --method paper --m 64 --n-int 4

Embeds a batch of prompts, runs NUIG (stage-1 probe + stage-2 attribution)
in embedding space, and prints per-token scores + convergence deltas for
paper vs uniform at the same step budget.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config, reduced
from repro.models.registry import Model
from repro.serve import ExplainRequest, ExplainService


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=sorted(ARCHS))
    ap.add_argument("--method", default="paper",
                    choices=["uniform", "paper", "warp", "gauss", "refine"])
    ap.add_argument("--m", type=int, default=64)
    ap.add_argument("--n-int", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    if cfg.frontend or cfg.is_encdec:
        print(f"note: {cfg.name} frontend is stubbed; explaining token stream only")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    rng = np.random.default_rng(args.seed)
    reqs = [
        ExplainRequest(
            tokens=rng.integers(0, cfg.vocab_size, size=args.seq).astype(np.int32),
            target=int(rng.integers(0, cfg.vocab_size)),
        )
        for _ in range(args.batch)
    ]

    for method in (args.method, "uniform"):
        svc = ExplainService(cfg, params, method=method, m=args.m, n_int=args.n_int)
        t0 = time.time()
        out = svc.explain(reqs)
        dt = time.time() - t0
        deltas = [o["delta"] for o in out]
        print(
            f"method={method:8s} m={args.m} wall={dt:.2f}s "
            f"mean_delta={np.mean(deltas):.5f} max_delta={np.max(deltas):.5f}"
        )
    top = np.argsort(-np.abs(out[0]["token_scores"]))[:5]
    print("top-5 attributed positions (req 0):", top)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
