"""Dry-run cell construction: (arch × shape × mesh) -> lowerable step.

One *cell* = the jit-able step function, ShapeDtypeStruct arguments, and
in/out shardings for one (architecture, input-shape) pair on a mesh:

    train_*    -> train_step(state, batch)      [FSDP+TP rules]
    prefill_*  -> prefill_step(params, batch)   [FSDP+TP rules]
    decode_*   -> serve_step(params, cache, tok)[FSDP+TP; long_*: +SP]

KV-head TP note: GQA configs with kv_heads < model-axis size get their decode
cache expanded to ``kv_slots = model_size`` head slots (``attn.expand_kv``)
so the cache head axis shards on 'model' — 4x less per-device KV than
replication for kv=4 configs (see DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig, shape_applicable
from repro.models import lm
from repro.models.registry import Model, input_specs
from repro.optim import OptState
from repro.serve.engine import make_prefill_step, make_serve_step
from repro.sharding import (
    FSDP_RULES,
    MeshRules,
    cache_specs,
    param_specs,
    spec_for_batch_tree,
    to_shardings,
    train_state_specs,
)
from repro.train.step import TrainConfig, abstract_train_state, make_train_step


@dataclass
class Cell:
    name: str
    fn: Callable
    args: tuple  # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple[int, ...]
    mesh: Optional[Mesh] = None  # for activation sharding constraints
    seq_sharded: bool = False


def _mesh_size(mesh: Mesh, axis: str) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get(axis, 1)


def choose_kv_slots(cfg: ArchConfig, mesh: Mesh, *, seq_sharded: bool) -> int:
    """Expand KV heads to the model-axis size for TP-sharded caches."""
    if seq_sharded or not cfg.num_kv_heads:
        return 0
    model = _mesh_size(mesh, "model")
    if 0 < cfg.num_kv_heads < model and model % cfg.num_kv_heads == 0:
        return model
    return 0


def build_train_cell(
    cfg: ArchConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    *,
    rules: MeshRules = FSDP_RULES,
    microbatches: int = 8,
    remat: bool = True,
    grad_compression: bool = False,
) -> Cell:
    tcfg = TrainConfig(microbatches=microbatches, remat=remat, grad_compression=grad_compression)
    state = abstract_train_state(cfg, tcfg)
    batch = input_specs(cfg, shape)
    defs = lm.param_defs(cfg)

    state_specs = train_state_specs(defs, mesh, rules, state)
    batch_specs = spec_for_batch_tree(batch, mesh, rules)
    metrics_specs = {"loss": P(), "grad_norm": P(), "lr": P()}

    return Cell(
        name=f"{cfg.name}:{shape.name}",
        fn=make_train_step(cfg, tcfg),
        args=(state, batch),
        in_shardings=(
            to_shardings(state_specs, mesh),
            to_shardings(batch_specs, mesh),
        ),
        out_shardings=(
            to_shardings(state_specs, mesh),
            to_shardings(metrics_specs, mesh),
        ),
        donate_argnums=(0,),
        mesh=mesh,
    )


def _cast_abstract(params, dtype):
    """ShapeDtypeStruct tree with floating leaves re-typed (serving dtype)."""
    import numpy as np

    def one(p):
        if np.issubdtype(p.dtype, np.floating):
            return jax.ShapeDtypeStruct(p.shape, jnp.dtype(dtype))
        return p

    return jax.tree.map(one, params)


def build_prefill_cell(
    cfg: ArchConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    *,
    rules: MeshRules = FSDP_RULES,
    serve_dtype: str = "bfloat16",  # production serving default (§Perf #4)
) -> Cell:
    kv_slots = choose_kv_slots(cfg, mesh, seq_sharded=False)
    batch = input_specs(cfg, shape)
    defs = lm.param_defs(cfg)
    params = _cast_abstract(lm.abstract_params(cfg), serve_dtype)
    fn = make_prefill_step(cfg, max_len=shape.seq_len, kv_slots=kv_slots)

    # abstract outputs for sharding trees
    logits_cache = jax.eval_shape(fn, params, batch)
    _, cache_abs = logits_cache

    p_specs = param_specs(defs, mesh, rules)
    batch_specs = spec_for_batch_tree(batch, mesh, rules)
    c_specs = cache_specs(cache_abs, mesh, rules)
    b = batch_specs["tokens"][0] if "tokens" in batch_specs else None
    logits_spec = P(b, None, "model" if cfg.vocab_size % _mesh_size(mesh, "model") == 0 else None)

    return Cell(
        name=f"{cfg.name}:{shape.name}",
        fn=fn,
        args=(params, batch),
        in_shardings=(to_shardings(p_specs, mesh), to_shardings(batch_specs, mesh)),
        out_shardings=(
            NamedSharding(mesh, logits_spec),
            to_shardings(c_specs, mesh),
        ),
        donate_argnums=(),
        mesh=mesh,
    )


def build_decode_cell(
    cfg: ArchConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    *,
    rules: MeshRules = FSDP_RULES,
    serve_dtype: str = "bfloat16",  # production serving default (§Perf #4)
) -> Cell:
    seq_sharded = shape.global_batch < _mesh_size(mesh, "data")  # long_500k
    kv_slots = choose_kv_slots(cfg, mesh, seq_sharded=seq_sharded)
    spec = input_specs(cfg, shape, kv_slots=kv_slots)
    token, cache = spec["token"], spec["cache"]
    defs = lm.param_defs(cfg)
    params = _cast_abstract(lm.abstract_params(cfg), serve_dtype)
    fn = make_serve_step(cfg)

    p_specs = param_specs(defs, mesh, rules)
    c_specs = cache_specs(cache, mesh, rules, seq_sharded=seq_sharded)
    tok_spec = spec_for_batch_tree(token, mesh, rules)

    return Cell(
        name=f"{cfg.name}:{shape.name}",
        fn=fn,
        args=(params, cache, token),
        in_shardings=(
            to_shardings(p_specs, mesh),
            to_shardings(c_specs, mesh),
            to_shardings(tok_spec, mesh),
        ),
        out_shardings=(
            to_shardings(tok_spec, mesh),
            to_shardings(c_specs, mesh),
        ),
        donate_argnums=(1,),
        mesh=mesh,
        seq_sharded=seq_sharded,
    )


def build_cell(
    cfg: ArchConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    **kw,
) -> Optional[Cell]:
    """Returns None (with reason recorded by the caller) for skipped cells."""
    ok, _reason = shape_applicable(cfg, shape)
    if not ok:
        return None
    if shape.kind == "train":
        return build_train_cell(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_cell(cfg, shape, mesh, **kw)
    return build_decode_cell(cfg, shape, mesh, **kw)


def lower_cell(cell: Cell):
    """jit + lower (no compile). The caller compiles and inspects.

    Tracing runs under the activation-sharding policy so the model's
    ``constrain`` calls pin intermediate layouts (see sharding/context.py).
    """
    from repro.sharding.context import activation_sharding

    jitted = jax.jit(
        cell.fn,
        in_shardings=cell.in_shardings,
        out_shardings=cell.out_shardings,
        donate_argnums=cell.donate_argnums,
    )
    if cell.mesh is not None:
        with activation_sharding(cell.mesh, seq_sharded=cell.seq_sharded):
            return jitted.lower(*cell.args)
    return jitted.lower(*cell.args)
