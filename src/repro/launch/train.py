"""Training driver: ``python -m repro.launch.train --arch <id> [--reduced]``.

Full configs target the production mesh (real TPU pods); on this CPU
container use ``--reduced`` which trains the reduced config of the same
family end-to-end: data pipeline -> sharded train_step -> checkpointing ->
fault-tolerant driver loop.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS, get_config, reduced
from repro.data import DataConfig, SyntheticLM
from repro.optim import AdamWConfig
from repro.runtime import FaultConfig, StragglerMonitor, run_with_recovery
from repro.train import TrainConfig, make_train_state, make_train_step


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true", help="CPU-scale variant")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)

    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps),
        microbatches=args.microbatches,
        remat=True,
        grad_compression=args.grad_compression,
    )
    state = make_train_state(cfg, tcfg, jax.random.PRNGKey(args.seed))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M steps={args.steps}")

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    data = SyntheticLM(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch, seed=args.seed)
    )

    ckpt = CheckpointManager(args.ckpt_dir, keep_n=2, save_async=True) if args.ckpt_dir else None
    start = 0
    if ckpt is not None:
        restored_step, restored = ckpt.restore_latest(state)
        if restored_step is not None:
            state, start = restored, restored_step
            print(f"resumed from step {start}")

    monitor = StragglerMonitor(FaultConfig())

    def wrapped(state, batch):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        new_state, metrics = step_fn(state, b)
        return new_state, {k: float(v) for k, v in metrics.items()}

    t0 = time.time()
    state, history = run_with_recovery(
        wrapped,
        state,
        data,
        num_steps=args.steps,
        ckpt_manager=ckpt,
        ckpt_every=args.ckpt_every,
        monitor=monitor,
        start_step=start,
    )
    dt = time.time() - t0
    losses = [h["loss"] for h in history]
    print(
        f"done: {len(history)} steps in {dt:.1f}s "
        f"({dt/max(len(history),1)*1e3:.0f} ms/step) "
        f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
        f"stragglers={len(monitor.flagged)}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
