"""Serving driver: batched generation, optionally with explain riding along.

    # classic: batched greedy generation on a reduced config
    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --tokens 32

    # sampled decoding (exercises the non-greedy serve path)
    PYTHONPATH=src python -m repro.launch.serve --sample --temperature 0.8

    # unified mixed workload: generate + explain through ONE scheduler
    # (docs/serving.md) — prints per-SLO-class latency and queue stats
    PYTHONPATH=src python -m repro.launch.serve --mixed --tokens 8 --requests 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, reduced
from repro.models.registry import Model
from repro.serve import ServeEngine


def run_classic(cfg, params, args) -> int:
    model_batch = args.batch
    key = jax.random.PRNGKey(args.seed + 1)
    batch = {
        "tokens": jax.random.randint(
            key, (model_batch, args.prompt_len), 0, cfg.vocab_size
        )
    }
    if cfg.frontend == "vision":
        batch["frontend"] = jnp.ones(
            (model_batch, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16
        )
    if cfg.frontend == "audio":
        batch["frontend"] = jnp.ones(
            (model_batch, cfg.encoder_seq, cfg.frontend_dim), jnp.bfloat16
        )

    engine = ServeEngine(cfg, params, max_len=args.prompt_len + args.tokens)
    sample_kw = {}
    if args.sample:
        sample_kw = {
            "key": jax.random.PRNGKey(args.seed + 2),
            "temperature": args.temperature,
        }
    t0 = time.time()
    out = engine.generate(batch, args.tokens, **sample_kw)
    dt = time.time() - t0
    mode = f"sampled T={args.temperature}" if args.sample else "greedy"
    print(f"arch={cfg.name} {mode} generated {out.shape} in {dt:.2f}s")
    print("first sequence:", np.asarray(out[0])[:16], "...")
    assert not bool(jnp.any(out < 0)) and not bool(jnp.any(out >= cfg.vocab_size))
    return 0


def run_mixed(cfg, params, args) -> int:
    """Mixed generate+explain traffic through the unified MixedScheduler."""
    from repro.serve import (
        BATCH,
        INTERACTIVE,
        ExplainEngine,
        ExplainRequest,
        GenerateRequest,
        MixedScheduler,
        TenantPolicy,
    )

    # probe-reuse bit-exactness holds at f32 compute (docs/serving.md)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    engine = ExplainEngine(
        cfg,
        params,
        m=args.m,
        n_int=args.n_int,
        seq_buckets=(8, 16, 32, 64),
        adaptive=args.adaptive,
        tol=args.tol,
        result_cache=args.result_cache * (1 << 20),
    )
    max_len = args.prompt_len + args.tokens
    tenants = (
        {"default": TenantPolicy(rate=args.tenant_rate)} if args.tenant_rate else None
    )
    sched = MixedScheduler(
        engine,
        max_len=max_len,
        max_queue=args.max_queue,
        decode_chunk=args.decode_chunk,
        tenants=tenants,
    )
    rng = np.random.default_rng(args.seed)

    for rnd in range(args.rounds):
        tickets = []
        for i in range(args.requests):
            prompt = rng.integers(1, cfg.vocab_size, args.prompt_len).astype(np.int32)
            if i % 3 == 2:  # every third request is explain-only traffic
                tickets.append(
                    sched.submit(
                        ExplainRequest(
                            tokens=prompt, target=int(rng.integers(0, cfg.vocab_size))
                        )
                    )
                )
            else:
                tickets.append(
                    sched.submit(
                        GenerateRequest(
                            tokens=prompt,
                            num_tokens=args.tokens,
                            explain=True,
                            slo=INTERACTIVE if i % 2 == 0 else BATCH,
                            temperature=args.temperature if args.sample else 0.0,
                            seed=args.seed + i if args.sample else None,
                        )
                    )
                )
        t0 = time.perf_counter()
        sched.run_until_idle()
        wall = time.perf_counter() - t0
        done = sum(t.status == "done" for t in tickets)
        print(
            f"round {rnd}: {done}/{len(tickets)} done in {wall:.2f}s "
            f"(degraded={engine.stats.degraded} "
            f"rejected={sched.rejected_backpressure + sched.rejected_rate})"
        )

    st = engine.stats
    print(f"executable cache: hits={st.hits} misses={st.misses} "
          f"hit_rate={st.hit_rate:.2f}")
    if engine.result_cache is not None:
        print(f"result cache: hits={st.result_hits} misses={st.result_misses} "
              f"hit_rate={st.result_hit_rate:.2f} evictions={st.result_evictions} "
              f"bytes={st.result_bytes}")
    print(f"scheduler: degraded={st.degraded} preempted={st.preempted} "
          f"stragglers={len(sched.monitor.flagged)}")
    for name, s in sorted(sched.latency_summary().items()):
        print(f"  {name:12s} n={s['n']:<4d} p50={1e3 * s['p50_s']:.1f}ms "
              f"p99={1e3 * s['p99_s']:.1f}ms")
    gen = next(t for t in tickets if t.kind == "generate" and t.status == "done")
    a0 = gen.attributions[0]
    print(f"sample generate ticket: tokens={gen.tokens[:8]} "
          f"first-token attribution f_x={a0['f_x']:.4f} delta={a0['delta']:.5f} "
          f"(endpoint donated by the decode prefill — no re-run)")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sample", action="store_true",
                    help="categorical sampling instead of greedy argmax")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--mixed", action="store_true",
                    help="mixed generate+explain traffic through the unified "
                    "MixedScheduler (docs/serving.md)")
    ap.add_argument("--requests", type=int, default=8, help="requests/round (--mixed)")
    ap.add_argument("--rounds", type=int, default=2, help="traffic rounds (--mixed)")
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--n-int", type=int, default=4)
    ap.add_argument("--adaptive", action="store_true")
    ap.add_argument("--tol", type=float, default=1e-2)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--tenant-rate", type=float, default=0.0,
                    help="per-tenant admission rate in req/s (0 = unlimited)")
    ap.add_argument("--result-cache", type=int, default=0, metavar="MB",
                    help="content-addressed attribution cache budget in MB "
                    "(0 = off): repeat explain traffic completes at admission "
                    "without a queue slot (--mixed; docs/caching.md)")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.mixed:
        if args.prompt_len > 32:
            args.prompt_len = 16  # keep the demo's bucket set small
        return run_mixed(cfg, params, args)
    return run_classic(cfg, params, args)


if __name__ == "__main__":
    raise SystemExit(main())
