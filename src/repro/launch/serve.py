"""Serving driver: batched greedy generation on a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, reduced
from repro.models.registry import Model
from repro.serve import ServeEngine


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    key = jax.random.PRNGKey(args.seed + 1)
    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["frontend"] = jnp.ones((args.batch, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    if cfg.frontend == "audio":
        batch["frontend"] = jnp.ones((args.batch, cfg.encoder_seq, cfg.frontend_dim), jnp.bfloat16)

    engine = ServeEngine(cfg, params, max_len=args.prompt_len + args.tokens)
    t0 = time.time()
    out = engine.generate(batch, args.tokens)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s")
    print("first sequence:", np.asarray(out[0])[:16], "...")
    assert not bool(jnp.any(out < 0)) and not bool(jnp.any(out >= cfg.vocab_size))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
