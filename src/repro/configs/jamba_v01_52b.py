"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf] — attention at position 4 of each 8-layer period;
MoE replaces the dense FFN on every second (odd) layer.
"""
from repro.configs.base import ArchConfig, LayerSpec

M_D = LayerSpec("mamba", "dense")
M_E = LayerSpec("mamba", "moe")
A_E = LayerSpec("attn", "moe")

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    source="[arXiv:2403.19887; hf]",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    moe_d_ff=14336,
    vocab_size=65_536,
    num_experts=16,
    experts_per_tok=2,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_chunk=256,
    # 8-layer period, 1:7 attn:mamba, MoE every 2nd layer:
    pattern=(M_D, M_E, M_D, M_E, LayerSpec("attn", "dense"), M_E, M_D, M_E),
)
