"""internvl2-26b [vlm] — InternViT (STUB frontend) + InternLM2-20B backbone.

[arXiv:2404.16821; hf] — ``input_specs()`` provides precomputed patch
embeddings; the backbone prepends the projected patches to the token sequence.
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    source="[arXiv:2404.16821; hf]",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92_553,
    rope_theta=1_000_000.0,
    frontend="vision",
    frontend_tokens=256,  # one 448px tile -> 256 patch embeddings after pixel-shuffle
    frontend_dim=3200,  # InternViT-6B width
    pattern=(LayerSpec("attn", "dense"),),
)
