"""paper-cnn — small inception-style convnet for the faithful vision repro.

The paper evaluates on InceptionV3/ImageNet. This container is CPU-only, so
the faithful reproduction runs the *same algorithm* on a scaled-down
inception-style classifier (conv stem + mixed blocks with parallel towers +
GAP head) over synthetic images. The IG mechanics (path, probe, schedule,
convergence delta) are identical; only the classifier is smaller.
"""
from dataclasses import dataclass, field
from typing import Sequence


@dataclass(frozen=True)
class CnnConfig:
    name: str = "paper-cnn"
    family: str = "vision"
    image_size: int = 32
    channels: int = 3
    num_classes: int = 10
    stem_features: int = 16
    # per mixed-block: (1x1 tower, 3x3 tower, 5x5 tower, pool-proj) features.
    # 4 mixed blocks: deep enough that the prob-vs-alpha path has the paper's
    # rugged, sharply-saturating shape (2 blocks converge too smoothly and
    # the uniform midpoint rule wins by quadrature order — see EXPERIMENTS).
    blocks: Sequence[tuple] = (
        (8, 16, 4, 4),
        (16, 32, 8, 8),
        (24, 48, 12, 12),
        (32, 64, 16, 16),
    )
    param_dtype: str = "float32"
    compute_dtype: str = "float32"


CONFIG = CnnConfig()
