"""Architecture / shape configuration dataclasses.

Every assigned architecture is expressed as an ``ArchConfig``. A config is a
pure description: model code in ``repro.models`` consumes it, the launcher
selects it via ``--arch <id>``, and ``reduced()`` derives the CPU-smoke-test
variant of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Optional, Sequence

Mixer = Literal["attn", "local", "mamba", "none"]
Ffn = Literal["dense", "moe", "none"]


@dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating layer pattern."""

    mixer: Mixer = "attn"
    ffn: Ffn = "dense"


@dataclass(frozen=True)
class ArchConfig:
    # -- identity ----------------------------------------------------------
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm", "vision"]
    source: str = ""  # provenance note: [source; verified-tier]

    # -- transformer backbone ---------------------------------------------
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0  # 0 -> d_model // num_heads
    d_ff: int = 0
    vocab_size: int = 0
    act: str = "silu"  # swiglu gating act
    norm_eps: float = 1e-6
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # -- layer pattern (repeats to num_layers) ------------------------------
    # e.g. gemma3: 5 local + 1 global; jamba: 7 mamba + 1 attn, moe every 2nd.
    pattern: Sequence[LayerSpec] = (LayerSpec(),)
    sliding_window: int = 0  # for mixer == "local"

    # -- MoE ----------------------------------------------------------------
    num_experts: int = 0
    experts_per_tok: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (0 -> d_ff)
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2
    # dispatch locality: tokens route in this many independent blocks
    # (aligned with DP shards; per-block capacity — see models/moe.py)
    moe_dispatch_blocks: int = 32

    # -- SSM (Mamba-2 SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # -- encoder / decoder ---------------------------------------------------
    encoder_layers: int = 0  # >0 => encoder-decoder (cross-attn in decoder)
    encoder_seq: int = 0  # fixed encoder length (whisper: 1500 frames)

    # -- modality frontend (STUB per assignment) -----------------------------
    frontend: Optional[Literal["audio", "vision"]] = None
    frontend_tokens: int = 0  # patch/frame embeddings prepended to sequence
    frontend_dim: int = 0  # raw embedding dim before projection (0 -> d_model)

    # -- attention implementation --------------------------------------------
    # "auto": XLA paths (full / blocked by seq length). "flash": the Pallas
    # kernel with fused custom-VJP backward (explain hot path). Block sizes
    # are the kernel tilings — autotuned per bucket by serve/autotune.py.
    attn_impl: Literal["auto", "flash"] = "auto"
    attn_block_q: int = 128
    attn_block_k: int = 128

    # -- numerics -------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attn_free(self) -> bool:
        return all(s.mixer in ("mamba", "none") for s in self.pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True when no layer does full global attention over the sequence.

        Local (sliding-window) attention and SSM mixers are sub-quadratic.
        """
        return all(s.mixer in ("mamba", "local", "none") for s in self.pattern)

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def layer_specs(self) -> tuple[LayerSpec, ...]:
        """Pattern repeated/truncated to exactly ``num_layers`` entries."""
        pat = tuple(self.pattern)
        reps = -(-self.num_layers // len(pat))
        return (pat * reps)[: self.num_layers]

    @property
    def num_periods(self) -> int:
        """Full pattern repetitions (scanned); remainder layers are unscanned."""
        return self.num_layers // len(self.pattern)

    @property
    def remainder_specs(self) -> tuple[LayerSpec, ...]:
        """Trailing layers beyond the scanned periods (e.g. gemma3: 62 = 10*6+2)."""
        return tuple(self.pattern)[: self.num_layers % len(self.pattern)]

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        if self.frontend:
            total += (self.frontend_dim or d) * d
        specs = list(self.layer_specs)
        if self.is_encdec:
            specs += [LayerSpec("attn", "dense")] * self.encoder_layers
        for s in specs:
            total += 2 * d  # norms
            if s.mixer in ("attn", "local"):
                total += d * hd * (n_q + 2 * n_kv) + n_q * hd * d
            elif s.mixer == "mamba":
                di, ns = self.d_inner, self.ssm_state
                total += d * (2 * di + 2 * self.ssm_groups * ns + self.ssm_heads)
                total += di * self.ssm_conv + di * d + self.ssm_heads * 2
            if s.ffn == "dense" and self.d_ff:
                total += 3 * d * self.d_ff
            elif s.ffn == "moe":
                eff = self.moe_d_ff or self.d_ff
                total += self.num_experts * 3 * d * eff + d * self.num_experts
        if self.is_encdec:  # cross-attention in every decoder layer
            total += self.num_layers * (d * hd * (n_q + 2 * n_kv) + n_q * hd * d)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts) for 6ND."""
        if not self.num_experts:
            return self.param_count()
        eff = self.moe_d_ff or self.d_ff
        inactive = 0
        specs = list(self.layer_specs)
        for s in specs:
            if s.ffn == "moe":
                inactive += (self.num_experts - self.experts_per_tok) * 3 * self.d_model * eff
        return self.param_count() - inactive


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: what gets lowered in the dry-run."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

LM_SHAPES: tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in LM_SHAPES}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason).

    ``long_500k`` needs sub-quadratic attention: it runs for SSM / hybrid
    archs (per assignment) and for predominantly-local archs (gemma3 5:1 —
    see DESIGN.md §5); it is skipped for pure full-attention archs.
    """
    if shape.name == "long_500k":
        mostly_local = any(s.mixer in ("mamba", "local") for s in cfg.pattern)
        if cfg.family in ("ssm", "hybrid") or mostly_local:
            return True, ""
        return False, "skipped: pure full-attention arch (quadratic at 524k)"
    return True, ""


def reduced(cfg: ArchConfig, *, seq: int = 64) -> ArchConfig:
    """Tiny same-family variant for CPU smoke tests (real allocation)."""
    pat = tuple(cfg.pattern)
    changes = dict(
        name=cfg.name + "-reduced",
        num_layers=2 * len(pat),
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        sliding_window=min(cfg.sliding_window, seq // 2) if cfg.sliding_window else 0,
    )
    if cfg.num_experts:
        changes.update(num_experts=8, experts_per_tok=min(cfg.experts_per_tok, 2), moe_d_ff=32)
    if cfg.ssm_state:
        changes.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.encoder_layers:
        changes.update(encoder_layers=2, encoder_seq=24)
    if cfg.frontend:
        changes.update(frontend_tokens=8, frontend_dim=32)
    return dataclasses.replace(cfg, **changes)
