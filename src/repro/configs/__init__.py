"""Config registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from repro.configs.base import (
    ArchConfig,
    LayerSpec,
    ShapeConfig,
    LM_SHAPES,
    SHAPES_BY_NAME,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
    reduced,
    shape_applicable,
)

from repro.configs import (  # noqa: E402
    gemma3_27b,
    internlm2_20b,
    llama3_8b,
    yi_9b,
    qwen3_moe_30b_a3b,
    qwen3_moe_235b_a22b,
    mamba2_780m,
    jamba_v01_52b,
    whisper_tiny,
    internvl2_26b,
    paper_cnn,
    vit,
)
from repro.configs.vit import VitConfig, reduced_vit

# The 10 assigned architectures (the 40-cell dry-run grid iterates these).
ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        gemma3_27b.CONFIG,
        internlm2_20b.CONFIG,
        llama3_8b.CONFIG,
        yi_9b.CONFIG,
        qwen3_moe_30b_a3b.CONFIG,
        qwen3_moe_235b_a22b.CONFIG,
        mamba2_780m.CONFIG,
        jamba_v01_52b.CONFIG,
        whisper_tiny.CONFIG,
        internvl2_26b.CONFIG,
    )
}

PAPER_CNN = paper_cnn.CONFIG
VIT_S16 = vit.CONFIG


def get_config(name: str) -> ArchConfig:
    if name in ARCHS:
        return ARCHS[name]
    if name in (PAPER_CNN.name, "paper_cnn"):
        return PAPER_CNN  # type: ignore[return-value]
    if name in (VIT_S16.name, "vit"):
        return VIT_S16  # type: ignore[return-value]
    raise KeyError(
        f"unknown arch {name!r}; known: {sorted(ARCHS)} + ['paper-cnn', 'vit-s16']"
    )


__all__ = [
    "ArchConfig",
    "LayerSpec",
    "ShapeConfig",
    "ARCHS",
    "PAPER_CNN",
    "VIT_S16",
    "VitConfig",
    "reduced_vit",
    "LM_SHAPES",
    "SHAPES_BY_NAME",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "get_config",
    "reduced",
    "shape_applicable",
]
