"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified]
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    source="[arXiv:2405.21060; unverified]",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,  # mamba2 blocks have no separate FFN
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,  # 48 SSD heads (d_inner=3072)
    ssm_groups=1,
    ssm_chunk=256,
    tie_embeddings=True,
    pattern=(LayerSpec("mamba", "none"),),
)
