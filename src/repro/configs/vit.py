"""vit-s16 — ViT image classifier for attention-path attributions.

The paper evaluates IG on InceptionV3/ImageNet; ``paper_cnn`` reproduces that
setup on a convnet. This config is the *attention* counterpart: a ViT-S/16
(ImageNet-scale defaults) whose patch-level attributions exercise the flash
attention custom-VJP on the explain hot path. ``reduced_vit()`` is the
CPU-smoke variant (32x32 images, 4x4 patches -> 64 patch tokens, 10 classes)
trained on the same synthetic task as the benchmark CNN.

Duck-typing: ``VitConfig`` exposes the subset of ``ArchConfig`` fields that
``models/attention.py`` consumes (d_model, num_heads, num_kv_heads,
resolved_head_dim, attn_impl, attn block sizes), so the attention dispatch
and the flash kernel serve both model families unchanged.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Literal


@dataclass(frozen=True)
class VitConfig:
    name: str = "vit-s16"
    family: str = "vision"
    image_size: int = 224
    patch_size: int = 16
    channels: int = 3
    num_classes: int = 1000
    num_layers: int = 12
    d_model: int = 384
    num_heads: int = 6
    d_ff: int = 1536
    norm_eps: float = 1e-6
    # attention implementation (see configs/base.py ArchConfig)
    attn_impl: Literal["auto", "flash"] = "auto"
    attn_block_q: int = 128
    attn_block_k: int = 128
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    @property
    def num_kv_heads(self) -> int:  # ViT is MHA: no GQA grouping
        return self.num_heads

    @property
    def resolved_head_dim(self) -> int:
        return self.d_model // self.num_heads

    @property
    def num_patches(self) -> int:
        return (self.image_size // self.patch_size) ** 2

    @property
    def patch_dim(self) -> int:
        return self.patch_size**2 * self.channels

    # unused by ViT but read by shared attention/layer helpers
    sliding_window: int = 0


CONFIG = VitConfig()


def reduced_vit(cfg: VitConfig = CONFIG) -> VitConfig:
    """CPU-smoke variant: 8x8 grid of 4x4 patches = 64 patch tokens."""
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        image_size=32,
        patch_size=4,
        num_classes=10,
        num_layers=2,
        d_model=64,
        num_heads=4,
        d_ff=128,
    )
