"""whisper-tiny [audio] — encoder-decoder, conv frontend STUB.

[arXiv:2212.04356; unverified] — ``input_specs()`` provides precomputed
log-mel frame embeddings (the conv frontend is a stub per the assignment).
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    source="[arXiv:2212.04356; unverified]",
    num_layers=4,  # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51_865,
    encoder_layers=4,
    encoder_seq=1500,  # 30s of audio at 50 frames/s
    frontend="audio",
    frontend_tokens=1500,
    frontend_dim=384,
    rope_theta=10_000.0,
    pattern=(LayerSpec("attn", "dense"),),
)
