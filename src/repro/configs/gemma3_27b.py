"""gemma3-27b [dense] — 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import ArchConfig, LayerSpec

L, G = LayerSpec("local", "dense"), LayerSpec("attn", "dense")

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    source="[hf:google/gemma-3-1b-pt; unverified]",
    num_layers=62,  # 10 scanned periods of 6 + 2 remainder layers (L, L)
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262_144,
    rope_theta=1_000_000.0,
    # 5 local : 1 global; 62 layers = 10 periods + (L, L) remainder.
    pattern=(L, L, L, L, L, G),
    sliding_window=1024,
    tie_embeddings=True,
)
