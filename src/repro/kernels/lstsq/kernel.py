"""Batched in-VMEM Gauss–Jordan solve — the LIME weighted-least-squares
kernel (forward-only perturbation class).

One grid step per batch row: the whole (N, N) system lives in VMEM for the
full elimination sweep — N is the LIME group count + intercept (tens), so
a row's system is a few KB and the alternative (XLA's batched LU via
``linalg.solve``) round-trips HBM per factorization step for matrices that
fit in registers. No pivoting: the serving path only ever solves ridge-
regularized normal equations (SPD + λI, diagonally solid) and the masked
rows are pinned to identity before the call (``ref.prepare_normal_eqs``),
so the pivot is always the strictly-positive diagonal.

The sweep is ``fori_loop`` over pivots with 2D ``broadcasted_iota`` row/
column masks (TPU needs ≥2D iota; masked reductions replace dynamic row
extraction): eliminate ``A ← A − col_k ⊗ row_k/piv`` everywhere except the
pivot row, which is overwritten with the normalized row — after N sweeps
``A = I`` and the right-hand side IS the solution.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gauss_jordan_kernel(a_ref, b_ref, o_ref):
    A = a_ref[0]  # (N, N) — ops upcasts to the compute dtype (≥ f32)
    b = b_ref[0]  # (N, 1)
    N = A.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (N, N), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (N, N), 1)
    rid = jax.lax.broadcasted_iota(jnp.int32, (N, 1), 0)
    zero = jnp.zeros((), A.dtype)

    def body(k, carry):
        A, b = carry
        on_row = rows == k
        on_col = cols == k
        piv = jnp.sum(jnp.where(on_row & on_col, A, zero), axis=(0, 1), keepdims=True)
        inv = 1.0 / piv  # (1, 1)
        row_k = jnp.sum(jnp.where(on_row, A, zero), axis=0, keepdims=True) * inv
        col_k = jnp.sum(jnp.where(on_col, A, zero), axis=1, keepdims=True)  # (N, 1)
        bk = jnp.sum(jnp.where(rid == k, b, zero), axis=(0, 1), keepdims=True) * inv
        colz = jnp.where(rid == k, zero, col_k)  # pivot row eliminates last
        A = jnp.where(on_row, jnp.broadcast_to(row_k, A.shape), A - colz * row_k)
        b = jnp.where(rid == k, jnp.broadcast_to(bk, b.shape), b - colz * bk)
        return A, b

    _, b = jax.lax.fori_loop(0, N, body, (A, b))
    o_ref[0] = b


@functools.partial(jax.jit, static_argnames=("interpret",))
def wls_solve_pallas(A: jax.Array, rhs: jax.Array, *, interpret: bool = True) -> jax.Array:
    """A (B, N, N); rhs (B, N) -> (B, N), solved per batch row in VMEM.

    Callers pre-condition the system (ridge + mask pinning + padding to the
    sublane multiple) — see ``kernels.lstsq.ops.wls_solve``.
    """
    B, N, _ = A.shape
    out = pl.pallas_call(
        _gauss_jordan_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, N, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, N, 1), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, N, 1), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N, 1), A.dtype),
        interpret=interpret,
    )(A, rhs[..., None])
    return out[..., 0]
