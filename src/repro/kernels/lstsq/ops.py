"""jit'd public wrapper for the batched WLS solve (LIME, DESIGN.md §8).

``wls_solve`` honors the LIME solve-hook signature
``(A, rhs, *, mask, ridge) -> beta`` so it drops into
``core.perturb.attribute_from_masks(solve_fn=...)`` — the serving engine
injects it under ``use_kernels=True``; the default hook is the pure-jnp
oracle ``kernels.lstsq.ref.wls_solve_ref``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.lstsq.kernel import wls_solve_pallas
from repro.kernels.lstsq.ref import prepare_normal_eqs


def wls_solve(
    A: jax.Array,
    rhs: jax.Array,
    *,
    mask: Optional[jax.Array] = None,
    ridge: float = 0.0,
    block_n: int = 8,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Solve ``(A + λI) β = rhs`` per batch row with the Pallas kernel.

    A: (B, N, N) accumulated normal equations (any float dtype — upcast to
    f32 minimum, the class accumulation dtype; f64 under ``enable_x64``);
    rhs: (B, N); mask: optional (B, N) valid-entry mask — invalid rows are
    pinned to identity/zero-rhs (ragged batches: β is EXACTLY zero there).
    N is padded up to a multiple of ``block_n`` (sublane alignment) with
    identity rows, which the elimination never couples to the real block.
    ``interpret=None`` resolves from the backend
    (``kernels.common.default_interpret``).
    """
    interpret = default_interpret(interpret)
    Ap, bp = prepare_normal_eqs(A, rhs, mask, ridge)
    B, N = bp.shape
    pad = (-N) % block_n
    if pad:
        Ap = jnp.pad(Ap, ((0, 0), (0, pad), (0, pad)))
        idx = jnp.arange(N, N + pad)
        Ap = Ap.at[:, idx, idx].set(1.0)
        bp = jnp.pad(bp, ((0, 0), (0, pad)))
    out = wls_solve_pallas(Ap, bp, interpret=interpret)
    return out[:, :N]


__all__ = ["wls_solve", "wls_solve_pallas", "prepare_normal_eqs"]
