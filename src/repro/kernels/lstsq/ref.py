"""Pure-jnp oracles for the batched weighted-least-squares solve (LIME).

The serving path accumulates the weighted normal equations
``A = XᵀWX`` / ``b = XᵀWy`` chunk-wise (``core.perturb.lime_update``) and
solves ``(A + λI) β = b`` per batch row. ``prepare_normal_eqs`` is the ONE
shared pre-solve step — ridge regularization plus mask-aware pinning for
ragged batches — used by both this oracle and the Pallas op, so kernel
parity is over the solve itself.

Mask pinning: rows/columns of invalid entries (e.g. LIME groups with no
real position in a padded bucket) are zeroed and their diagonal set to 1
with a zero right-hand side, so their solution entry is EXACTLY zero and
they are fully decoupled from the valid block.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def prepare_normal_eqs(
    A: jax.Array,
    rhs: jax.Array,
    mask: Optional[jax.Array] = None,
    ridge: float = 0.0,
) -> tuple[jax.Array, jax.Array]:
    """(…, N, N), (…, N) → the regularized, mask-pinned system (f32 minimum).

    bf16 inputs are upcast to f32 (the class's accumulation dtype); f64
    rides through under ``jax.experimental.enable_x64``.
    """
    dt = jnp.promote_types(A.dtype, jnp.float32)
    A = A.astype(dt)
    rhs = rhs.astype(dt)
    N = A.shape[-1]
    eye = jnp.eye(N, dtype=dt)
    A = A + jnp.asarray(ridge, dt) * eye
    if mask is not None:
        m = mask.astype(dt)
        A = A * (m[..., :, None] * m[..., None, :]) + (1.0 - m)[..., :, None] * eye
        rhs = rhs * m
    return A, rhs


def wls_solve_ref(
    A: jax.Array,
    rhs: jax.Array,
    *,
    mask: Optional[jax.Array] = None,
    ridge: float = 0.0,
) -> jax.Array:
    """Batched solve of the (regularized, pinned) normal equations.

    A: (B, N, N); rhs: (B, N); mask: optional (B, N) valid-entry mask
    -> (B, N) in the promoted (≥ f32) dtype. The oracle for
    ``kernels.lstsq.ops.wls_solve`` and the default LIME solve hook.
    """
    Ap, bp = prepare_normal_eqs(A, rhs, mask, ridge)
    return jnp.linalg.solve(Ap, bp[..., None])[..., 0]


def normal_eqs(
    X: jax.Array, w: jax.Array, y: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Assemble (XᵀWX, XᵀWy) from a raw weighted design — the unchunked
    form of ``core.perturb.lime_update``'s accumulation (test/bench helper).

    X: (…, P, N) design rows; w: (…, P) weights; y: (…, P) responses.
    """
    Xw = X * w[..., None]
    return (
        jnp.einsum("...pi,...pj->...ij", Xw, X),
        jnp.einsum("...pi,...p->...i", Xw, y),
    )
