"""Fused interpolated-batch generation (stage 2 hot loop, memory-bound).

Naive IG materializes K interpolants with K× HBM reads of (x, baseline); this
kernel reads each (x, baseline) feature tile into VMEM **once** per K-tile and
streams the K interpolants out — HBM traffic drops from 2·K·F reads to
2·(K/Kt)·F, i.e. the read side is amortized over the whole α-tile.

Grid: (B, K/Kt, F/Ft). BlockSpecs keep every operand in VMEM:
  x/baseline tile (1, Ft), alphas tile (1, Kt), out tile (1, Kt, Ft).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _interp_kernel(x_ref, b_ref, a_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # (1, Ft)
    b = b_ref[...].astype(jnp.float32)  # (1, Ft)
    a = a_ref[...].astype(jnp.float32)  # (1, Kt)
    diff = x - b  # (1, Ft)
    o = b[:, None, :] + a[:, :, None] * diff[:, None, :]  # (1, Kt, Ft)
    o_ref[...] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "block_f", "interpret"))
def interpolate_pallas(
    x: jax.Array,
    baseline: jax.Array,
    alphas: jax.Array,
    *,
    block_k: int = 8,
    block_f: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """x, baseline: (B, F); alphas: (B, K) -> (B, K, F)."""
    B, F = x.shape
    K = alphas.shape[1]
    bk, bf = min(block_k, K), min(block_f, F)
    assert K % bk == 0 and F % bf == 0, (K, bk, F, bf)
    grid = (B, K // bk, F // bf)
    return pl.pallas_call(
        _interp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bf), lambda b, k, f: (b, f)),
            pl.BlockSpec((1, bf), lambda b, k, f: (b, f)),
            pl.BlockSpec((1, bk), lambda b, k, f: (b, k)),
        ],
        out_specs=pl.BlockSpec((1, bk, bf), lambda b, k, f: (b, k, f)),
        out_shape=jax.ShapeDtypeStruct((B, K, F), x.dtype),
        interpret=interpret,
    )(x, baseline, alphas)
