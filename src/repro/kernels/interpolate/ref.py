"""Pure-jnp oracle for the fused interpolation kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def interpolate_ref(x: jax.Array, baseline: jax.Array, alphas: jax.Array) -> jax.Array:
    """x, baseline: (B, F);  alphas: (B, K)  ->  (B, K, F).

    out[b, k, f] = baseline[b, f] + alphas[b, k] * (x[b, f] - baseline[b, f])
    """
    a = alphas[..., None].astype(jnp.float32)
    xe = x[:, None].astype(jnp.float32)
    be = baseline[:, None].astype(jnp.float32)
    return (be + a * (xe - be)).astype(x.dtype)
