"""jit'd public wrapper: arbitrary feature shape + padding + engine adapter."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.paths import mask_to_baseline
from repro.kernels.common import default_interpret
from repro.kernels.interpolate.kernel import interpolate_pallas
from repro.kernels.interpolate.ref import interpolate_ref


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def interpolate(
    x: jax.Array,
    baseline: jax.Array,
    alphas: jax.Array,
    *,
    mask: jax.Array = None,
    block_k: int = 8,
    block_f: int = 512,
    interpret: bool = None,
) -> jax.Array:
    """Engine-compatible drop-in for ``repro.core.paths.interpolate``.

    x, baseline: (B, *F); alphas: (K,) or (B, K) -> (B, K, *F).
    mask: optional (B, *L) real-position mask — masked positions are pinned
    to the baseline before the kernel runs, so padded features interpolate
    to exactly the baseline (bucketed serving; DESIGN.md §6).
    ``interpret=None`` resolves from the backend (interpreted on CPU,
    compiled on GPU/TPU; ``kernels.common.default_interpret``).
    """
    interpret = default_interpret(interpret)
    x = mask_to_baseline(x, baseline, mask)
    B = x.shape[0]
    feat = x.shape[1:]
    F = int(np.prod(feat))
    if alphas.ndim == 1:
        alphas = jnp.broadcast_to(alphas, (B,) + alphas.shape)
    K = alphas.shape[1]
    xf = _pad_to(x.reshape(B, F), block_f, 1)
    bf = _pad_to(baseline.reshape(B, F), block_f, 1)
    af = _pad_to(alphas, block_k, 1)
    bk = min(block_k, af.shape[1])
    blf = min(block_f, xf.shape[1])
    out = interpolate_pallas(
        xf, bf, af.astype(jnp.float32), block_k=bk, block_f=blf, interpret=interpret
    )
    return out[:, :K, :F].reshape((B, K) + feat)


__all__ = ["interpolate", "interpolate_ref"]
