"""Pallas TPU kernels for the perf-critical hot spots.

Each kernel ships as kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper) and ref.py (pure-jnp oracle). On this CPU container kernels run with
interpret=True; on TPU set interpret=False.
"""
from repro.kernels.interpolate.ops import interpolate
from repro.kernels.ig_accum.ops import ig_accum
from repro.kernels.flash_attention.ops import flash_attention

__all__ = ["interpolate", "ig_accum", "flash_attention"]
