"""Pallas TPU kernels for the perf-critical hot spots.

Each kernel ships as kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper) and ref.py (pure-jnp oracle). The ops resolve ``interpret=None``
per call via ``kernels.common.default_interpret`` — interpreted on CPU,
compiled on GPU/TPU — overridable per call.
"""
from repro.kernels.common import default_interpret
from repro.kernels.interpolate.ops import interpolate
from repro.kernels.interp_accum.ops import interp_accum
from repro.kernels.ig_accum.ops import ig_accum
from repro.kernels.flash_attention.ops import flash_attention

__all__ = [
    "default_interpret",
    "interpolate",
    "interp_accum",
    "ig_accum",
    "flash_attention",
]
