"""Flash attention (GQA, causal, ragged) — Pallas TPU kernels, fwd + bwd.

TPU adaptation of the classic GPU algorithm: Q/K/V tiles are staged in VMEM
via BlockSpecs, the score tile hits the MXU (block sizes multiples of 128),
and the online-softmax running state (m, l, acc) lives in VMEM scratch across
the innermost (sequential) K-block grid dimension — replacing the GPU's
shared-memory/warp-register carries.

Forward grid: (B, NQ, Sq/bq, Sk/bk), K innermost. GQA: the K/V BlockSpec
index-maps query head h -> kv head h // G, so KV tiles are fetched once per
group. Fully-masked (future / beyond-kvlen) K blocks are skipped via pl.when
on the block index — with a causal grid this removes ~half the MXU work.

Backward pass (two kernels, independent tilings — see docs/attention.md):

* residuals are O and the per-row logsumexp ``lse = m + log(l)`` — the
  (bq, bk) probability tile is recomputed as ``exp(s - lse)`` instead of
  being materialized, so bwd memory is O(S*D) not O(S^2);
* ``delta = rowsum(dO * O)`` is precomputed once outside the kernels and
  shared by both (it is the softmax-jacobian diagonal term);
* dQ kernel: grid (B, NQ, Sq/bq, Sk/bk) K innermost, one (bq, D) f32 VMEM
  accumulator that stays resident across the K sweep;
* dK/dV kernel: grid (B, NKV, Sk/bk, G, Sq/bq) with the GQA group and the Q
  sweep innermost, so the (bk, D) f32 dK/dV accumulators for one KV tile
  stay resident while every query head of the group streams past.

Ragged masking: ``kvlen`` is a (B, 1) int32 of valid K lengths; K positions
>= kvlen[b] are masked in all kernels (this is also how the wrappers in
``ops.py`` make padded sequence lengths exact).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mask(s, *, causal, qi, ki, bq, bk, kvlen):
    """Apply the causal + ragged-length mask to a (bq, bk) score tile."""
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    keep = kpos < kvlen
    if causal:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        keep &= qpos >= kpos
    return jnp.where(keep, s, NEG_INF), keep


# ------------------------------------------------------------------ forward


def _flash_fwd_kernel(
    q_ref, k_ref, v_ref, kvlen_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref,
    *, causal, bq, bk, scale,
):
    ki = pl.program_id(3)
    qi = pl.program_id(2)
    nk = pl.num_programs(3)
    kvlen = kvlen_ref[0, 0]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip K blocks strictly in the future of this Q block or beyond kvlen
    live = (ki * bk <= qi * bq + bq - 1) if causal else (ki >= 0)

    @pl.when(live & (ki * bk < kvlen))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, D)
        s = q @ k.T  # (bq, bk) — MXU
        s, _ = _mask(s, causal=causal, qi=qi, ki=ki, bq=bq, bk=bk, kvlen=kvlen)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_ref[...] + jnp.log(jnp.maximum(l, 1e-30))


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention_fwd_pallas(
    q: jax.Array,  # (B, NQ, Sq, D)
    k: jax.Array,  # (B, NKV, Sk, D)
    v: jax.Array,
    kvlen: jax.Array,  # (B, 1) int32 valid K lengths
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns (o, lse): the attention output and the (B, NQ, Sq) f32
    per-row logsumexp residual the backward kernels recompute P from."""
    B, NQ, Sq, D = q.shape
    NKV, Sk = k.shape[1], k.shape[2]
    G = NQ // NKV
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    grid = (B, NQ, Sq // bq, Sk // bk)
    kernel = functools.partial(
        _flash_fwd_kernel, causal=causal, bq=bq, bk=bk, scale=D**-0.5
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1), lambda b, h, iq, ik: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, NQ, Sq, D), q.dtype),
            jax.ShapeDtypeStruct((B, NQ, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),  # running max m
            pltpu.VMEM((bq,), jnp.float32),  # running denom l
            pltpu.VMEM((bq, D), jnp.float32),  # running output acc
        ],
        interpret=interpret,
    )(q, k, v, kvlen)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention_pallas(
    q: jax.Array,  # (B, NQ, Sq, D)
    k: jax.Array,  # (B, NKV, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, Sk = q.shape[0], k.shape[2]
    kvlen = jnp.full((B, 1), Sk, jnp.int32)
    o, _ = flash_attention_fwd_pallas(
        q, k, v, kvlen, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )
    return o


# ----------------------------------------------------------------- backward


def _flash_bwd_dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, kvlen_ref, dq_ref, acc_ref,
    *, causal, bq, bk, scale,
):
    ki = pl.program_id(3)
    qi = pl.program_id(2)
    nk = pl.num_programs(3)
    kvlen = kvlen_ref[0, 0]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = (ki * bk <= qi * bq + bq - 1) if causal else (ki >= 0)

    @pl.when(live & (ki * bk < kvlen))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, D)
        do = do_ref[0, 0].astype(jnp.float32)  # (bq, D)
        lse = lse_ref[0, 0]  # (bq,) f32
        delta = delta_ref[0, 0]  # (bq,) f32
        s = (q @ k.T) * scale
        _, keep = _mask(s, causal=causal, qi=qi, ki=ki, bq=bq, bk=bk, kvlen=kvlen)
        # recompute P from the lse residual; explicit zero (not exp(NEG_INF -
        # lse)) so fully-masked rows with lse ~ NEG_INF stay exactly zero
        p = jnp.where(keep, jnp.exp(s - lse[:, None]), 0.0)
        dp = do @ v.T  # (bq, bk)
        ds = p * (dp - delta[:, None])
        acc_ref[...] += ds @ k

    @pl.when(ki == nk - 1)
    def _finalize():
        dq_ref[0, 0] = (acc_ref[...] * scale).astype(dq_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention_bwd_dq_pallas(
    q: jax.Array,  # (B, NQ, Sq, D)
    k: jax.Array,  # (B, NKV, Sk, D)
    v: jax.Array,
    do: jax.Array,  # (B, NQ, Sq, D) output cotangent
    lse: jax.Array,  # (B, NQ, Sq) f32 forward residual
    delta: jax.Array,  # (B, NQ, Sq) f32 rowsum(dO * O)
    kvlen: jax.Array,  # (B, 1) int32
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, NQ, Sq, D = q.shape
    NKV, Sk = k.shape[1], k.shape[2]
    G = NQ // NKV
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    grid = (B, NQ, Sq // bq, Sk // bk)
    kernel = functools.partial(
        _flash_bwd_dq_kernel, causal=causal, bq=bq, bk=bk, scale=D**-0.5
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, iq, ik: (b, h, iq)),
            pl.BlockSpec((1, 1, bq), lambda b, h, iq, ik: (b, h, iq)),
            pl.BlockSpec((1, 1), lambda b, h, iq, ik: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, NQ, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],  # dq accumulator
        interpret=interpret,
    )(q, k, v, do, lse, delta, kvlen)


def _flash_bwd_dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, kvlen_ref,
    dk_ref, dv_ref, dk_acc, dv_acc,
    *, causal, bq, bk, scale,
):
    jk = pl.program_id(2)
    g = pl.program_id(3)
    qi = pl.program_id(4)
    ng = pl.num_programs(3)
    nq = pl.num_programs(4)
    kvlen = kvlen_ref[0, 0]

    @pl.when((g == 0) & (qi == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    # skip Q blocks strictly before this K block (causal) or dead K blocks
    live = (qi * bq + bq - 1 >= jk * bk) if causal else (qi >= 0)

    @pl.when(live & (jk * bk < kvlen))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, D)
        do = do_ref[0, 0].astype(jnp.float32)  # (bq, D)
        lse = lse_ref[0, 0]  # (bq,) f32
        delta = delta_ref[0, 0]  # (bq,) f32
        s = (q @ k.T) * scale
        _, keep = _mask(s, causal=causal, qi=qi, ki=jk, bq=bq, bk=bk, kvlen=kvlen)
        p = jnp.where(keep, jnp.exp(s - lse[:, None]), 0.0)
        dv_acc[...] += p.T @ do
        dp = do @ v.T
        ds = p * (dp - delta[:, None])
        dk_acc[...] += ds.T @ q

    @pl.when((g == ng - 1) & (qi == nq - 1))
    def _finalize():
        dk_ref[0, 0] = (dk_acc[...] * scale).astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention_bwd_dkv_pallas(
    q: jax.Array,  # (B, NQ, Sq, D)
    k: jax.Array,  # (B, NKV, Sk, D)
    v: jax.Array,
    do: jax.Array,  # (B, NQ, Sq, D)
    lse: jax.Array,  # (B, NQ, Sq) f32
    delta: jax.Array,  # (B, NQ, Sq) f32
    kvlen: jax.Array,  # (B, 1) int32
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    B, NQ, Sq, D = q.shape
    NKV, Sk = k.shape[1], k.shape[2]
    G = NQ // NKV
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    # group g and the Q sweep are the two innermost (sequential) dims so the
    # (bk, D) dK/dV accumulators stay VMEM-resident for one KV tile
    grid = (B, NKV, Sk // bk, G, Sq // bq)
    kernel = functools.partial(
        _flash_bwd_dkv_kernel, causal=causal, bq=bq, bk=bk, scale=D**-0.5
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), functools.partial(_q_index, G=G)),
            pl.BlockSpec((1, 1, bk, D), lambda b, hk, jk, g, iq: (b, hk, jk, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, hk, jk, g, iq: (b, hk, jk, 0)),
            pl.BlockSpec((1, 1, bq, D), functools.partial(_q_index, G=G)),
            pl.BlockSpec((1, 1, bq), functools.partial(_row_index, G=G)),
            pl.BlockSpec((1, 1, bq), functools.partial(_row_index, G=G)),
            pl.BlockSpec((1, 1), lambda b, hk, jk, g, iq: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, D), lambda b, hk, jk, g, iq: (b, hk, jk, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, hk, jk, g, iq: (b, hk, jk, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, NKV, Sk, D), k.dtype),
            jax.ShapeDtypeStruct((B, NKV, Sk, D), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, D), jnp.float32),  # dk accumulator
            pltpu.VMEM((bk, D), jnp.float32),  # dv accumulator
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta, kvlen)


def _q_index(b, hk, jk, g, iq, *, G):
    return (b, hk * G + g, iq, 0)


def _row_index(b, hk, jk, g, iq, *, G):
    return (b, hk * G + g, iq)
