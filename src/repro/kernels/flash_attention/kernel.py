"""Flash attention (GQA, causal) — Pallas TPU kernel.

TPU adaptation of the classic GPU algorithm: Q/K/V tiles are staged in VMEM
via BlockSpecs, the score tile hits the MXU (block sizes multiples of 128),
and the online-softmax running state (m, l, acc) lives in VMEM scratch across
the innermost (sequential) K-block grid dimension — replacing the GPU's
shared-memory/warp-register carries.

Grid: (B, NQ, Sq/bq, Sk/bk), K innermost. GQA: the K/V BlockSpec index-maps
query head h -> kv head h // G, so KV tiles are fetched once per group.
NOTE: fully-masked (future) K blocks are skipped via pl.when on the block
index — with a causal grid this removes ~half the MXU work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, causal, bq, bk, scale):
    ki = pl.program_id(3)
    qi = pl.program_id(2)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip K blocks strictly in the future of this whole Q block
    @pl.when((ki * bk <= qi * bq + bq - 1) if causal else (ki >= 0))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, D)
        s = q @ k.T  # (bq, bk) — MXU
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention_pallas(
    q: jax.Array,  # (B, NQ, Sq, D)
    k: jax.Array,  # (B, NKV, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, NQ, Sq, D = q.shape
    NKV, Sk = k.shape[1], k.shape[2]
    G = NQ // NKV
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    grid = (B, NQ, Sq // bq, Sk // bk)
    kernel = functools.partial(
        _flash_kernel, causal=causal, bq=bq, bk=bk, scale=D**-0.5
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, NQ, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),  # running max m
            pltpu.VMEM((bq,), jnp.float32),  # running denom l
            pltpu.VMEM((bq, D), jnp.float32),  # running output acc
        ],
        interpret=interpret,
    )(q, k, v)
