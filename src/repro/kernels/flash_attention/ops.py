"""Flash attention op: model layout, ragged lengths, fused custom-VJP bwd.

``flash_attention`` is the explain-hot-path entry point: (B, S, H, D) model
layout in/out, optional per-row valid lengths, sequence padding to block
multiples (made exact by the kernel's kvlen mask + output slicing), and a
``jax.custom_vjp`` whose backward recomputes the probability tile from the
(B, NQ, Sq) f32 logsumexp residual — differentiating through attention never
materializes the (B, H, S, S) score tensor in either direction.

Residuals kept for backward: q, k, v, o, lse, kvlen — O(B*S*H*D), vs the
O(B*H*S^2) score tensor the XLA materializing path saves.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import default_interpret
from repro.kernels.flash_attention.kernel import (
    flash_attention_bwd_dkv_pallas,
    flash_attention_bwd_dq_pallas,
    flash_attention_fwd_pallas,
)
from repro.kernels.flash_attention.ref import attention_ref, attention_vjp_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _flash(q, k, v, kvlen, causal, block_q, block_k, interpret):
    o, _ = flash_attention_fwd_pallas(
        q, k, v, kvlen, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )
    return o


def _flash_fwd(q, k, v, kvlen, causal, block_q, block_k, interpret):
    o, lse = flash_attention_fwd_pallas(
        q, k, v, kvlen, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )
    return o, (q, k, v, o, lse, kvlen)


def _flash_bwd(causal, block_q, block_k, interpret, res, do):
    q, k, v, o, lse, kvlen = res
    # softmax-jacobian diagonal term, shared by the dQ and dK/dV kernels
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    dq = flash_attention_bwd_dq_pallas(
        q, k, v, do, lse, delta, kvlen, causal=causal, block_q=block_q,
        block_k=block_k, interpret=interpret,
    )
    dk, dv = flash_attention_bwd_dkv_pallas(
        q, k, v, do, lse, delta, kvlen, causal=causal, block_q=block_q,
        block_k=block_k, interpret=interpret,
    )
    # integer lengths are non-differentiable: float0 cotangent
    return dq, dk, dv, np.zeros(kvlen.shape, jax.dtypes.float0)


_flash.defvjp(_flash_fwd, _flash_bwd)


def _pad_seq(x: jax.Array, mult: int) -> jax.Array:
    """Zero-pad the sequence axis (axis 2, kernel layout) to a multiple."""
    s = x.shape[2]
    pad = (-s) % mult
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))


def flash_attention(
    q: jax.Array,  # (B, S, NQ, D) — model layout
    k: jax.Array,  # (B, S, NKV, D)
    v: jax.Array,
    *,
    causal: bool = True,
    lengths: Optional[jax.Array] = None,  # (B,) or (B, 1) valid K lengths
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Differentiable flash attention in model layout.

    ``interpret=None`` resolves via ``kernels.common.default_interpret``:
    interpreted on the CPU backend (CI), compiled on TPU. Sequence lengths
    that don't divide the block sizes are zero-padded; padded K positions
    are masked via kvlen so values and gradients match the unpadded oracle
    exactly, and padded Q rows are sliced off (their cotangent is zero, so
    they contribute nothing to dK/dV).
    """
    interpret = default_interpret(interpret)
    B, Sq, NQ, D = q.shape
    Sk = k.shape[1]
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    qt = _pad_seq(qt, bq)
    kt = _pad_seq(kt, bk)
    vt = _pad_seq(vt, bk)
    if lengths is None:
        kvlen = jnp.full((B, 1), Sk, jnp.int32)
    else:
        kvlen = jnp.minimum(lengths.astype(jnp.int32).reshape(B, 1), Sk)
    o = _flash(qt, kt, vt, kvlen, causal, bq, bk, interpret)
    return o[:, :, :Sq, :].transpose(0, 2, 1, 3)


__all__ = ["flash_attention", "attention_ref", "attention_vjp_ref"]
