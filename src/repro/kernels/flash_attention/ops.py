"""jit'd wrapper: (B, S, H, D) model layout -> kernel layout + fallbacks."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention(
    q: jax.Array,  # (B, S, NQ, D) — model layout
    k: jax.Array,  # (B, S, NKV, D)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_pallas(
        qt, kt, vt, causal=causal, block_q=block_q, block_k=block_k, interpret=interpret
    )
    return o.transpose(0, 2, 1, 3)


__all__ = ["flash_attention", "attention_ref"]
