"""Pure-jnp oracle for the flash attention kernel (GQA, causal)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, NQ, Sq, D)
    k: jax.Array,  # (B, NKV, Sk, D)
    v: jax.Array,  # (B, NKV, Sk, D)
    *,
    causal: bool = True,
) -> jax.Array:
    B, NQ, Sq, D = q.shape
    NKV, Sk = k.shape[1], k.shape[2]
    G = NQ // NKV
    qg = q.reshape(B, NKV, G, Sq, D).astype(jnp.float32) * (D**-0.5)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32))
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", a, v.astype(jnp.float32))
    return o.reshape(B, NQ, Sq, D).astype(q.dtype)
