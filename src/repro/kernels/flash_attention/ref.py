"""Pure-jnp oracle for the flash attention kernel (GQA, causal, ragged).

``attention_ref`` is the forward oracle; ``attention_vjp_ref`` spells out the
backward pass the Pallas kernels implement (dP -> dS -> dQ/dK/dV with the
softmax-jacobian diagonal term ``delta = rowsum(dO * O)``), so kernel parity
tests can check gradients against explicit formulas rather than only against
jax.grad of the forward.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _masked_probs(
    q: jax.Array,  # (B, NQ, Sq, D)
    k: jax.Array,  # (B, NKV, Sk, D)
    *,
    causal: bool,
    lengths: Optional[jax.Array],
) -> jax.Array:
    """(B, NKV, G, Sq, Sk) f32 softmax probabilities with causal/ragged mask."""
    B, NQ, Sq, D = q.shape
    NKV, Sk = k.shape[1], k.shape[2]
    G = NQ // NKV
    qg = q.reshape(B, NKV, G, Sq, D).astype(jnp.float32) * (D**-0.5)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32))
    mask = jnp.ones((1, 1, 1, Sq, Sk), bool)
    if causal:
        mask = mask & (jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :])
    if lengths is not None:
        valid = jnp.arange(Sk)[None, :] < lengths.reshape(B, 1)  # (B, Sk)
        mask = mask & valid[:, None, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid key (e.g. length 0) softmax to uniform garbage; zero
    # them so the oracle matches the kernel's l=0 -> o=0 convention
    return jnp.where(mask.any(axis=-1, keepdims=True), p, 0.0)


def attention_ref(
    q: jax.Array,  # (B, NQ, Sq, D)
    k: jax.Array,  # (B, NKV, Sk, D)
    v: jax.Array,  # (B, NKV, Sk, D)
    *,
    causal: bool = True,
    lengths: Optional[jax.Array] = None,  # (B,) or (B, 1) valid K lengths
) -> jax.Array:
    B, NQ, Sq, D = q.shape
    p = _masked_probs(q, k, causal=causal, lengths=lengths)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, NQ, Sq, D).astype(q.dtype)


def attention_vjp_ref(
    q: jax.Array,  # (B, NQ, Sq, D)
    k: jax.Array,  # (B, NKV, Sk, D)
    v: jax.Array,
    do: jax.Array,  # (B, NQ, Sq, D) output cotangent
    *,
    causal: bool = True,
    lengths: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Explicit (dq, dk, dv) — the formulas the Pallas bwd kernels compute.

    With P = softmax(scale * Q K^T + mask) and O = P V:
        dV = P^T dO
        dP = dO V^T
        dS = P * (dP - delta),  delta = rowsum(dO * O)
        dQ = scale * dS K,  dK = scale * dS^T Q  (summed over the GQA group)
    """
    B, NQ, Sq, D = q.shape
    NKV = k.shape[1]
    G = NQ // NKV
    scale = D**-0.5
    p = _masked_probs(q, k, causal=causal, lengths=lengths)  # (B,NKV,G,Sq,Sk)
    vf = v.astype(jnp.float32)
    dog = do.reshape(B, NKV, G, Sq, D).astype(jnp.float32)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    dv = jnp.einsum("bhgqk,bhgqd->bhkd", p, dog)
    dp = jnp.einsum("bhgqd,bhkd->bhgqk", dog, vf)
    delta = jnp.sum(dog * o, axis=-1)  # (B, NKV, G, Sq)
    ds = p * (dp - delta[..., None])
    qg = q.reshape(B, NKV, G, Sq, D).astype(jnp.float32)
    dq = scale * jnp.einsum("bhgqk,bhkd->bhgqd", ds, k.astype(jnp.float32))
    dk = scale * jnp.einsum("bhgqk,bhgqd->bhkd", ds, qg)
    return (
        dq.reshape(B, NQ, Sq, D).astype(q.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
    )
