"""Shared kernel-op plumbing: backend-resolved interpret mode."""
from __future__ import annotations

from typing import Optional

import jax


def default_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve a Pallas ``interpret`` override against the active backend.

    ``None`` (the ops' default) means "interpret exactly when the backend
    cannot compile Pallas" — i.e. the CPU test/dev container runs interpreted
    while GPU/TPU runs actually hit the hardware. Passing an explicit bool
    always wins (kernel-parity tests force ``True``; a TPU debug session can
    force ``True`` too).

        >>> default_interpret(False)
        False
        >>> import jax
        >>> default_interpret() == (jax.default_backend() == "cpu")
        True
    """
    if interpret is not None:
        return interpret
    return jax.default_backend() == "cpu"
