"""Pure-jnp oracles for the fused interp-into-VJP kernels (DESIGN.md §10)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def interp_add_ref(
    x: jax.Array, baseline: jax.Array, alphas: jax.Array, carry: jax.Array
) -> jax.Array:
    """x, baseline: (B, F); alphas: (B, K); carry: (B, F) or (B, K, F) f32.

    out[b, k, f] = baseline[b, f] + alphas[b, k]·(x − baseline)[b, f]
                   + carry[b, (k,) f]

    Interpolation at INPUT precision then the carry add lifted to f32 — the
    §10 dtype contract (at carry == 0 the quadrature nodes are bit-identical
    to the unfused path's, bf16 included), mirroring kernel.py's ``_interp``.
    """
    a = alphas.astype(x.dtype)[:, :, None]
    xi = (baseline[:, None, :] + a * (x - baseline)[:, None, :]).astype(jnp.float32)
    u = carry[:, None, :] if carry.ndim == 2 else carry
    return (xi + u).astype(x.dtype)


def accum_cot_ref(grads: jax.Array) -> jax.Array:
    """grads (B, K, F) -> (B, F) f32 = Σ_k grads[:, k] (f32 reduction)."""
    return jnp.sum(grads.astype(jnp.float32), axis=1)
