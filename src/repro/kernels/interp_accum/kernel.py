"""Fused interpolate-into-VJP kernels for the bandwidth-optimal stage 2.

The fused stage 2 (``ig.attribute(fused=True)``, DESIGN.md §10) differentiates
``carry ↦ f(interp(x, x′, α) + carry)`` at ``carry = 0``. Its two halves map
onto two single-pass kernels:

  * forward — ``interp_add_pallas``: one pass generating the interpolant tile
    b + α(x − b) + carry in VMEM, reading each (x, x′) feature tile once per
    K-tile (the ``kernels.interpolate`` amortization) AND folding the additive
    carry in, so the fused chunk function costs no extra HBM round trip over
    plain interpolation. The carry is either (B, F) f32 — the riemann-class
    broadcast over the step axis — or (B, K, F) f32 — the per-step probe the
    quadratic (IDGI) class differentiates against.
  * backward — ``accum_cot_pallas``: the transpose of the broadcast-add IS
    the weighted accumulation (the quadrature weights ride the VJP seed).
    One pass over the cotangent ḡ with the riemann carry structure: grid
    (B, F/Ft, K/Kt), K innermost so the (1, Ft) f32 output tile stays
    resident in VMEM across the whole step axis — 1 output write per F-tile
    instead of K read-modify-write round trips. The per-step (B, K, F)
    carry's transpose is an identity (plus the f32 cast) — the quadratic
    (IDGI) class pays no kernel at all on the way back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _interp(x_ref, b_ref, a_ref):
    # interpolation at INPUT precision — bit-compatible with the unfused
    # path's quadrature nodes (paths.interp_add dtype contract, §10) — then
    # lifted to f32 for the carry add
    x = x_ref[...]  # (1, Ft) input dtype
    b = b_ref[...]  # (1, Ft)
    a = a_ref[...].astype(x.dtype)  # (1, Kt)
    xi = b[:, None, :] + a[:, :, None] * (x - b)[:, None, :]  # (1, Kt, Ft)
    return xi.astype(jnp.float32)


def _interp_add_bcast_kernel(x_ref, b_ref, a_ref, u_ref, o_ref):
    u = u_ref[...]  # (1, Ft) f32 — broadcast over steps
    o_ref[...] = (_interp(x_ref, b_ref, a_ref) + u[:, None, :]).astype(o_ref.dtype)


def _interp_add_step_kernel(x_ref, b_ref, a_ref, u_ref, o_ref):
    u = u_ref[...]  # (1, Kt, Ft) f32 — per-step carry
    o_ref[...] = (_interp(x_ref, b_ref, a_ref) + u).astype(o_ref.dtype)


def _accum_cot_kernel(g_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(g_ref[...].astype(jnp.float32), axis=1)  # (1, Ft)


@functools.partial(jax.jit, static_argnames=("block_k", "block_f", "interpret"))
def interp_add_pallas(
    x: jax.Array,
    baseline: jax.Array,
    alphas: jax.Array,
    carry: jax.Array,
    *,
    block_k: int = 8,
    block_f: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """x, baseline: (B, F); alphas: (B, K); carry: (B, F) or (B, K, F) f32
    -> (B, K, F) in x.dtype: b + α(x − b) + carry, one fused pass."""
    B, F = x.shape
    K = alphas.shape[1]
    bk, bf = min(block_k, K), min(block_f, F)
    assert K % bk == 0 and F % bf == 0, (K, bk, F, bf)
    grid = (B, K // bk, F // bf)
    bcast = carry.ndim == 2
    kernel = _interp_add_bcast_kernel if bcast else _interp_add_step_kernel
    carry_spec = (
        pl.BlockSpec((1, bf), lambda b, k, f: (b, f))
        if bcast
        else pl.BlockSpec((1, bk, bf), lambda b, k, f: (b, k, f))
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bf), lambda b, k, f: (b, f)),
            pl.BlockSpec((1, bf), lambda b, k, f: (b, f)),
            pl.BlockSpec((1, bk), lambda b, k, f: (b, k)),
            carry_spec,
        ],
        out_specs=pl.BlockSpec((1, bk, bf), lambda b, k, f: (b, k, f)),
        out_shape=jax.ShapeDtypeStruct((B, K, F), x.dtype),
        interpret=interpret,
    )(x, baseline, alphas, carry)


@functools.partial(jax.jit, static_argnames=("block_k", "block_f", "interpret"))
def accum_cot_pallas(
    grads: jax.Array,
    *,
    block_k: int = 8,
    block_f: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """grads (B, K, F) -> (B, F) f32 = Σ_k grads[:, k] — the fused backward.

    The weighted accumulation of the fused stage 2: the quadrature weights
    already ride the cotangent (they seed the VJP at the model output), so
    the transpose of the step-axis broadcast is a plain K-reduction with the
    f32 output tile carried in VMEM (K innermost)."""
    B, K, F = grads.shape
    bk, bf = min(block_k, K), min(block_f, F)
    assert K % bk == 0 and F % bf == 0, (K, bk, F, bf)
    grid = (B, F // bf, K // bk)
    return pl.pallas_call(
        _accum_cot_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, bk, bf), lambda b, f, k: (b, k, f))],
        out_specs=pl.BlockSpec((1, bf), lambda b, f, k: (b, f)),
        out_shape=jax.ShapeDtypeStruct((B, F), jnp.float32),
        interpret=interpret,
    )(grads)
