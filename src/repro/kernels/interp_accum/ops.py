"""Differentiable fused interp-plus-carry op — the fused stage 2's kernel unit.

``interp_accum`` is the Pallas drop-in for ``repro.core.paths.interp_add``
(the function ``ig.attribute(fused=True)`` differentiates w.r.t. its carry,
DESIGN.md §10), with a custom VJP:

  forward   one fused Pallas pass b + α(x − x′) + carry (kernel.py);
  backward  carry rank 2 (riemann class, carry broadcast over steps):
            ``accum_cot_pallas`` — the one-pass K-reduction with the f32
            output tile carried in VMEM; carry rank 3 (IDGI class, per-step
            probe): an f32 cast of the cotangent, no kernel.

The op is differentiable W.R.T. THE CARRY ONLY: the endpoint/alpha cotangents
are declared zero, because the fused stage 2 treats (x, x′, α) as constants
of the chunk program. Use the pure-jnp ``paths.interp_add`` where full AD
through the endpoints is needed.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.paths import mask_to_baseline
from repro.kernels.common import default_interpret
from repro.kernels.interp_accum.kernel import accum_cot_pallas, interp_add_pallas
from repro.kernels.interp_accum.ref import accum_cot_ref, interp_add_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _interp_add_flat(x, baseline, alphas, carry, block_k, block_f, interpret):
    """Flat padded core: x/baseline (B, F), alphas (B, K), carry (B, F) or
    (B, K, F) f32 -> (B, K, F) x.dtype."""
    return interp_add_pallas(
        x, baseline, alphas, carry,
        block_k=block_k, block_f=block_f, interpret=interpret,
    )


def _interp_add_flat_fwd(x, baseline, alphas, carry, block_k, block_f, interpret):
    out = _interp_add_flat(x, baseline, alphas, carry, block_k, block_f, interpret)
    # dtype-only residuals (rank-0 zeros): the backward needs no primal
    # values, only the cotangent dtypes for the declared-zero endpoints and
    # the carry rank for transpose dispatch
    res = (
        jnp.zeros((), x.dtype),
        jnp.zeros((), baseline.dtype),
        jnp.zeros((), alphas.dtype),
        carry.ndim == 2,
    )
    return out, res


def _interp_add_flat_bwd(block_k, block_f, interpret, res, g):
    zx, zb, za, bcast = res
    B, K, F = g.shape
    if bcast:  # riemann class: transpose of the step broadcast = fused K-sum
        ubar = accum_cot_pallas(g, block_k=block_k, block_f=block_f, interpret=interpret)
    else:  # IDGI class: identity transpose, f32 cast only
        ubar = g.astype(jnp.float32)
    return (
        jnp.zeros((B, F), zx.dtype),
        jnp.zeros((B, F), zb.dtype),
        jnp.zeros((B, K), za.dtype),
        ubar,
    )


_interp_add_flat.defvjp(_interp_add_flat_fwd, _interp_add_flat_bwd)


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def interp_accum(
    x: jax.Array,
    baseline: jax.Array,
    alphas: jax.Array,
    carry: jax.Array,
    *,
    mask: Optional[jax.Array] = None,
    block_k: int = 8,
    block_f: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Engine-compatible drop-in for ``repro.core.paths.interp_add``.

    x, baseline: (B, *F); alphas: (K,) or (B, K); carry: (B, *F) f32
    (broadcast over steps — riemann class) or (B, K, *F) f32 (per-step —
    IDGI class). Returns (B, K, *F) in ``x.dtype``. mask: optional (B, *L)
    real-position mask — masked positions are pinned to the baseline before
    the kernel runs (DESIGN.md §6). ``interpret=None`` resolves from the
    backend (``kernels.common.default_interpret``).
    """
    interpret = default_interpret(interpret)
    x = mask_to_baseline(x, baseline, mask)
    B = x.shape[0]
    feat = x.shape[1:]
    F = int(np.prod(feat))
    if alphas.ndim == 1:
        alphas = jnp.broadcast_to(alphas, (B,) + alphas.shape)
    K = alphas.shape[1]
    xf = _pad_to(x.reshape(B, F), block_f, 1)
    bf = _pad_to(baseline.reshape(B, F), block_f, 1)
    af = _pad_to(alphas.astype(jnp.float32), block_k, 1)
    bcast = carry.ndim == x.ndim
    cf = carry.astype(jnp.float32)
    if bcast:
        cf = _pad_to(cf.reshape(B, F), block_f, 1)
    else:
        cf = _pad_to(_pad_to(cf.reshape(B, K, F), block_f, 2), block_k, 1)
    bk = min(block_k, af.shape[1])
    blf = min(block_f, xf.shape[1])
    out = _interp_add_flat(xf, bf, af, cf, bk, blf, interpret)
    return out[:, :K, :F].reshape((B, K) + feat)


__all__ = ["interp_accum", "interp_add_ref", "accum_cot_ref"]
