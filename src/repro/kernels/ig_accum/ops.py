"""jit'd public wrappers + engine adapters for the accumulation kernels.

Both wrappers honor the MethodSpec accumulator signature
``(acc, grads, weights, *, diff, mask)`` (DESIGN.md §8), so they drop into
``ig.attribute(accum_fn=...)`` for their method: ``ig_accum`` for every
riemann-class method (ig / noise_tunnel / expected_grad — ``diff`` is
accepted and ignored), ``ig_accum_idgi`` for IDGI. ``accum_fn_for`` maps an
accumulator class name to its kernel.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import default_interpret
from repro.kernels.ig_accum.kernel import (
    idgi_dots_pallas,
    ig_accum_pallas,
    ig_accum_sq_pallas,
)
from repro.kernels.ig_accum.ref import ig_accum_idgi_ref, ig_accum_ref


def _mask_grads(grads: jax.Array, mask: jax.Array) -> jax.Array:
    mm = mask.reshape(
        mask.shape[:1] + (1,) + mask.shape[1:] + (1,) * (grads.ndim - mask.ndim - 1)
    )
    return grads * mm.astype(grads.dtype)


def ig_accum(
    acc: jax.Array,
    grads: jax.Array,
    weights: jax.Array,
    *,
    diff: Optional[jax.Array] = None,
    mask: Optional[jax.Array] = None,
    block_k: int = 8,
    block_f: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Engine-compatible drop-in for the riemann accumulator.

    acc: (B, *F) f32; grads: (B, K, *F); weights: (B, K) -> (B, *F) f32.
    diff: accepted for signature uniformity (riemann ignores the direction).
    mask: optional (B, *L) real-position mask — padded-position gradients
    are zeroed before accumulation (bucketed serving; DESIGN.md §6).
    ``interpret=None`` resolves from the backend (interpreted on CPU,
    compiled on GPU/TPU; ``kernels.common.default_interpret``).
    """
    interpret = default_interpret(interpret)
    if mask is not None:
        grads = _mask_grads(grads, mask)
    B = acc.shape[0]
    feat = acc.shape[1:]
    F = int(np.prod(feat))
    K = grads.shape[1]
    pad_f = (-F) % block_f
    pad_k = (-K) % block_k
    af = jnp.pad(acc.reshape(B, F), ((0, 0), (0, pad_f)))
    gf = jnp.pad(grads.reshape(B, K, F), ((0, 0), (0, pad_k), (0, pad_f)))
    wf = jnp.pad(weights, ((0, 0), (0, pad_k)))
    out = ig_accum_pallas(
        af,
        gf,
        wf,
        block_k=min(block_k, K + pad_k),
        block_f=min(block_f, F + pad_f),
        interpret=interpret,
    )
    return out[:, :F].reshape((B,) + feat)


def ig_accum_idgi(
    acc: jax.Array,
    grads: jax.Array,
    weights: jax.Array,
    *,
    diff: jax.Array,
    mask: Optional[jax.Array] = None,
    block_k: int = 8,
    block_f: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Engine-compatible drop-in for the IDGI accumulator (two fused passes).

    acc: (B, *F) f32; grads: (B, K, *F); weights: (B, K); diff: (B, *F)
    -> (B, *F) f32 = acc + Σ_k w_k ⟨g_k, diff⟩/⟨g_k, g_k⟩ · g_k².
    Zero-padding K/F is safe: padded features contribute 0 to both inner
    products and padded steps get coefficient w=0. ``interpret=None``
    resolves from the backend (``kernels.common.default_interpret``).
    """
    interpret = default_interpret(interpret)
    if mask is not None:
        grads = _mask_grads(grads, mask)
    B = acc.shape[0]
    feat = acc.shape[1:]
    F = int(np.prod(feat))
    K = grads.shape[1]
    pad_f = (-F) % block_f
    pad_k = (-K) % block_k
    af = jnp.pad(acc.reshape(B, F), ((0, 0), (0, pad_f)))
    gf = jnp.pad(grads.reshape(B, K, F), ((0, 0), (0, pad_k), (0, pad_f)))
    wf = jnp.pad(weights, ((0, 0), (0, pad_k)))
    df = jnp.pad(diff.reshape(B, F), ((0, 0), (0, pad_f)))
    bk = min(block_k, K + pad_k)
    bf = min(block_f, F + pad_f)
    s, p = idgi_dots_pallas(gf, df, block_k=bk, block_f=bf, interpret=interpret)
    coeff = (
        wf.astype(jnp.float32)
        * p
        * jnp.where(s > 0.0, 1.0 / jnp.where(s > 0.0, s, 1.0), 0.0)
    )
    out = ig_accum_sq_pallas(af, gf, coeff, block_k=bk, block_f=bf, interpret=interpret)
    return out[:, :F].reshape((B,) + feat)


def accum_fn_for(accum: str) -> Callable:
    """Pallas kernel for a MethodSpec accumulator class name."""
    table = {"riemann": ig_accum, "idgi": ig_accum_idgi}
    if accum not in table:
        raise ValueError(f"unknown accumulator class {accum!r}; known: {sorted(table)}")
    return table[accum]


__all__ = ["ig_accum", "ig_accum_idgi", "ig_accum_ref", "ig_accum_idgi_ref", "accum_fn_for"]
