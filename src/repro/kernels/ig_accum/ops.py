"""jit'd public wrapper + engine adapter for the accumulation kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ig_accum.kernel import ig_accum_pallas
from repro.kernels.ig_accum.ref import ig_accum_ref


def ig_accum(
    acc: jax.Array,
    grads: jax.Array,
    weights: jax.Array,
    *,
    mask: jax.Array = None,
    block_k: int = 8,
    block_f: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Engine-compatible drop-in for the default accumulator.

    acc: (B, *F) f32; grads: (B, K, *F); weights: (B, K) -> (B, *F) f32.
    mask: optional (B, *L) real-position mask — padded-position gradients
    are zeroed before accumulation (bucketed serving; DESIGN.md §6).
    """
    if mask is not None:
        mm = mask.reshape(
            mask.shape[:1] + (1,) + mask.shape[1:] + (1,) * (grads.ndim - mask.ndim - 1)
        )
        grads = grads * mm.astype(grads.dtype)
    B = acc.shape[0]
    feat = acc.shape[1:]
    F = int(np.prod(feat))
    K = grads.shape[1]
    pad_f = (-F) % block_f
    pad_k = (-K) % block_k
    af = jnp.pad(acc.reshape(B, F), ((0, 0), (0, pad_f)))
    gf = jnp.pad(grads.reshape(B, K, F), ((0, 0), (0, pad_k), (0, pad_f)))
    wf = jnp.pad(weights, ((0, 0), (0, pad_k)))
    out = ig_accum_pallas(
        af,
        gf,
        wf,
        block_k=min(block_k, K + pad_k),
        block_f=min(block_f, F + pad_f),
        interpret=interpret,
    )
    return out[:, :F].reshape((B,) + feat)


__all__ = ["ig_accum", "ig_accum_ref"]
