"""Pure-jnp oracle for the weighted Riemann accumulation kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ig_accum_ref(acc: jax.Array, grads: jax.Array, weights: jax.Array) -> jax.Array:
    """acc: (B, F) f32; grads: (B, K, F); weights: (B, K) -> (B, F) f32.

    out[b, f] = acc[b, f] + Σ_k weights[b, k] * grads[b, k, f]
    """
    return acc + jnp.einsum(
        "bkf,bk->bf", grads.astype(jnp.float32), weights.astype(jnp.float32)
    )
