"""Pure-jnp oracles for the accumulation kernels (riemann + IDGI)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ig_accum_ref(acc: jax.Array, grads: jax.Array, weights: jax.Array) -> jax.Array:
    """acc: (B, F) f32; grads: (B, K, F); weights: (B, K) -> (B, F) f32.

    out[b, f] = acc[b, f] + Σ_k weights[b, k] * grads[b, k, f]
    """
    return acc + jnp.einsum(
        "bkf,bk->bf", grads.astype(jnp.float32), weights.astype(jnp.float32)
    )


def ig_accum_idgi_ref(
    acc: jax.Array, grads: jax.Array, weights: jax.Array, diff: jax.Array
) -> jax.Array:
    """IDGI accumulation (repro.core.methods.idgi_accum, DESIGN.md §8).

    acc: (B, F) f32; grads: (B, K, F); weights: (B, K); diff: (B, F).
    out[b, f] = acc[b, f] + Σ_k c[b, k] * grads[b, k, f]²
    with  c[b, k] = weights[b, k] · ⟨g_k, diff⟩ / ⟨g_k, g_k⟩  (0 where ⟨g,g⟩=0).
    """
    g = grads.astype(jnp.float32)
    d = diff.astype(jnp.float32)
    s = jnp.einsum("bkf,bkf->bk", g, g)
    p = jnp.einsum("bkf,bf->bk", g, d)
    c = weights.astype(jnp.float32) * p * jnp.where(s > 0.0, 1.0 / jnp.where(s > 0.0, s, 1.0), 0.0)
    return acc + jnp.einsum("bkf,bk->bf", g * g, c)
