"""Fused accumulation kernels for the stage-2 hot loop.

Riemann: acc += Σ_k w_k · g_k. The non-uniform interval widths ride in w —
stage 2 of the paper is exactly this reduction. Fusing keeps the running
attribution tile resident in VMEM across the K (steps) grid dimension instead
of K× read-modify-write round trips to HBM (memory-bound op: 1 output write
per K-tile instead of K).

Grid: (B, F/Ft, K/Kt) — K is the innermost (sequential) dimension so the
output tile is revisited with carry semantics; f32 accumulation.

IDGI (DESIGN.md §8) adds the gradient-direction weighting
``acc += Σ_k c_k g_k²`` with ``c_k = w_k ⟨g_k, diff⟩ / ⟨g_k, g_k⟩``. The two
inner products reduce over ALL of F, which an F-tiled carry grid cannot see
at once — so the op runs two passes over the same tiling: a dots kernel
(grid (B, K/Kt, F/Ft), F innermost, carrying the (1, Kt) partial dots) and a
squared-grad accumulation kernel that reuses the riemann carry structure with
the per-(b, k) coefficient in place of the weight. Both passes stay
memory-bound single reads of g; g² is fused, never materialized in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _accum_kernel(acc_ref, g_ref, w_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = acc_ref[...].astype(jnp.float32)

    g = g_ref[...].astype(jnp.float32)  # (1, Kt, Ft)
    w = w_ref[...].astype(jnp.float32)  # (1, Kt)
    o_ref[...] += jnp.sum(g * w[..., None], axis=1)  # (1, Ft)


def _dots_kernel(g_ref, d_ref, s_ref, p_ref):
    f = pl.program_id(2)

    @pl.when(f == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        p_ref[...] = jnp.zeros_like(p_ref)

    g = g_ref[...].astype(jnp.float32)  # (1, Kt, Ft)
    d = d_ref[...].astype(jnp.float32)  # (1, Ft)
    s_ref[...] += jnp.sum(g * g, axis=2)  # (1, Kt)
    p_ref[...] += jnp.sum(g * d[:, None, :], axis=2)


def _accum_sq_kernel(acc_ref, g_ref, c_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = acc_ref[...].astype(jnp.float32)

    g = g_ref[...].astype(jnp.float32)  # (1, Kt, Ft)
    c = c_ref[...].astype(jnp.float32)  # (1, Kt)
    o_ref[...] += jnp.sum((g * g) * c[..., None], axis=1)  # (1, Ft)


@functools.partial(jax.jit, static_argnames=("block_k", "block_f", "interpret"))
def idgi_dots_pallas(
    grads: jax.Array,
    diff: jax.Array,
    *,
    block_k: int = 8,
    block_f: int = 512,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """grads (B, K, F); diff (B, F) -> (⟨g,g⟩ (B, K) f32, ⟨g,diff⟩ (B, K) f32)."""
    B, K, F = grads.shape
    bk, bf = min(block_k, K), min(block_f, F)
    assert K % bk == 0 and F % bf == 0, (K, bk, F, bf)
    grid = (B, K // bk, F // bf)
    return pl.pallas_call(
        _dots_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bk, bf), lambda b, k, f: (b, k, f)),
            pl.BlockSpec((1, bf), lambda b, k, f: (b, f)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk), lambda b, k, f: (b, k)),
            pl.BlockSpec((1, bk), lambda b, k, f: (b, k)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, K), jnp.float32),
            jax.ShapeDtypeStruct((B, K), jnp.float32),
        ],
        interpret=interpret,
    )(grads, diff)


@functools.partial(jax.jit, static_argnames=("block_k", "block_f", "interpret"))
def ig_accum_sq_pallas(
    acc: jax.Array,
    grads: jax.Array,
    coeff: jax.Array,
    *,
    block_k: int = 8,
    block_f: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """acc (B, F) f32; grads (B, K, F); coeff (B, K) -> (B, F) f32.

    out = acc + Σ_k coeff_k · g_k² — the IDGI weighting pass (g² fused)."""
    B, K, F = grads.shape
    bk, bf = min(block_k, K), min(block_f, F)
    assert K % bk == 0 and F % bf == 0, (K, bk, F, bf)
    grid = (B, F // bf, K // bk)
    return pl.pallas_call(
        _accum_sq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bf), lambda b, f, k: (b, f)),
            pl.BlockSpec((1, bk, bf), lambda b, f, k: (b, k, f)),
            pl.BlockSpec((1, bk), lambda b, f, k: (b, k)),
        ],
        out_specs=pl.BlockSpec((1, bf), lambda b, f, k: (b, f)),
        out_shape=jax.ShapeDtypeStruct((B, F), jnp.float32),
        interpret=interpret,
    )(acc, grads, coeff)


@functools.partial(jax.jit, static_argnames=("block_k", "block_f", "interpret"))
def ig_accum_pallas(
    acc: jax.Array,
    grads: jax.Array,
    weights: jax.Array,
    *,
    block_k: int = 8,
    block_f: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """acc (B, F) f32; grads (B, K, F); weights (B, K) -> (B, F) f32."""
    B, K, F = grads.shape
    bk, bf = min(block_k, K), min(block_f, F)
    assert K % bk == 0 and F % bf == 0, (K, bk, F, bf)
    grid = (B, F // bf, K // bk)
    return pl.pallas_call(
        _accum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bf), lambda b, f, k: (b, f)),
            pl.BlockSpec((1, bk, bf), lambda b, f, k: (b, k, f)),
            pl.BlockSpec((1, bk), lambda b, f, k: (b, k)),
        ],
        out_specs=pl.BlockSpec((1, bf), lambda b, f, k: (b, f)),
        out_shape=jax.ShapeDtypeStruct((B, F), jnp.float32),
        interpret=interpret,
    )(acc, grads, weights)
