"""Fused weighted Riemann-sum accumulation: acc += Σ_k w_k · g_k.

The non-uniform interval widths ride in w — stage 2 of the paper is exactly
this reduction. Fusing keeps the running attribution tile resident in VMEM
across the K (steps) grid dimension instead of K× read-modify-write round
trips to HBM (memory-bound op: 1 output write per K-tile instead of K).

Grid: (B, F/Ft, K/Kt) — K is the innermost (sequential) dimension so the
output tile is revisited with carry semantics; f32 accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _accum_kernel(acc_ref, g_ref, w_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = acc_ref[...].astype(jnp.float32)

    g = g_ref[...].astype(jnp.float32)  # (1, Kt, Ft)
    w = w_ref[...].astype(jnp.float32)  # (1, Kt)
    o_ref[...] += jnp.sum(g * w[..., None], axis=1)  # (1, Ft)


@functools.partial(jax.jit, static_argnames=("block_k", "block_f", "interpret"))
def ig_accum_pallas(
    acc: jax.Array,
    grads: jax.Array,
    weights: jax.Array,
    *,
    block_k: int = 8,
    block_f: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """acc (B, F) f32; grads (B, K, F); weights (B, K) -> (B, F) f32."""
    B, K, F = grads.shape
    bk, bf = min(block_k, K), min(block_f, F)
    assert K % bk == 0 and F % bf == 0, (K, bk, F, bf)
    grid = (B, F // bf, K // bk)
    return pl.pallas_call(
        _accum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bf), lambda b, f, k: (b, f)),
            pl.BlockSpec((1, bk, bf), lambda b, f, k: (b, k, f)),
            pl.BlockSpec((1, bk), lambda b, f, k: (b, k)),
        ],
        out_specs=pl.BlockSpec((1, bf), lambda b, f, k: (b, f)),
        out_shape=jax.ShapeDtypeStruct((B, F), jnp.float32),
        interpret=interpret,
    )(acc, grads, weights)
