"""Serving engine: batched prefill + decode with a static KV cache.

``make_serve_step``/``make_prefill_step`` return the pure functions the
multi-pod dry-run lowers for the decode_*/prefill_* cells. ``ServeEngine``
drives them for real batched generation (examples/serve_lm.py).

The cache is fully static-shape (max_len fixed at engine construction);
decode_32k lowers one new token against a seq_len cache, exactly as the
assignment specifies.

Decoding modes: ``greedy=True`` (the default everywhere) is argmax;
``greedy=False`` is temperature/categorical sampling and requires an explicit
PRNG key — the step/loop signatures grow a ``key`` argument so sampling can
never silently fall back to argmax. ``make_decode_chunk`` is the unified
serving path's unit (``repro.serve.scheduler``): a fixed-length scanned chunk
that also emits each chosen token's log-probability, which is exactly the
stage-1 probe endpoint ``f(x)`` an attached explain request needs — the
decode forward pays for it once and the explain path reuses it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.registry import Model


def make_prefill_step(cfg: ArchConfig, max_len: int, *, kv_slots: int = 0) -> Callable:
    model = Model(cfg)

    def prefill_step(params: Any, batch: dict) -> tuple[jax.Array, dict]:
        logits, cache = model.prefill(params, batch, max_len, kv_slots=kv_slots)
        return logits, cache

    return prefill_step


def sample_token(
    logits: jax.Array, key: jax.Array, temperature: jax.Array
) -> jax.Array:
    """(B, V) logits -> (B,) sampled ids at ``temperature`` (runtime scalar).

    The temperature rides the program as data, so one compiled sampler serves
    every temperature; ``temperature`` must be > 0 (greedy is its own step).
    """
    lg = logits.astype(jnp.float32) / temperature.astype(jnp.float32)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)


def make_serve_step(cfg: ArchConfig, *, greedy: bool = True) -> Callable:
    """Decode-step builder.

    greedy=True:  (params, cache, token (B,1)) -> (next (B,1), cache) — argmax.
    greedy=False: (params, cache, token (B,1), key, temperature) ->
                  (next (B,1), cache) — categorical sampling. The explicit
                  key/temperature arguments are the fix for the historical
                  bug where ``greedy=False`` silently served argmax.
    """
    model = Model(cfg)

    if greedy:

        def serve_step(params: Any, cache: dict, token: jax.Array) -> tuple[jax.Array, dict]:
            logits, cache = model.decode_step(params, cache, token)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            return nxt, cache

        return serve_step

    def sample_step(
        params: Any, cache: dict, token: jax.Array, key: jax.Array,
        temperature: jax.Array,
    ) -> tuple[jax.Array, dict]:
        logits, cache = model.decode_step(params, cache, token)
        nxt = sample_token(logits[:, -1], key, temperature)[:, None]
        return nxt, cache

    return sample_step


def make_decode_loop(cfg: ArchConfig, *, greedy: bool = True) -> Callable:
    """Scanned decode loop; one compiled program per generation length.

    greedy=True:  (params, cache, token (B,1), num_steps) -> tokens (B, n).
    greedy=False: (params, cache, token (B,1), key, temperature, num_steps)
                  -> tokens (B, n); step k samples with fold_in(key, k).

    ``lax.scan`` over the serve step: one compiled program per generation
    length instead of num_steps host round-trips, with the cache carried
    (and donatable) on-device for the whole loop.
    """
    step = make_serve_step(cfg, greedy=greedy)

    if greedy:

        def decode_loop(
            params: Any, cache: dict, token: jax.Array, num_steps: int
        ) -> jax.Array:
            def body(carry, _):
                tok, cache = carry
                nxt, cache = step(params, cache, tok)
                return (nxt, cache), nxt

            _, toks = jax.lax.scan(body, (token, cache), None, length=num_steps)
            return toks[..., 0].swapaxes(0, 1)  # (n, B, 1) -> (B, n)

        return decode_loop

    def sample_loop(
        params: Any, cache: dict, token: jax.Array, key: jax.Array,
        temperature: jax.Array, num_steps: int,
    ) -> jax.Array:
        def body(carry, k):
            tok, cache = carry
            nxt, cache = step(params, cache, tok, jax.random.fold_in(key, k), temperature)
            return (nxt, cache), nxt

        _, toks = jax.lax.scan(
            body, (token, cache), jnp.arange(num_steps), length=num_steps
        )
        return toks[..., 0].swapaxes(0, 1)

    return sample_loop


def make_decode_chunk(cfg: ArchConfig) -> Callable:
    """The unified serving path's preemptible decode unit.

    (params, cache, token (B,1), key, temperature, num_steps) ->
        (tokens (B, n), logprobs (B, n), cache)

    One scanned chunk of ``num_steps`` tokens that ALSO emits each chosen
    token's log-probability — ``log_softmax(logits)[chosen]`` is exactly the
    explain stage-1 probe endpoint ``f(x)`` for "attribute the prefix toward
    the emitted token", so explain-as-you-serve traffic never re-runs the
    forward the decode loop already paid for. ``temperature`` is runtime
    data; ``temperature <= 0`` selects greedy argmax (via ``lax.cond``-free
    ``where``), so one compiled chunk serves both modes.
    """
    model = Model(cfg)

    def decode_chunk(
        params: Any, cache: dict, token: jax.Array, key: jax.Array,
        temperature: jax.Array, num_steps: int,
    ) -> tuple[jax.Array, jax.Array, dict]:
        def body(carry, k):
            tok, cache = carry
            logits, cache = model.decode_step(params, cache, tok)
            lg = logits[:, -1].astype(jnp.float32)
            greedy_tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            sampled = sample_token(lg, jax.random.fold_in(key, k),
                                   jnp.maximum(temperature, 1e-6))
            nxt = jnp.where(temperature > 0, sampled, greedy_tok)
            lp = jax.nn.log_softmax(lg, axis=-1)[jnp.arange(lg.shape[0]), nxt]
            return (nxt[:, None], cache), (nxt, lp)

        (_, cache), (toks, lps) = jax.lax.scan(
            body, (token, cache), jnp.arange(num_steps), length=num_steps
        )
        return toks.swapaxes(0, 1), lps.swapaxes(0, 1), cache

    return decode_chunk


@dataclass
class ServeEngine:
    """Batched generation over a static cache (greedy or sampled)."""

    cfg: ArchConfig
    params: Any
    max_len: int
    kv_slots: int = 0

    def __post_init__(self):
        self._prefill = jax.jit(make_prefill_step(self.cfg, self.max_len, kv_slots=self.kv_slots))
        # cache state is donated into the scan — the decode loop reuses the
        # prefill cache buffers instead of holding both alive
        self._decode = jax.jit(
            make_decode_loop(self.cfg), static_argnums=(3,), donate_argnums=(1,)
        )
        self._decode_sampled = jax.jit(
            make_decode_loop(self.cfg, greedy=False),
            static_argnums=(5,), donate_argnums=(1,),
        )

    def generate(
        self,
        batch: dict,
        num_tokens: int,
        *,
        key: Optional[jax.Array] = None,
        temperature: float = 1.0,
    ) -> jax.Array:
        """batch: prompt dict -> (B, num_tokens) generated ids.

        Greedy argmax decoding by default; pass ``key`` to sample at
        ``temperature`` instead (the prefill token is sampled too, with
        ``fold_in(key, 2**32 - 1)`` so it never collides with a loop step key).
        ``num_tokens <= 0`` generates nothing and returns an empty (B, 0)
        array — it must NOT emit the free prefill token.
        """
        B = batch["tokens"].shape[0]
        if num_tokens <= 0:
            return jnp.zeros((B, 0), jnp.int32)
        logits, cache = self._prefill(self.params, batch)
        lg = logits[:, -1]
        if key is None:
            tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]
        else:
            temp = jnp.asarray(temperature, jnp.float32)
            tok = sample_token(lg, jax.random.fold_in(key, 2**32 - 1), temp)[:, None]
        if num_tokens == 1:  # the prefill token is free; scan needs length >= 1
            return tok
        if key is None:
            rest = self._decode(self.params, cache, tok, num_tokens - 1)
        else:
            rest = self._decode_sampled(
                self.params, cache, tok, key, temp, num_tokens - 1
            )
        return jnp.concatenate([tok, rest], axis=1)
