"""Serving engine: batched prefill + decode with a static KV cache.

``make_serve_step``/``make_prefill_step`` return the pure functions the
multi-pod dry-run lowers for the decode_*/prefill_* cells. ``ServeEngine``
drives them for real batched generation (examples/serve_lm.py).

The cache is fully static-shape (max_len fixed at engine construction);
decode_32k lowers one new token against a seq_len cache, exactly as the
assignment specifies.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.registry import Model


def make_prefill_step(cfg: ArchConfig, max_len: int, *, kv_slots: int = 0) -> Callable:
    model = Model(cfg)

    def prefill_step(params: Any, batch: dict) -> tuple[jax.Array, dict]:
        logits, cache = model.prefill(params, batch, max_len, kv_slots=kv_slots)
        return logits, cache

    return prefill_step


def make_serve_step(cfg: ArchConfig, *, greedy: bool = True) -> Callable:
    """(params, cache, token (B,1)) -> (next_token (B,1), cache)."""
    model = Model(cfg)

    def serve_step(params: Any, cache: dict, token: jax.Array) -> tuple[jax.Array, dict]:
        logits, cache = model.decode_step(params, cache, token)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache

    return serve_step


def make_decode_loop(cfg: ArchConfig) -> Callable:
    """(params, cache, token (B,1), num_steps) -> tokens (B, num_steps).

    ``lax.scan`` over the serve step: one compiled program per generation
    length instead of num_steps host round-trips, with the cache carried
    (and donatable) on-device for the whole loop.
    """
    step = make_serve_step(cfg)

    def decode_loop(
        params: Any, cache: dict, token: jax.Array, num_steps: int
    ) -> jax.Array:
        def body(carry, _):
            tok, cache = carry
            nxt, cache = step(params, cache, tok)
            return (nxt, cache), nxt

        _, toks = jax.lax.scan(body, (token, cache), None, length=num_steps)
        return toks[..., 0].swapaxes(0, 1)  # (n, B, 1) -> (B, n)

    return decode_loop


@dataclass
class ServeEngine:
    """Greedy batched generation over a static cache."""

    cfg: ArchConfig
    params: Any
    max_len: int
    kv_slots: int = 0

    def __post_init__(self):
        self._prefill = jax.jit(make_prefill_step(self.cfg, self.max_len, kv_slots=self.kv_slots))
        # cache state is donated into the scan — the decode loop reuses the
        # prefill cache buffers instead of holding both alive
        self._decode = jax.jit(
            make_decode_loop(self.cfg), static_argnums=(3,), donate_argnums=(1,)
        )

    def generate(self, batch: dict, num_tokens: int) -> jax.Array:
        """batch: prompt dict -> (B, num_tokens) generated ids (greedy)."""
        logits, cache = self._prefill(self.params, batch)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        if num_tokens <= 1:  # the prefill token is free; scan needs length >= 1
            return tok
        rest = self._decode(self.params, cache, tok, num_tokens - 1)
        return jnp.concatenate([tok, rest], axis=1)
