"""Content-addressed attribution result cache (ISSUE 10 / docs/caching.md).

At scale the dominant explain traffic pattern is REPEATS: the same
(input, baseline, method) tuple arriving again. The cheapest gradient step
is the one never taken — this module stores finished attribution result
dicts under a sha256 content key and replays them bit-identically.

Key contract (``ExplainEngine.request_cache_key``): the key is sha256 over
the engine's *cache context* — everything that changes the produced bytes:
method name (NOT the accumulator class: IDGI and IG attributions for the
same input are different artifacts even though they share executables),
schedule family, (m, n_int, chunk), the adaptive knobs (tol, m_max),
ensemble identity (n_samples, sigma, sample_seed), the forward-only mask
budget, fused/use_kernels/attn program flags, the mesh axis sizes, the
baseline id (pad_id), the model fingerprint (config + params sha256,
``core.fingerprint``), and a fingerprint of the loaded autotune entries
(a tuned chunk changes scan boundaries and therefore bits) — concatenated
with the request's own bytes: tokens, target, feature bytes, and the
donated ``f_x`` endpoint (kept conservatively: a different donated value is
a different program input).

NOT keyed (see docs/caching.md for the full argument): the bucket shape and
batch composition a request happens to land in — the padding-invariance
contract makes results independent of co-batched traffic — and the hop-zero
δ-history, which only moves the adaptive starting rung for MISSES.

Replay is bit-identical by construction: ``get`` returns a fresh deep copy
of the stored dict (arrays copied), so callers can never mutate the cached
bytes; eviction is LRU under a byte budget with hit/miss/eviction counters
mirrored onto ``EngineStats``.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional

import numpy as np

DEFAULT_BUDGET_BYTES = 256 * 1024 * 1024


def _entry_bytes(result: dict) -> int:
    """Approximate resident size of one cached result dict."""
    n = 0
    for k, v in result.items():
        n += len(k) + 48  # dict slot + key overhead
        if isinstance(v, np.ndarray):
            n += int(v.nbytes)
        else:
            n += 32
    return n


def _copy_result(result: dict) -> dict:
    """Deep-enough copy: arrays are copied, scalars/tuples are immutable."""
    return {
        k: (v.copy() if isinstance(v, np.ndarray) else v)
        for k, v in result.items()
    }


class ResultCache:
    """Byte-budget LRU of finished attribution result dicts.

        >>> import numpy as np
        >>> rc = ResultCache(max_bytes=1 << 20)
        >>> rc.put("k", {"token_scores": np.ones(4, np.float32)})
        >>> hit = rc.get("k")
        >>> hit["token_scores"][0] = 0.0   # caller mutation...
        >>> rc.get("k")["token_scores"][0]  # ...never corrupts the cache
        np.float32(1.0)
        >>> rc.get("absent") is None
        True
        >>> rc.hits, rc.misses
        (2, 1)
    """

    def __init__(self, max_bytes: int = DEFAULT_BUDGET_BYTES):
        assert max_bytes > 0, "a result cache needs a positive byte budget"
        self.max_bytes = int(max_bytes)
        self._entries: OrderedDict[str, tuple[dict, int]] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.bytes = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: str) -> bool:
        return key in self._entries

    def get(self, key: str) -> Optional[dict]:
        """The stored result as a fresh copy, or None; counts hit/miss and
        refreshes LRU recency on hit."""
        ent = self._entries.get(key)
        if ent is None:
            self.misses += 1
            return None
        self.hits += 1
        self._entries.move_to_end(key)
        return _copy_result(ent[0])

    def put(self, key: str, result: dict) -> None:
        """Store a copy of ``result``; evicts LRU entries past the budget.

        An entry larger than the whole budget is refused (counted as an
        eviction) — storing it would immediately evict everything including
        itself. Re-putting an existing key replaces the entry (same bytes on
        the serving path: the key is content-addressed).
        """
        size = _entry_bytes(result)
        if size > self.max_bytes:
            self.evictions += 1
            return
        old = self._entries.pop(key, None)
        if old is not None:
            self.bytes -= old[1]
        self._entries[key] = (_copy_result(result), size)
        self.bytes += size
        while self.bytes > self.max_bytes:
            _, (_, esize) = self._entries.popitem(last=False)
            self.bytes -= esize
            self.evictions += 1
