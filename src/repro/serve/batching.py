"""Shape-bucketed request batching for the ExplainEngine (DESIGN.md §6).

Mixed-length prompts cannot share one compiled executable unless their shapes
agree, and compiling per exact length would recompile on nearly every request.
The classic serving answer is a *bucket ladder*: right-pad every request's
token sequence up to the smallest ladder rung ≥ its length (powers of two by
default), and pad the batch axis up to a batch ladder rung, so steady-state
traffic touches a small closed set of shapes — each compiled exactly once.

Padding is masked, not free: the plan carries a per-position real-token mask
that the NUIG pipeline threads through the stage-1 probe and stage-2
accumulation, so padded positions receive exactly zero attribution and δ is
computed over real tokens only. Batch-pad rows duplicate a real request (a
fully-masked row would make the probe degenerate) and are dropped on output.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import numpy as np

# Default sequence-bucket ladder: powers of two. Configurable per engine.
DEFAULT_SEQ_BUCKETS: tuple[int, ...] = (8, 16, 32, 64, 128, 256, 512, 1024)
# Default batch-bucket ladder: keeps (B, S) — not just S — a small closed set.
DEFAULT_BATCH_BUCKETS: tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64)


def pow2_ladder(max_size: int, *, start: int = 8) -> tuple[int, ...]:
    """Powers-of-two rungs start, 2·start, ... up to ≥ max_size."""
    out = [start]
    while out[-1] < max_size:
        out.append(out[-1] * 2)
    return tuple(out)


def bucket_for(size: int, ladder: Sequence[int]) -> int:
    """Smallest ladder rung ≥ size."""
    for b in ladder:
        if size <= b:
            return b
    raise ValueError(f"size {size} exceeds bucket ladder max {max(ladder)}")


def pad_rows(
    rows: Sequence[int],
    batch_buckets: Optional[Sequence[int]],
    *,
    multiple: int = 1,
) -> tuple[list[int], int]:
    """Pad a row-index list up the batch ladder by repeating the last row.

    The adaptive escalation path re-batches still-unconverged rows mid-flight
    (DESIGN.md §7); padding them to a ladder rung keeps hop executables on
    the same closed (B, S) shape set as plan-time batches. Pad slots repeat a
    real row (same reason as ``plan_buckets``: a fully-masked row would make
    the δ check degenerate) and are dropped on output.

    ``multiple`` is the mesh-divisibility contract (DESIGN.md §9): the padded
    B is additionally rounded up to a multiple of the mesh's data-parallel
    extent, so ``explain_shardings`` can always shard the batch axis instead
    of silently replicating. With the default pow-2 ladders and a pow-2 dp
    size the rounded set stays closed (``max(rung, dp)`` is still a rung or
    dp itself).

    Returns ``(padded_rows, B)`` with ``padded_rows[:len(rows)] == rows``.
    """
    rows = list(rows)
    assert rows, "pad_rows needs at least one row"
    B = bucket_for(len(rows), batch_buckets) if batch_buckets else len(rows)
    if multiple > 1:
        B = ((B + multiple - 1) // multiple) * multiple
    return rows + [rows[-1]] * (B - len(rows)), B


class BucketBatch(NamedTuple):
    """One padded, maskable batch of same-bucket requests."""

    bucket: tuple[int, int]  # (B_padded, S_padded) — the compile-cache shape
    indices: tuple[int, ...]  # request-list positions of the real rows
    tokens: np.ndarray  # (B, S) int32, right-padded with pad_id
    lens: np.ndarray  # (B,) int32 true lengths (pad rows repeat a real row)
    targets: np.ndarray  # (B,) int32
    mask: np.ndarray  # (B, S) float32, 1.0 on real tokens
    # feature-space requests (e.g. ViT patch features): (B, S, *F) float32,
    # zero-padded; None for token-only traffic
    features: Optional[np.ndarray] = None
    # known endpoint values f(x) donated by the decode path (probe-reuse
    # contract, DESIGN.md §11): (B,) float32, pad rows repeating a real row;
    # None when the engine must compute the endpoint itself. Requests with
    # and without a known endpoint never share a bucket (different compiled
    # probe signatures), so ``plan_buckets`` groups by (S, has_fx).
    f_x: Optional[np.ndarray] = None


def plan_buckets(
    requests: Sequence,
    *,
    seq_buckets: Sequence[int] = DEFAULT_SEQ_BUCKETS,
    batch_buckets: Optional[Sequence[int]] = DEFAULT_BATCH_BUCKETS,
    max_batch: int = 0,
    pad_id: int = 0,
    batch_multiple: int = 1,
) -> list[BucketBatch]:
    """Group heterogeneous ExplainRequests into padded shape buckets.

    requests: objects with ``.tokens`` (1-D int array) and ``.target`` (int);
    an optional ``.features`` ((S, *F) float array, e.g. ViT patch features)
    rides the plan zero-padded — all requests in a plan must agree on whether
    they carry features (mixed traffic would need per-bucket model facades).
    max_batch caps real rows per batch (0 = unlimited); batch_buckets=None
    disables batch-axis padding (B = number of grouped rows).
    ``batch_multiple`` rounds every padded B up to a multiple of the mesh's
    data-parallel extent (mesh-divisible padding, DESIGN.md §9) so sharded
    engines never fall back to replication.
    """
    groups: dict[tuple[int, bool], list[int]] = {}
    for i, r in enumerate(requests):
        has_fx = getattr(r, "f_x", None) is not None
        key = (bucket_for(len(r.tokens), seq_buckets), has_fx)
        groups.setdefault(key, []).append(i)

    out: list[BucketBatch] = []
    for S, has_fx in sorted(groups):
        idx = groups[(S, has_fx)]
        step = max_batch if max_batch else len(idx)
        if batch_buckets:
            step = min(step, max(batch_buckets))  # never outgrow the ladder
        for lo in range(0, len(idx), step):
            rows = idx[lo : lo + step]
            padded_rows, B = pad_rows(rows, batch_buckets, multiple=batch_multiple)
            tokens = np.full((B, S), pad_id, np.int32)
            lens = np.empty((B,), np.int32)
            targets = np.empty((B,), np.int32)
            mask = np.zeros((B, S), np.float32)
            features = None
            fx = np.empty((B,), np.float32) if has_fx else None
            has_feat = getattr(requests[padded_rows[0]], "features", None) is not None
            for j, ri in enumerate(padded_rows):
                t = np.asarray(requests[ri].tokens, np.int32)
                tokens[j, : len(t)] = t
                lens[j] = len(t)
                targets[j] = int(requests[ri].target)
                mask[j, : len(t)] = 1.0
                if has_fx:
                    fx[j] = float(requests[ri].f_x)
                f = getattr(requests[ri], "features", None)
                if (f is not None) != has_feat:
                    raise ValueError(
                        "plan_buckets: mixed feature/token requests in one plan"
                    )
                if f is not None:
                    f = np.asarray(f, np.float32)
                    if features is None:
                        features = np.zeros((B, S) + f.shape[1:], np.float32)
                    features[j, : f.shape[0]] = f
            out.append(
                BucketBatch(
                    (B, S), tuple(rows), tokens, lens, targets, mask, features, fx
                )
            )
    return out
