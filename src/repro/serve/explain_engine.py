"""ExplainEngine — shape-bucketed NUIG serving with a compiled-executable cache.

The paper's 2.6–3.6× latency win assumes the two-stage pipeline runs as ONE
hot compiled program. This engine makes that true under real traffic:

  * heterogeneous ``ExplainRequest``s are padded into shape buckets
    (``repro.serve.batching``: powers-of-two S, configurable ladder, plus a
    batch-axis ladder so (B, S) is a small closed set);
  * padded positions are masked out of the stage-1 probe and the stage-2
    attribution/δ (see ``repro.core.ig.attribute``'s ``mask``) — they receive
    exactly zero attribution and δ is over real tokens only;
  * one executable per ``(bucket_shape, method, m, n_int, chunk)`` key is
    AOT-compiled (``jit(...).lower(...).compile()``) and cached, so
    steady-state traffic never recompiles — the cache and its hit/miss/latency
    stats are first-class, inspectable state;
  * every schedule family in ``repro.core.schedule.SCHEDULES`` rides the same
    compiled path (the registry's uniform builder signature);
  * an optional mesh shards the folded (batch × step) stage-2 axis via the
    pjit specs in ``repro.sharding`` (``explain_shardings``).

``ExplainService`` remains as a thin compatibility shim over this engine.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.api import Explainer
from repro.core.baselines import pad_embedding
from repro.models.registry import Model
from repro.serve.batching import (
    DEFAULT_BATCH_BUCKETS,
    DEFAULT_SEQ_BUCKETS,
    BucketBatch,
    plan_buckets,
)


@dataclass(frozen=True)
class ExplainRequest:
    tokens: np.ndarray  # (S,) int32 prompt — lengths may differ per request
    target: int  # token id whose next-token log-prob is attributed


@dataclass
class BucketStats:
    compiles: int = 0
    calls: int = 0
    requests: int = 0
    compile_s: float = 0.0
    total_s: float = 0.0  # wall time of cached calls (excludes compiles)

    @property
    def mean_latency_s(self) -> float:
        return self.total_s / self.calls if self.calls else 0.0


@dataclass
class EngineStats:
    hits: int = 0  # executable-cache hits
    misses: int = 0  # executable-cache misses == compilations
    buckets: dict = field(default_factory=dict)  # (B, S) -> BucketStats

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def bucket(self, shape: tuple[int, int]) -> BucketStats:
        return self.buckets.setdefault(shape, BucketStats())


class ExplainEngine:
    """Bucketed, cache-compiled NUIG serving over one model + param set."""

    def __init__(
        self,
        cfg: ArchConfig,
        params: Any,
        *,
        method: str = "paper",
        m: int = 64,
        n_int: int = 4,
        chunk: int = 0,
        refine_rounds: int = 4,
        power: float = 0.5,
        pad_id: int = 0,
        seq_buckets: Sequence[int] = DEFAULT_SEQ_BUCKETS,
        batch_buckets: Optional[Sequence[int]] = DEFAULT_BATCH_BUCKETS,
        max_batch: int = 0,
        mesh: Optional[jax.sharding.Mesh] = None,
    ):
        self.cfg = cfg
        self.params = params
        self.method = method
        self.m = m
        self.n_int = n_int
        self.chunk = chunk
        self.pad_id = pad_id
        self.seq_buckets = tuple(seq_buckets)
        self.batch_buckets = tuple(batch_buckets) if batch_buckets else None
        self.max_batch = max_batch
        self.mesh = mesh
        self.model = Model(cfg)
        self.stats = EngineStats()
        self._cache: dict[tuple, Any] = {}  # key -> compiled executable
        self._explainer = Explainer(
            self.model.target_logprob_at_fn(params),
            method=method,
            m=m,
            n_int=n_int,
            chunk=chunk,
            refine_rounds=refine_rounds,
            power=power,
        )

    # -- compiled-executable cache ----------------------------------------

    def _key(self, bucket: tuple[int, int]) -> tuple:
        return (bucket, self.method, self.m, self.n_int, self.chunk)

    def _attr_fn(self, embeds, baseline, aux, mask):
        return self._explainer.attribute(embeds, baseline, aux, mask=mask)

    def _executable(self, bucket: tuple[int, int], args: tuple) -> Any:
        """AOT-compiled stage1+stage2 program for one bucket shape."""
        key = self._key(bucket)
        hit = key in self._cache
        bs = self.stats.bucket(bucket)
        if hit:
            self.stats.hits += 1
            return self._cache[key]
        self.stats.misses += 1
        bs.compiles += 1
        t0 = time.perf_counter()
        jit_kw = {}
        if self.mesh is not None:
            from repro.sharding import explain_shardings

            shardings = explain_shardings(self.mesh, batch=bucket[0])
            if shardings is not None:
                jit_kw["in_shardings"] = shardings
        sds = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), args)
        compiled = jax.jit(self._attr_fn, **jit_kw).lower(*sds).compile()
        bs.compile_s += time.perf_counter() - t0
        self._cache[key] = compiled
        return compiled

    # -- serving -----------------------------------------------------------

    def _run_bucket(self, bb: BucketBatch) -> Any:
        tokens = jnp.asarray(bb.tokens)
        aux = {
            "target": jnp.asarray(bb.targets, jnp.int32),
            "pos": jnp.asarray(bb.lens - 1, jnp.int32),
        }
        mask = jnp.asarray(bb.mask)
        embeds = self.model.embed_inputs(self.params, {"tokens": tokens})
        # PAD-token embedding, not zeros: RMSNorm backbones are scale-
        # invariant through their first norm, so a ray through the origin
        # has (near-)zero gradient a.e. and completeness can never converge.
        baseline = pad_embedding(
            self.params["embed"]["embedding"], embeds, pad_id=self.pad_id
        )
        args = (embeds, baseline, aux, mask)
        fn = self._executable(bb.bucket, args)
        bs = self.stats.bucket(bb.bucket)
        t0 = time.perf_counter()
        res = fn(*args)
        res = jax.block_until_ready(res)
        bs.total_s += time.perf_counter() - t0
        bs.calls += 1
        bs.requests += len(bb.indices)
        return res

    def explain(
        self, requests: Sequence[ExplainRequest], *, return_raw: bool = False
    ) -> list[dict]:
        """Serve a heterogeneous batch; results align with ``requests``.

        Each result dict: token_scores (S_req,), delta, f_x, f_baseline,
        bucket (B, S); with ``return_raw`` also raw_token_scores (S_bucket,)
        — the untrimmed row, exactly zero at padded positions.
        """
        plan = plan_buckets(
            requests,
            seq_buckets=self.seq_buckets,
            batch_buckets=self.batch_buckets,
            max_batch=self.max_batch,
            pad_id=self.pad_id,
        )
        out: list[Optional[dict]] = [None] * len(requests)
        for bb in plan:
            res = self._run_bucket(bb)
            per_token = np.asarray(res.attributions.sum(-1))  # (B, S)
            for row, ri in enumerate(bb.indices):
                r = {
                    "token_scores": per_token[row, : bb.lens[row]],
                    "delta": float(res.delta[row]),
                    "f_x": float(res.f_x[row]),
                    "f_baseline": float(res.f_baseline[row]),
                    "bucket": bb.bucket,
                }
                if return_raw:
                    r["raw_token_scores"] = per_token[row]
                out[ri] = r
        return out
