"""ExplainEngine — shape-bucketed NUIG serving with a compiled-executable cache.

The paper's 2.6–3.6× latency win assumes the two-stage pipeline runs as ONE
hot compiled program. This engine makes that true under real traffic:

  * heterogeneous ``ExplainRequest``s are padded into shape buckets
    (``repro.serve.batching``: powers-of-two S, configurable ladder, plus a
    batch-axis ladder so (B, S) is a small closed set);
  * padded positions are masked out of the stage-1 probe and the stage-2
    attribution/δ (see ``repro.core.ig.attribute``'s ``mask``) — they receive
    exactly zero attribution and δ is over real tokens only;
  * one executable per ``(bucket_shape, accumulator, schedule, m, n_int,
    chunk)`` key is AOT-compiled (``jit(...).lower(...).compile()``) and
    cached, so steady-state traffic never recompiles — the cache and its
    hit/miss/latency stats are first-class, inspectable state;
  * every schedule family in ``repro.core.schedule.SCHEDULES`` rides the same
    compiled path (the registry's uniform builder signature), and so does
    every attribution method in ``repro.core.methods.METHODS`` (DESIGN.md §8):
    executables are keyed by the method's accumulator CLASS (``spec.accum``),
    so ``ig``/``noise_tunnel``/``expected_grad`` share one warmed riemann set
    and ``idgi`` compiles its own — either way the shape set stays closed.
    Path-ensemble methods are served by replicating each request
    ``n_samples``× at plan time and perturbing rows in embedding space at
    batch-construction time (outside the compiled program), then averaging
    each request's contiguous sample results;
  * an optional device mesh shards the folded (batch × step) stage-2 axis
    via the pjit specs in ``repro.sharding`` (DESIGN.md §9): every bucket /
    start / hop executable is compiled with ``NamedSharding``s resolved per
    argument tree (``explain_arg_shardings``), cache keys carry the mesh axis
    sizes (``mesh_cache_key``) so single-device and sharded entries coexist,
    and bucket batches are padded up to a multiple of the data-parallel
    extent (``dp_size``) at plan time so the shardings always apply. δ and
    the adaptive escalation decisions are computed from device-local per-row
    reductions (feature axes stay replicated), so a sharded engine escalates
    bit-identically to the unsharded one. A bucket that somehow reaches the
    compile step without a dp-divisible batch serves replicated and is
    counted in ``EngineStats.mesh_fallbacks`` — never silently.

**Hot-path bandwidth** (DESIGN.md §10): with ``fused=True`` stage 2 composes
interpolation with the model forward under one VJP
(``ig.attribute(fused=True)``), so the (B·chunk, *F) interpolant batch never
crosses a program boundary and riemann-class methods collapse the per-step
gradient batch into one (B, *F) cotangent. Hop executables donate their
``IGState`` (ladder escalation reuses the f32 accumulator buffer in place),
``autotune=True`` loads per-(bucket, device) tuned (chunk, block_k, block_f)
configs from ``serve.autotune``'s on-disk cache, ``use_kernels=True``
injects the Pallas kernel set at those block sizes, and every compile
records its ``cost_analysis`` bytes-accessed / peak-bytes budget on the
bucket's stats row.

**Adaptive iso-convergence** (``adaptive=True``, DESIGN.md §7): ``m`` becomes
the base rung of a pow-2 m-ladder instead of a fixed budget. Each bucket runs
rung 0 (probe + base schedule + resumable accumulation), then examples whose
completeness gap δ still exceeds ``tol · |f(x) − f(x′)|`` are re-batched
together and escalated: their schedules are refined (nested doubling — prior
gradients are never discarded, see ``schedule.refine_nested``) and only the
NEW nodes run, through "hop" executables keyed on ``(bucket, n_new, chunk)``
— method-independent, because schedules are data. Ladder hops therefore only
ever touch the same closed set of warmed shapes as fixed-m serving: zero
recompiles at steady state, per-request shapes never exist.

``ExplainService`` remains as a thin compatibility shim over this engine.
"""
from __future__ import annotations

import functools
import hashlib
import time
import warnings
import dataclasses
from dataclasses import dataclass, field, replace
from typing import Any, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import ig, methods as methods_mod, perturb
from repro.core.api import Explainer
from repro.core.baselines import pad_embedding
from repro.core.fingerprint import model_fingerprint
from repro.core.probes import probe_cost
from repro.core.schedule import Schedule, family, m_ladder
from repro.models.registry import model_for
from repro.roofline import cost_analysis_dict
from repro.serve.autotune import AutotuneCache, HotpathConfig, bucket_key
from repro.serve.result_cache import ResultCache
from repro.sharding import (
    DEFAULT_RULES,
    MeshRules,
    dp_size,
    explain_arg_shardings,
    mesh_cache_key,
)
from repro.serve.batching import (
    DEFAULT_BATCH_BUCKETS,
    DEFAULT_SEQ_BUCKETS,
    BucketBatch,
    pad_rows,
    plan_buckets,
)


@dataclass(frozen=True)
class ExplainRequest:
    tokens: np.ndarray  # (S,) int32 prompt — lengths may differ per request
    target: int  # token id whose next-token log-prob is attributed
    # feature-space request (patch models): (S, *F) float patch features from
    # ``models.vit.patchify``; ``tokens`` then only sets the length/bucket
    # (use e.g. arange(num_patches)) and ``target`` is the attributed class
    features: Optional[np.ndarray] = None
    # known endpoint value f(x) donated by the decode path (the probe-reuse
    # contract, docs/serving.md): the unified scheduler sets this to the
    # prefill forward's target log-prob, so the engine skips the α=1 probe
    # forward and the endpoint forward. Bit-identical to a self-computed
    # endpoint at float32 compute; dropped automatically for path-ensemble
    # methods (samples perturb x, so the donated value is for the wrong
    # point). None = the engine computes f(x) itself (the classic path).
    f_x: Optional[float] = None


@dataclass
class BucketStats:
    compiles: int = 0
    calls: int = 0
    requests: int = 0
    compile_s: float = 0.0
    total_s: float = 0.0  # wall time of cached calls (excludes compiles)
    # roofline-facing compile-time budgets (DESIGN.md §10): HBM traffic and
    # peak live bytes of the LAST executable compiled at this bucket shape,
    # from compiled.cost_analysis()/memory_analysis() — what the autotuner
    # ranks candidate configs by, surfaced per bucket so regressions are
    # observable in serving stats, not just in benchmarks
    bytes_accessed: float = 0.0
    peak_bytes: float = 0.0

    @property
    def mean_latency_s(self) -> float:
        return self.total_s / self.calls if self.calls else 0.0


@dataclass
class AdaptiveStats:
    """Aggregate δ-feedback serving counters (per-request values ride on the
    result dicts: ``m_used``, ``delta``, ``hops``, ``converged``). For
    path-ensemble methods every counter is per served ROW (sample), i.e.
    ``n_samples``× the user-visible request count."""

    requests: int = 0  # requests served adaptively
    converged: int = 0  # requests that reached δ ≤ tol·|f_x − f_b|
    early_exits: int = 0  # requests that converged below the ladder top
    hop_calls: int = 0  # escalation batches launched
    total_steps: int = 0  # Σ per-request m_used (iso-convergence metric)
    launched_steps: int = 0  # actual grad steps incl. batch-pad rows
    probe_forwards: int = 0  # stage-1 forwards (not gradient steps)
    m_used: dict = field(default_factory=dict)  # final rung -> request count

    @property
    def mean_m_used(self) -> float:
        return self.total_steps / self.requests if self.requests else 0.0


@dataclass
class EngineStats:
    hits: int = 0  # executable-cache hits
    misses: int = 0  # executable-cache misses == compilations
    buckets: dict = field(default_factory=dict)  # (B, S) -> BucketStats
    # hop executables get their own table: a hop at a plan-bucket shape does
    # different work per call (n_new new nodes, no probe/endpoints), so
    # folding it into `buckets` would corrupt per-bucket serving latency
    hop_buckets: dict = field(default_factory=dict)  # (B, S) -> BucketStats
    adaptive: AdaptiveStats = field(default_factory=AdaptiveStats)
    # buckets compiled WITHOUT shardings despite a multi-device mesh — the
    # mesh-divisible-padding contract (DESIGN.md §9) makes this unreachable
    # on the serving path; a nonzero count means padding was bypassed and
    # those buckets ran replicated (correct, but not scaled)
    mesh_fallbacks: int = 0
    # unified-scheduler counters (serve.scheduler): requests served a
    # fallback result after fault-policy exhaustion; decode work items run
    # ahead of queued explain hops (δ-aware preemption); and the scheduler
    # queue depth observed at the most recent dispatch
    degraded: int = 0
    preempted: int = 0
    queue_depth: int = 0
    # content-addressed RESULT cache (serve.result_cache) — a second cache
    # with its own counters: `hits`/`misses` above are the EXECUTABLE cache
    # (compile avoidance); these are whole-attribution replays (compute
    # avoidance). Mirrored from the ResultCache so one stats object reports
    # both in launch/explain and launch/serve
    result_hits: int = 0
    result_misses: int = 0
    result_evictions: int = 0
    result_bytes: int = 0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    @property
    def result_hit_rate(self) -> float:
        n = self.result_hits + self.result_misses
        return self.result_hits / n if n else 0.0

    def bucket(self, shape: tuple[int, int]) -> BucketStats:
        return self.buckets.setdefault(shape, BucketStats())

    def hop_bucket(self, shape: tuple[int, int]) -> BucketStats:
        return self.hop_buckets.setdefault(shape, BucketStats())

    @property
    def compiles(self) -> int:
        return sum(
            b.compiles for d in (self.buckets, self.hop_buckets) for b in d.values()
        )


class ExplainEngine:
    """Bucketed, cache-compiled NUIG serving over one model + param set.

    Args (the load-bearing subset — see the module docstring for the design):
        cfg / params: an ``ArchConfig`` and its parameter pytree.
        method / schedule: names in ``methods.METHODS`` / ``schedule.SCHEDULES``.
        m, n_int, chunk: the stage-2 budget, stage-1 intervals, scan chunk.
        seq_buckets / batch_buckets: the (S, B) padding ladders.
        mesh / mesh_rules: optional ``jax.sharding.Mesh`` — shards the folded
            (batch × step) stage-2 axis across the mesh's data axes
            (DESIGN.md §9).
        adaptive / tol / m_max: δ-feedback serving up the pow-2 m-ladder.
        fused: fused stage 2 (DESIGN.md §10); the default False is the
            materializing oracle path (the BENCH_hotpath reference).
        use_kernels / autotune / autotune_dir: Pallas kernel injection and
            the per-(bucket, device) tuned-config cache (§10).
        attn: "flash" serves the model with ``attn_impl="flash"`` — every
            executable differentiates through the Pallas flash-attention
            custom VJP (docs/attention.md); tuned attention block sizes from
            the autotune cache rebuild the model closure per bucket.

    Example (tiny CPU-reduced LM, one mixed-length round):

        >>> import numpy as np, jax
        >>> from repro.configs import ARCHS, reduced
        >>> from repro.models.registry import Model
        >>> cfg = reduced(ARCHS["llama3-8b"])
        >>> params = Model(cfg).init(jax.random.PRNGKey(0))
        >>> eng = ExplainEngine(cfg, params, m=4, n_int=2, seq_buckets=(8,))
        >>> reqs = [ExplainRequest(np.arange(1, 6, dtype=np.int32), target=7)]
        >>> out = eng.explain(reqs)
        >>> out[0]["token_scores"].shape, eng.stats.misses
        ((5,), 1)
        >>> _ = eng.explain(reqs)  # same bucket -> pure cache hit
        >>> eng.stats.misses, eng.stats.hits
        (1, 1)
    """

    def __init__(
        self,
        cfg: ArchConfig,
        params: Any,
        *,
        method: str = "ig",
        schedule: str = "paper",
        m: int = 64,
        n_int: int = 4,
        chunk: int = 0,
        refine_rounds: int = 4,
        power: float = 0.5,
        pad_id: int = 0,
        seq_buckets: Sequence[int] = DEFAULT_SEQ_BUCKETS,
        batch_buckets: Optional[Sequence[int]] = DEFAULT_BATCH_BUCKETS,
        max_batch: int = 0,
        mesh: Optional[jax.sharding.Mesh] = None,
        mesh_rules: MeshRules = DEFAULT_RULES,
        adaptive: bool = False,
        tol: float = 1e-2,
        m_max: int = 0,
        n_samples: int = 0,
        sigma: float = 0.0,
        n_masks: int = 0,
        sample_seed: int = 0,
        fused: bool = False,
        use_kernels: bool = False,
        attn: str = "auto",
        autotune: bool = False,
        autotune_dir: str = "results",
        result_cache: Union[None, int, ResultCache] = None,
        hop_zero: bool = False,
        hop_zero_q: float = 0.75,
        hop_zero_min: int = 8,
    ):
        # attention implementation of the SERVED model: "flash" rebuilds the
        # config with attn_impl="flash" so every executable differentiates
        # through the Pallas custom-VJP kernel instead of materializing the
        # (B·K, H, S, S) score tensor; "auto" leaves the config untouched.
        # Rides every cache key — flash and materializing programs coexist.
        assert attn in ("auto", "flash"), attn
        if attn == "flash" or getattr(cfg, "attn_impl", "auto") == "flash":
            self.attn = "flash"
            cfg = dataclasses.replace(cfg, attn_impl="flash")
        else:
            self.attn = "auto"
        self.cfg = cfg
        self.params = params
        self.method = method
        self.schedule = schedule
        self._spec = methods_mod.get(method)
        self.m = m
        self.n_int = n_int
        self.chunk = chunk
        self.pad_id = pad_id
        # fused stage 2 (DESIGN.md §10): bandwidth-optimal, opt-in — fused
        # and unfused agree to float tolerance but not bitwise, and under
        # bf16 the w-seeded backward rounds cotangents at a different scale
        # (≲0.5% relative), so flipping the serving default is gated on the
        # BENCH_hotpath trace/bytes/latency evidence, not assumed
        self.fused = fused
        self.use_kernels = use_kernels
        # per-(bucket, device) tuned (chunk, block_k, block_f) configs from
        # serve.autotune — loaded once at construction; a missing cache file
        # is an empty cache (every bucket falls back to the engine-wide
        # chunk and the default Pallas blocks)
        self._autotune_cache = (
            AutotuneCache.load(autotune_dir) if autotune else None
        )
        self.seq_buckets = tuple(seq_buckets)
        self.batch_buckets = tuple(batch_buckets) if batch_buckets else None
        self.max_batch = max_batch
        # forward-only perturbation class (DESIGN.md §8 / core.perturb): no
        # VJP exists, so δ carries no convergence meaning — the adaptive
        # m-ladder is a gradient-class contract and must be refused loudly
        if self._spec.forward_only and adaptive:
            raise ValueError(
                f"method {self._spec.name!r} is forward-only; the δ-adaptive "
                "m-ladder needs the gradient class (serve it fixed-budget)"
            )
        # mask budget P — the forward analogue of m (n_masks=0: spec default)
        self.n_masks = n_masks if n_masks else (self._spec.n_masks or 64)
        self.mesh = mesh
        self.mesh_rules = mesh_rules
        # data-parallel extent: every bucket batch is padded to a multiple of
        # this at plan time (mesh-divisible padding, DESIGN.md §9)
        self.dp = dp_size(mesh, mesh_rules)
        # cache keys carry the mesh axis sizes so single-device and sharded
        # executables coexist in one cache
        self._mesh_key = mesh_cache_key(mesh)
        self.adaptive = adaptive
        self.tol = tol
        self.m_max = m_max if m_max else (8 * m if adaptive else m)
        self.m_ladder = m_ladder(m, self.m_max)
        # path-ensemble serving: each request becomes n_samples plan rows
        self.n_samples = (
            (n_samples if n_samples else self._spec.n_samples)
            if self._spec.expand is not None
            else 1
        )
        self.sigma = sigma if sigma else self._spec.sigma_default
        self.sample_seed = sample_seed
        self.model = model_for(cfg)
        self.stats = EngineStats()
        self._cache: dict[tuple, Any] = {}  # key -> compiled executable
        # content-addressed attribution cache (serve.result_cache): an int
        # is a byte budget, a ResultCache instance is shared/injected, None
        # (default) disables — repeat requests then always recompute
        if isinstance(result_cache, ResultCache):
            self.result_cache: Optional[ResultCache] = result_cache
        elif result_cache:
            self.result_cache = (
                ResultCache()  # True -> the default byte budget
                if result_cache is True
                else ResultCache(max_bytes=int(result_cache))
            )
        else:
            self.result_cache = None
        # hop-zero starting rung (DESIGN.md §7 amortization): pick the
        # adaptive ladder's starting m from the per-(S-bucket, method)
        # m_used-history quantile — repeat-heavy traffic skips the rungs it
        # historically escalated through. History only accumulates from
        # base-rung runs (no ratcheting) and never-seen buckets keep the
        # base rung, so their traces are unchanged.
        self.hop_zero = hop_zero and adaptive
        self.hop_zero_q = hop_zero_q
        self.hop_zero_min = hop_zero_min
        self._delta_hist: dict[tuple[int, str], list[int]] = {}
        # per-rung Explainer variants for hop-zero starts (m0 != m)
        self._explainers_m: dict[int, Explainer] = {}
        # (fn, arg ShapeDtypeStructs, donate_argnums) per compiled key —
        # what warm-start persistence needs to jax.export the set
        self._export_info: dict[tuple, tuple] = {}
        self._model_fp: Optional[str] = None
        # model fns rebuilt at tuned attention block sizes (flash only):
        # (attn_block_q, attn_block_k) -> target_logprob_at_fn closure
        self._attn_fns: dict[tuple[int, int], Any] = {}
        # the compiled per-row unit: expansion stripped (row_spec) — the
        # engine samples the ensemble itself at batch-construction time
        self._explainer = Explainer(
            self.model.target_logprob_at_fn(params),
            method=self._spec.row_spec(),
            schedule=schedule,
            m=m,
            n_int=n_int,
            chunk=chunk,
            refine_rounds=refine_rounds,
            power=power,
            fused=fused,
            **self._kernel_kwargs(HotpathConfig(chunk)),
        )

    # -- compiled-executable cache ----------------------------------------

    def _kernel_kwargs(self, cfg: HotpathConfig) -> dict:
        """Pallas injection kwargs for one tuned config (``use_kernels``).

        Fused mode injects the custom-VJP interp-plus-carry op (its backward
        is the fused accumulation kernel, DESIGN.md §10) plus the class
        accumulator for quadratic methods; unfused mode injects the classic
        interpolate + accumulate pair. Forward-only methods have no gradient
        accumulator — their kernel injection is the lstsq solve hook inside
        ``_fwd_fn_at``."""
        if not self.use_kernels or self._spec.forward_only:
            return {}
        from repro.kernels.ig_accum.ops import accum_fn_for
        from repro.kernels.interp_accum.ops import interp_accum
        from repro.kernels.interpolate.ops import interpolate as interpolate_op

        blocks = {"block_k": cfg.block_k, "block_f": cfg.block_f}
        kw = {"accum_fn": functools.partial(accum_fn_for(self._spec.accum), **blocks)}
        if self.fused:
            kw["interp_add_fn"] = functools.partial(interp_accum, **blocks)
        else:
            kw["interp_fn"] = functools.partial(interpolate_op, **blocks)
        return kw

    def _cfg_for(self, bucket: tuple[int, int]) -> HotpathConfig:
        """The bucket's tuned (chunk, block_k, block_f), or the engine-wide
        defaults when no autotune entry exists (DESIGN.md §10)."""
        if self._autotune_cache is not None:
            tuned = self._autotune_cache.config_for(
                bucket_key(bucket, self._spec.accum, self.schedule, self.m,
                           self.n_int, self.fused, attn=self.attn)
            )
            if tuned is not None:
                return tuned
        return HotpathConfig(self.chunk)

    def _f_for(self, cfg: HotpathConfig):
        """The model function at one tuned config's attention block sizes.

        Flash models bake (attn_block_q, attn_block_k) into the differentiated
        function itself, so tuned attention blocks need a rebuilt closure —
        cached per block pair; (0, 0) and non-flash engines reuse the
        construction-time function.
        """
        blocks = (cfg.attn_block_q, cfg.attn_block_k)
        if self.attn != "flash" or blocks == (0, 0):
            return self._explainer.f
        if blocks not in self._attn_fns:
            mcfg = dataclasses.replace(
                self.cfg, attn_block_q=blocks[0], attn_block_k=blocks[1]
            )
            self._attn_fns[blocks] = model_for(mcfg).target_logprob_at_fn(
                self.params
            )
        return self._attn_fns[blocks]

    def _explainer_at(self, cfg: HotpathConfig) -> Explainer:
        return replace(
            self._explainer, f=self._f_for(cfg), chunk=cfg.chunk,
            **self._kernel_kwargs(cfg)
        )

    def _attr_fn_at(self, cfg: HotpathConfig, *, with_fx: bool = False):
        """Fixed-m bucket unit at one tuned config (also the autotuner's
        candidate-compile hook). ``with_fx`` compiles the probe-reuse variant
        whose trailing (B,) argument donates the known endpoint f(x) — a
        DIFFERENT program (one fewer probe forward, a B-row endpoint batch),
        so it gets its own cache-key flag."""
        exp = self._explainer_at(cfg)

        if with_fx:

            def attr_fx_fn(embeds, baseline, aux, mask, f_x):
                return exp.attribute(embeds, baseline, aux, mask=mask, f_x=f_x)

            return attr_fx_fn

        def attr_fn(embeds, baseline, aux, mask):
            return exp.attribute(embeds, baseline, aux, mask=mask)

        return attr_fn

    def _key(self, bucket: tuple[int, int], *, with_fx: bool = False) -> tuple:
        # keyed by accumulator CLASS, not method name: methods sharing an
        # accumulator share the warmed executables (DESIGN.md §8); the mesh
        # axis sizes ride every key so sharded and single-device entries
        # coexist (DESIGN.md §9); the resolved per-bucket HotpathConfig and
        # the fused/use_kernels program choices ride it too (§10), so tuned
        # and untuned entries never alias; ``with_fx`` separates probe-reuse
        # programs (docs/serving.md) from self-probing ones
        return (bucket, self._spec.accum, self.schedule, self.m, self.n_int,
                self._cfg_for(bucket), self.fused, self.use_kernels,
                self.attn, self._mesh_key, with_fx)

    # -- content-addressed identity (result cache + warm start) ------------

    @property
    def model_fingerprint(self) -> str:
        """sha256 of (config repr, params bytes) — computed once, lazily
        (hashing every param leaf is cheap on reduced models but real
        weights should pay it a single time)."""
        if self._model_fp is None:
            self._model_fp = model_fingerprint(self.cfg, self.params)
        return self._model_fp

    def _context_parts(self) -> list:
        """Everything engine-level that changes produced attribution BYTES.

        Keyed by METHOD NAME, not the accumulator class executables share:
        IDGI and IG attributions of one input are different artifacts. The
        bucket ladders are absent on purpose — the padding-invariance
        contract makes results independent of which bucket/batch a request
        lands in (tests/test_explain_engine.py exercises it)."""
        return [
            "ctx-v1", self.model_fingerprint, self.method, self.schedule,
            self.m, self.n_int, self.chunk, self.adaptive, self.tol,
            self.m_max, self.n_samples, self.sigma, self.sample_seed,
            self.n_masks, self.fused, self.use_kernels, self.attn,
            self._mesh_key, self.pad_id, self._autotune_cache is not None,
        ]

    def warm_context(self) -> str:
        """Identity a persisted warm state must match (serve.warm_state).

        Excludes the autotune ENTRIES fingerprint: the warm state carries
        the entries itself and installs them before any executable is
        consulted, so a restarted engine whose autotune file is gone can
        still restore."""
        return hashlib.sha256(repr(self._context_parts()).encode()).hexdigest()

    def request_cache_key(self, req: ExplainRequest) -> str:
        """sha256 content key for one request's attribution result.

        Engine context (including the loaded autotune entries — a tuned
        chunk changes scan boundaries and therefore bits) + the request's
        own bytes. The donated ``f_x`` rides the key conservatively — it is
        a program input — but is dropped exactly where ``explain()`` strips
        it (ensemble and forward-only methods), so donating and
        self-probing variants of those methods share entries."""
        parts = self._context_parts()
        if self._autotune_cache is not None:
            parts.append(self._autotune_cache.entries_fingerprint())
        h = hashlib.sha256(repr(parts).encode())
        tok = np.ascontiguousarray(np.asarray(req.tokens, np.int32))
        h.update(str(tok.shape).encode())
        h.update(tok.tobytes())
        h.update(str(int(req.target)).encode())
        if req.features is not None:
            f = np.ascontiguousarray(np.asarray(req.features, np.float32))
            h.update(b"feat")
            h.update(str(f.shape).encode())
            h.update(f.tobytes())
        f_x = req.f_x
        if self._spec.forward_only or self.n_samples > 1:
            f_x = None
        h.update(
            b"fx" + (np.float32(f_x).tobytes() if f_x is not None else b"none")
        )
        return h.hexdigest()

    def _sync_result_stats(self) -> None:
        """Mirror the ResultCache counters onto EngineStats (satellite 1)."""
        rc = self.result_cache
        if rc is not None:
            st = self.stats
            st.result_hits = rc.hits
            st.result_misses = rc.misses
            st.result_evictions = rc.evictions
            st.result_bytes = rc.bytes

    def _start_fn(self, embeds, baseline, aux, mask, f_x=None):
        """Adaptive rung 0: fused probe + base schedule + resumable stage 2.

        Returns the materialized per-example schedule too — the host needs it
        to refine on escalation (uniform's shared (m,) schedule is broadcast
        so survivor rows can be gathered independently). The optional
        trailing ``f_x`` is the probe-reuse variant (only ever compiled with
        it present or absent — the two signatures never alias, see
        ``_key``'s with_fx flag); the returned IGState carries the endpoints
        either way, so hop executables are IDENTICAL for both."""
        res, state, sched = self._explainer.start(
            embeds, baseline, aux, mask=mask, f_x=f_x
        )
        B = embeds.shape[0]
        sched = Schedule(
            jnp.broadcast_to(sched.alphas, (B, sched.alphas.shape[-1])),
            jnp.broadcast_to(sched.weights, (B, sched.weights.shape[-1])),
        )
        return res, state, sched

    def _hop_fn(self, embeds, baseline, aux, mask, new_nodes, state):
        """One ladder hop: stage 2 over the refined schedule's new nodes only
        (method-independent — the schedule arrives as runtime data)."""
        return self._explainer.resume(
            embeds, baseline, aux, new_nodes, state, mask=mask
        )

    # -- hop-zero starting rung (DESIGN.md §7 amortization) ----------------

    def _explainer_for_m(self, m0: int) -> Explainer:
        """The per-row Explainer at ladder rung ``m0`` (hop-zero starts).

        ``m0 == m`` is the construction-time instance; higher rungs get a
        cached variant. The engine chunk divides m, m0 is a pow-2 multiple
        of m, so the §7 one-chunk-per-ladder contract holds unchanged."""
        if m0 == self.m:
            return self._explainer
        if m0 not in self._explainers_m:
            self._explainers_m[m0] = replace(self._explainer, m=m0)
        return self._explainers_m[m0]

    def _start_fn_for(self, m0: int):
        """``_start_fn`` at an elevated starting rung (same contract)."""
        if m0 == self.m:
            return self._start_fn
        exp = self._explainer_for_m(m0)

        def start_fn(embeds, baseline, aux, mask, f_x=None):
            res, state, sched = exp.start(embeds, baseline, aux, mask=mask, f_x=f_x)
            B = embeds.shape[0]
            sched = Schedule(
                jnp.broadcast_to(sched.alphas, (B, sched.alphas.shape[-1])),
                jnp.broadcast_to(sched.weights, (B, sched.weights.shape[-1])),
            )
            return res, state, sched

        return start_fn

    def _hop_fn_for(self, m0: int):
        if m0 == self.m:
            return self._hop_fn
        exp = self._explainer_for_m(m0)

        def hop_fn(embeds, baseline, aux, mask, new_nodes, state):
            return exp.resume(embeds, baseline, aux, new_nodes, state, mask=mask)

        return hop_fn

    def _hop_zero_m(self, bucket: tuple[int, int]) -> int:
        """The adaptive ladder's starting rung for one bucket.

        With enough recorded base-rung history for (S-bucket, method), the
        smallest ladder rung covering the ``hop_zero_q`` quantile of final
        ``m_used`` — repeat-heavy traffic starts where it historically
        ended. Below ``hop_zero_min`` observations (and always for
        never-seen buckets) the base rung ``m`` is returned, so such
        traffic's m_used/δ traces are EXACTLY the non-hop-zero ones."""
        if not self.hop_zero:
            return self.m
        hist = self._delta_hist.get((bucket[1], self.method))
        if not hist or len(hist) < self.hop_zero_min:
            return self.m
        q = float(np.quantile(np.asarray(hist, np.float64), self.hop_zero_q))
        for rung in self.m_ladder:
            if rung >= q:
                return rung
        return self.m_ladder[-1]

    def _record_m_used(self, seq_bucket: int, values: Sequence[int]) -> None:
        """Accumulate base-rung-start ``m_used`` outcomes (the hop-zero
        evidence; capped so a long-lived engine's history stays bounded)."""
        hist = self._delta_hist.setdefault((seq_bucket, self.method), [])
        hist.extend(int(v) for v in values)
        if len(hist) > 512:
            del hist[:-512]

    def _executable(
        self, key: tuple, bs: BucketStats, fn, args: tuple, donate: tuple = ()
    ) -> Any:
        """AOT-compiled program (+ its input shardings) for one cache key.

        ``bs`` is the stats row (plan bucket or hop bucket) that the compile
        time is charged to. Under a mesh, input ``NamedSharding``s are
        resolved per argument tree (``explain_arg_shardings`` — hop args
        carry Schedule/IGState leaves beyond the 4-arg fixed-m tuple, all
        handled by the same leading-dim rule) and baked into the executable;
        mesh-divisible padding (DESIGN.md §9) guarantees they resolve, and a
        bucket that reaches here indivisible anyway compiles replicated and
        bumps ``EngineStats.mesh_fallbacks``. Returns ``(compiled,
        shardings)`` — callers feed the pair to ``_timed_call`` so inputs are
        placed onto the mesh before the call.

        ``donate`` (``donate_argnums``) marks args whose buffers the
        executable may overwrite — hop executables donate their ``IGState``
        so ladder escalation reuses the (B, *F) f32 accumulator in place
        instead of copying it each rung (DESIGN.md §10; every donated arg is
        constructed fresh per call, never read back after). Compile-time
        roofline budgets (bytes accessed, peak bytes) are recorded on ``bs``.
        """
        hit = key in self._cache
        if hit:
            self.stats.hits += 1
            return self._cache[key]
        self.stats.misses += 1
        bs.compiles += 1
        t0 = time.perf_counter()
        jit_kw = {}
        shardings = None
        if self.mesh is not None and self.dp > 1:
            shardings = explain_arg_shardings(self.mesh, args, self.mesh_rules)
            if shardings is not None:
                jit_kw["in_shardings"] = shardings
            else:
                self.stats.mesh_fallbacks += 1
                warnings.warn(
                    f"ExplainEngine: bucket batch {args[0].shape[0]} does not "
                    f"divide dp={self.dp}; serving replicated (key={key[:2]})",
                    stacklevel=2,
                )
        sds = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), args)
        with warnings.catch_warnings():
            # CPU cannot honor donation; the aliasing request is still
            # correct on every backend and must not spam serving logs
            warnings.filterwarnings(
                "ignore", message=".*donated buffers were not usable.*"
            )
            compiled = (
                jax.jit(fn, donate_argnums=donate, **jit_kw).lower(*sds).compile()
            )
        bs.compile_s += time.perf_counter() - t0
        bs.bytes_accessed = float(
            cost_analysis_dict(compiled).get("bytes accessed", 0.0)
        )
        try:
            ma = compiled.memory_analysis()
            bs.peak_bytes = float(
                ma.argument_size_in_bytes
                + ma.output_size_in_bytes
                + ma.temp_size_in_bytes
            )
        except Exception:  # noqa: BLE001 — backend-optional introspection
            pass
        self._cache[key] = (compiled, shardings)
        # what serve.warm_state needs to serialize this entry portably
        self._export_info[key] = (fn, sds, donate)
        return self._cache[key]

    def precompile_hop_zero_starts(self) -> int:
        """AOT-compile the start executables the δ-history now implies.

        History accumulates DURING a serving run, so the elevated starting
        rung ``_hop_zero_m`` would pick for a bucket may never have been
        compiled by that run (its own starts used the rung chosen when each
        batch arrived). ``save_warm_state`` calls this before serializing so
        a restored engine replays previously-seen buckets with zero compiles
        even where the restored history elevates the start. Shapes are free:
        the rung only changes program constants, so the elevated executable
        reuses the base start's recorded arg specs. Returns how many
        executables were added (not charged to serving stats — this is
        save-time work, not traffic)."""
        if not self.hop_zero:
            return 0
        n = 0
        for key in [k for k in self._cache if k[0] == "start"]:
            bucket, with_fx = key[1], key[-1]
            m0 = self._hop_zero_m(bucket)
            if m0 == key[4]:  # history picks this rung already
                continue
            info = self._export_info.get(key)
            if info is None or self._cache[key][1] is not None:
                continue  # sharded/unexportable — mesh engines recompile
            _, sds, donate = info
            new_key = (
                "start", bucket, self._spec.accum, self.schedule, m0,
                self.n_int, self._explainer_for_m(m0).adaptive_chunk,
                self.fused, self.use_kernels, self.attn, self._mesh_key,
                with_fx,
            )
            if new_key in self._cache:
                continue
            fn = self._start_fn_for(m0)
            compiled = jax.jit(fn, donate_argnums=donate).lower(*sds).compile()
            self._cache[new_key] = (compiled, None)
            self._export_info[new_key] = (fn, sds, donate)
            n += 1
        return n

    # -- serving -----------------------------------------------------------

    def _bucket_inputs(self, bb: BucketBatch) -> tuple:
        tokens = jnp.asarray(bb.tokens)
        aux = {
            "target": jnp.asarray(bb.targets, jnp.int32),
            "pos": jnp.asarray(bb.lens - 1, jnp.int32),
        }
        mask = jnp.asarray(bb.mask)
        if bb.features is not None:
            # feature-space requests (ViT patches): the IG path interpolates
            # embedded features toward the embedded BLACK image (an affine
            # patch projection maps the paper's pixel-space straight line to
            # exactly this embedding-space line; the bias+posemb offset is
            # shared, so it is off-path-direction and the baseline gradient
            # is non-degenerate — unlike a zero embedding)
            feats = jnp.asarray(bb.features)
            embeds = self.model.embed_features(self.params, feats)
            baseline = self.model.embed_features(
                self.params, jnp.zeros_like(feats)
            )
        else:
            embeds = self.model.embed_inputs(self.params, {"tokens": tokens})
            # PAD-token embedding, not zeros: RMSNorm backbones are scale-
            # invariant through their first norm, so a ray through the origin
            # has (near-)zero gradient a.e. and completeness can never
            # converge.
            baseline = pad_embedding(
                self.params["embed"]["embedding"], embeds, pad_id=self.pad_id
            )
        if self._spec.expand is not None:
            # path-ensemble perturbation in embedding space: rows are already
            # replicated requests (see explain()), so each row draws its own
            # iid sample here — OUTSIDE the compiled program, which is what
            # keeps ensemble methods on the shared riemann executables. Each
            # row's key is a pure function of ITS OWN (expanded) request
            # index, NOT a call counter and NOT the batch shape: replayed
            # traffic must draw the same ensemble so its escalation path —
            # and therefore the set of hop shapes it touches — replays
            # exactly (zero recompiles), and a mesh-padded bucket (B rounded
            # up to the dp multiple, DESIGN.md §9) must draw the same
            # per-row ensemble as the single-device bucket (sharded parity).
            # Batch-pad rows duplicate the last real request's index, so
            # their (discarded) noise duplicates too.
            base = jax.random.fold_in(
                jax.random.PRNGKey(self.sample_seed), bb.bucket[1]
            )
            padded = list(bb.indices)
            padded += [padded[-1]] * (bb.bucket[0] - len(padded))
            # one vmapped draw, not a per-row loop: same per-row streams
            # (each row's draw depends only on its own key), O(1) dispatches
            keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(
                jnp.asarray(padded, jnp.uint32)
            )
            e2, b2 = jax.vmap(
                lambda e, b, k: self._spec.expand(
                    e[None], b[None], k, 1, self.sigma
                )
            )(embeds, baseline, keys)
            embeds, baseline = e2[:, 0], b2[:, 0]
        if bb.f_x is not None:
            # probe-reuse bucket (docs/serving.md): the donated endpoint rides
            # as a trailing (B,) f32 argument. plan_buckets never mixes
            # known-fx and self-probing requests in one bucket, and explain()
            # strips f_x for ensemble methods before planning.
            return embeds, baseline, aux, mask, jnp.asarray(bb.f_x, jnp.float32)
        return embeds, baseline, aux, mask

    def _run_bucket(self, bb: BucketBatch) -> Any:
        args = self._bucket_inputs(bb)
        with_fx = bb.f_x is not None
        bs = self.stats.bucket(bb.bucket)
        ex = self._executable(
            self._key(bb.bucket, with_fx=with_fx), bs,
            self._attr_fn_at(self._cfg_for(bb.bucket), with_fx=with_fx), args,
        )
        res = self._timed_call(bs, ex, args)
        bs.requests += len(bb.indices)
        return res

    # -- forward-only (perturbation) class ---------------------------------

    def _fwd_chunk(self) -> int:
        """Masks per scan step — the engine chunk when it divides P, else
        the whole mask batch (P is pow-2-sized by convention, so any pow-2
        chunk divides it)."""
        return self.chunk if self.chunk and self.n_masks % self.chunk == 0 else 0

    def _fwd_fn_at(self, cfg: HotpathConfig):
        """The compiled forward-evaluator unit: embeds + masks -> scores.

        Masks arrive as RUNTIME data drawn at plan time (the expansion
        happens outside the compiled program, mirroring the path-ensemble
        contract), so one executable per (bucket, method, P) serves all
        replayed traffic. LIME's group map and ragged-group validity are
        pure in (bucket shape, mask) and recomputed inside the program —
        every argument stays batch-leading for the mesh sharding rule.
        ``use_kernels`` injects the Pallas WLS solve (``kernels/lstsq``)."""
        f = self._f_for(cfg)
        spec = self._spec
        chunk = self._fwd_chunk()
        solve = None
        if self.use_kernels:
            from repro.kernels.lstsq.ops import wls_solve

            solve = wls_solve
        if spec.accum == "lime":

            def fwd_lime(embeds, baseline, aux, mask, z, zg):
                G = zg.shape[-1]
                gids = perturb.lime_group_ids(embeds.shape[1], G)
                gvalid = perturb.group_real_mask(mask, gids, G)
                return perturb.attribute_from_masks(
                    f, embeds, baseline, aux,
                    perturb.PerturbMasks(z, zg, gids), method=spec, mask=mask,
                    group_valid=gvalid, chunk=chunk, solve_fn=solve,
                )

            return fwd_lime

        def fwd(embeds, baseline, aux, mask, z):
            return perturb.attribute_from_masks(
                f, embeds, baseline, aux, perturb.PerturbMasks(z),
                method=spec, mask=mask, chunk=chunk,
            )

        return fwd

    def _fwd_bucket_inputs(self, bb: BucketBatch) -> tuple:
        """Fixed-m inputs plus the plan-time mask draw.

        Every row's masks come from ``perturb.request_key`` — pure in its
        own request index, exactly the ensemble-expansion discipline: replay
        is bit-identical, batch-pad rows duplicate the last real row's
        masks, and a mesh-padded bucket draws the same per-row masks as the
        single-device one."""
        # callers strip f_x before planning (explain()/the scheduler flush);
        # slice defensively so a stray endpoint can't widen the arg tuple
        embeds, baseline, aux, mask = self._bucket_inputs(bb)[:4]
        S = bb.bucket[1]
        padded = list(bb.indices)
        padded += [padded[-1]] * (bb.bucket[0] - len(padded))
        keys = jax.vmap(
            lambda i: perturb.request_key(self.sample_seed, S, i)
        )(jnp.asarray(padded, jnp.uint32))
        pm = perturb.draw_masks(self._spec.name, keys, S, self.n_masks)
        if pm.groups is not None:
            return embeds, baseline, aux, mask, pm.z, pm.groups
        return embeds, baseline, aux, mask, pm.z

    def _run_bucket_fwd(self, bb: BucketBatch) -> Any:
        """One forward-evaluator bucket call -> ``perturb.PerturbResult``
        (attributions are per POSITION (B, S), already exactly zero at
        pads). Its own executable key class: no schedule, no n_int — the
        mask budget P and the scan chunk are the program shape."""
        args = self._fwd_bucket_inputs(bb)
        bs = self.stats.bucket(bb.bucket)
        key = ("fwd", bb.bucket, self._spec.accum, self.n_masks,
               self._fwd_chunk(), self.use_kernels, self.attn, self._mesh_key)
        ex = self._executable(
            key, bs, self._fwd_fn_at(self._cfg_for(bb.bucket)), args
        )
        res = self._timed_call(bs, ex, args)
        bs.requests += len(bb.indices)
        return res

    def _timed_call(self, bs: BucketStats, ex: tuple, args: tuple) -> Any:
        """Run one cached ``(compiled, shardings)`` entry; sharded inputs are
        placed onto the mesh first (host→device layout is part of the serving
        latency, so it stays inside the timer)."""
        compiled, shardings = ex
        t0 = time.perf_counter()
        if shardings is not None:
            args = jax.device_put(args, shardings)
        out = jax.block_until_ready(compiled(*args))
        bs.total_s += time.perf_counter() - t0
        bs.calls += 1
        return out

    def _run_bucket_adaptive(self, bb: BucketBatch) -> list[dict]:
        """δ-feedback serving for one bucket: rung 0, then escalate survivors.

        Returns one result dict per real request in ``bb.indices`` order.
        The ladder is an ``AdaptiveBucketRun`` driven to completion inline;
        the unified scheduler (``serve.scheduler``) drives the SAME object
        hop-by-hop instead, interleaving decode work between hops — both
        drivers hit identical executables and cache keys, so steady-state
        adaptive traffic never recompiles whichever path served it.
        """
        run = AdaptiveBucketRun(self, bb)
        run.start()
        while run.hop():
            pass
        return run.results()

    @staticmethod
    def _reduce_samples(group: list[dict]) -> dict:
        """Average one request's contiguous sample results (path-ensemble
        methods). δ is recomputed on the reduced quantities — the gap of the
        expectation, not the mean of per-sample gaps."""
        if len(group) == 1:
            return group[0]
        r = dict(group[0])
        mean = lambda k: np.mean([g[k] for g in group], axis=0)
        r["token_scores"] = mean("token_scores")
        if "raw_token_scores" in r:
            r["raw_token_scores"] = mean("raw_token_scores")
        r["f_x"] = float(mean("f_x"))
        r["f_baseline"] = float(mean("f_baseline"))
        r["delta"] = float(
            abs(float(np.sum(r["token_scores"])) - (r["f_x"] - r["f_baseline"]))
        )
        if "m_used" in r:  # adaptive: the request pays its worst sample
            r["m_used"] = max(g["m_used"] for g in group)
            r["hops"] = max(g["hops"] for g in group)
            r["threshold"] = float(mean("threshold"))
            r["converged"] = all(g["converged"] for g in group)
        return r

    def explain(
        self, requests: Sequence[ExplainRequest], *, return_raw: bool = False
    ) -> list[dict]:
        """Serve a heterogeneous batch; results align with ``requests``.

        With a ``result_cache``, each request's content key is consulted
        BEFORE ``plan_buckets``: hits replay the stored result dict
        bit-identically (a fresh copy — callers cannot corrupt the cache)
        and only misses are planned, bucketed, and computed. Degraded
        (fault-fallback) results are never cached. Everything below
        describes the compute path.
        """
        rc = self.result_cache
        if rc is None:
            return self._explain_uncached(requests, return_raw=return_raw)
        keys = [self.request_cache_key(r) for r in requests]
        results: list[Optional[dict]] = [rc.get(k) for k in keys]
        miss = [i for i, r in enumerate(results) if r is None]
        if miss:
            # always compute WITH raw rows so cached entries can serve both
            # return_raw variants; the caller-facing copy is trimmed below
            fresh = self._explain_uncached(
                [requests[i] for i in miss], return_raw=True
            )
            for i, r in zip(miss, fresh):
                if not r.get("degraded"):
                    rc.put(keys[i], r)
                results[i] = r
        self._sync_result_stats()
        if not return_raw:
            for r in results:
                r.pop("raw_token_scores", None)
        return results

    def _explain_uncached(
        self, requests: Sequence[ExplainRequest], *, return_raw: bool = False
    ) -> list[dict]:
        """The compute path (``explain`` without the result cache).

        Each result dict: token_scores (S_req,), delta, f_x, f_baseline,
        bucket (B, S); with ``return_raw`` also raw_token_scores (S_bucket,)
        — the untrimmed row, exactly zero at padded positions. In adaptive
        mode every dict additionally reports ``m_used`` (the rung the request
        exited at), ``hops``, ``threshold`` (tol·|f_x − f_baseline|) and
        ``converged``.

        Path-ensemble methods (noise_tunnel / expected_grad): each request is
        replicated ``n_samples``× at plan time, rows are perturbed in
        embedding space at batch construction, and each request's sample
        results are averaged back into one dict — so the per-request
        contract above is method-independent.
        """
        n = self.n_samples
        if n == 1:
            expanded = list(requests)
        else:
            # ensemble rows perturb the input in embedding space, so a
            # decode-donated endpoint value is for the WRONG point — strip it
            # before planning (requests fall back to self-probing buckets)
            expanded = [
                replace(r, f_x=None) if r.f_x is not None else r
                for r in requests
                for _ in range(n)
            ]
        if self._spec.forward_only:
            # forward-only buckets always compute both endpoints inside the
            # program (a donated f_x would fork the executable key class for
            # no gradient saved — there are no gradients), so strip it and
            # keep ONE compiled program per (bucket, method, P)
            expanded = [
                replace(r, f_x=None) if r.f_x is not None else r
                for r in expanded
            ]
        plan = plan_buckets(
            expanded,
            seq_buckets=self.seq_buckets,
            batch_buckets=self.batch_buckets,
            max_batch=self.max_batch,
            pad_id=self.pad_id,
            batch_multiple=self.dp,
        )
        out: list[Optional[dict]] = [None] * len(expanded)
        for bb in plan:
            if self.adaptive:
                for r in self._run_bucket_adaptive(bb):
                    ri = r.pop("request")
                    if not return_raw:
                        r.pop("raw_token_scores")
                    out[ri] = r
                continue
            if self._spec.forward_only:
                res = self._run_bucket_fwd(bb)
                # perturbation scores are already per POSITION (B, S) —
                # there is no feature axis to reduce
                per_token = np.asarray(res.attributions)
            else:
                res = self._run_bucket(bb)
                per_token = np.asarray(res.attributions.sum(-1))  # (B, S)
            for row, ri in enumerate(bb.indices):
                r = {
                    "token_scores": per_token[row, : bb.lens[row]],
                    "delta": float(res.delta[row]),
                    "f_x": float(res.f_x[row]),
                    "f_baseline": float(res.f_baseline[row]),
                    "bucket": bb.bucket,
                }
                if return_raw:
                    r["raw_token_scores"] = per_token[row]
                out[ri] = r
        if n == 1:
            return out
        return [
            self._reduce_samples(out[i * n : (i + 1) * n])
            for i in range(len(requests))
        ]


class AdaptiveBucketRun:
    """One bucket's δ-adaptive ladder as explicit, preemptible work items.

    The classic engine path (``ExplainEngine._run_bucket_adaptive``) drives
    this to completion inline; the unified scheduler (``serve.scheduler``)
    interleaves ``hop()`` calls with decode work instead — each hop is one
    compiled executable call over the still-unconverged survivors, so decode
    traffic preempts BETWEEN hops, never inside a compiled program. Hop
    executables and their cache keys are byte-identical on both drivers, so
    mixed and standalone traffic warm ONE shared executable set (the
    zero-steady-state-recompile invariant extends across the scheduler).

    Protocol:
      * ``start()`` — rung 0: probe + base schedule + resumable stage 2
        (honors a donated ``bb.f_x`` endpoint, see docs/serving.md);
      * while ``active``: ``hop()`` escalates the survivors one rung and
        returns whether work remains;
      * ``degrade()`` — abandon the remaining ladder: the current rung's
        results stand as the fallback (they are complete attributions, just
        less converged than tol demands); affected rows are marked
        ``degraded`` and counted on ``EngineStats.degraded``;
      * ``results()`` — finalize the adaptive stats (once) and return one
        dict per real request in ``bb.indices`` order.
    """

    def __init__(self, engine: ExplainEngine, bb: BucketBatch):
        self.eng = engine
        self.bb = bb
        self._started = False
        self._results: Optional[list[dict]] = None
        self._degraded: set[int] = set()
        self._rung_i = 1  # next ladder index to run (0 is start())
        self.act: list[int] = []

    @property
    def active(self) -> bool:
        """More ladder hops pending (unconverged survivors + rungs left)."""
        return bool(self.act) and self._rung_i < len(self.eng.m_ladder)

    def start(self) -> None:
        eng, bb = self.eng, self.bb
        assert not self._started
        self._started = True
        # hop-zero (engine._hop_zero_m): with enough per-(S, method) history
        # the ladder starts at the historical-quantile rung m0 >= m; cold
        # buckets keep the base rung, so their traces are unchanged. The
        # start key carries m0 and the rung's chunk — the m0 set is the
        # ladder, so the executable set stays closed.
        self.m0 = eng._hop_zero_m(bb.bucket)
        self._rung_i = eng.m_ladder.index(self.m0) + 1
        self.chunk = eng._explainer_for_m(self.m0).adaptive_chunk
        with_fx = bb.f_x is not None
        args = eng._bucket_inputs(bb)
        key = ("start", bb.bucket, eng._spec.accum, eng.schedule, self.m0,
               eng.n_int, self.chunk, eng.fused, eng.use_kernels, eng.attn,
               eng._mesh_key, with_fx)
        bs = eng.stats.bucket(bb.bucket)
        ex = eng._executable(key, bs, eng._start_fn_for(self.m0), args)
        res, state, sched = eng._timed_call(bs, ex, args)
        bs.requests += len(bb.indices)

        n_real = len(bb.indices)
        ast = eng.stats.adaptive
        ast.requests += n_real
        ast.total_steps += n_real * self.m0
        ast.launched_steps += bb.bucket[0] * self.m0
        # per-real-request like total_steps (pad-row forwards are launch
        # overhead, visible via launched_steps' bucket padding instead); a
        # donated endpoint saves the α=1 probe forward per row
        ast.probe_forwards += n_real * probe_cost(
            family(eng.schedule).probe,
            n_int=eng.n_int,
            rounds=eng._explainer.refine_rounds,
            known_fx=with_fx,
        )

        embeds, baseline, aux, mask = args[:4]
        self.embeds = np.asarray(embeds)
        self.baseline = np.asarray(baseline)
        self.aux = {k: np.asarray(v) for k, v in aux.items()}
        self.mask = np.asarray(mask)
        self.delta = np.asarray(res.delta).copy()
        self.f_x = np.asarray(res.f_x)
        self.f_b = np.asarray(res.f_baseline)
        self.threshold = eng.tol * np.abs(self.f_x - self.f_b)
        self.per_token = np.asarray(res.attributions.sum(-1)).copy()  # (B, S)
        self.m_used = np.full((bb.bucket[0],), self.m0, np.int64)
        self.hops = np.zeros((bb.bucket[0],), np.int64)

        # survivors: real rows whose δ still exceeds tol·|f_x − f_b|
        self.act = [r for r in range(n_real) if self.delta[r] > self.threshold[r]]
        self.a_act = np.asarray(sched.alphas)[self.act]
        self.w_act = np.asarray(sched.weights)[self.act]
        self.acc_act = np.asarray(state.acc)[self.act]

    def hop(self) -> bool:
        """Run ONE escalation rung over the survivors; returns ``active``.

        Escalation re-batches still-unconverged rows together (batch axis
        padded up the batch ladder by duplicating a survivor, as at plan
        time) and runs ONLY the refined schedule's new nodes through hop
        executables keyed ``("hop", (B', S), n_new, chunk)`` — a closed shape
        set, so steady-state adaptive traffic never recompiles.
        """
        if not self.active:
            return False
        eng, act = self.eng, self.act
        S = self.bb.bucket[1]
        rung = eng.m_ladder[self._rung_i]
        self._rung_i += 1
        n_new = rung // 2
        refined = family(eng.schedule).refine(
            Schedule(jnp.asarray(self.a_act), jnp.asarray(self.w_act))
        )
        ra, rw = np.asarray(refined.alphas), np.asarray(refined.weights)
        rows, B2 = pad_rows(act, eng.batch_buckets, multiple=eng.dp)
        # schedule/state slot per padded row: pad_rows keeps act as a
        # prefix and repeats the last real row into the pad slots
        pad_sel = list(range(len(act))) + [len(act) - 1] * (B2 - len(act))
        hop_bucket = (B2, S)
        hop_args = (
            self.embeds[rows],
            self.baseline[rows],
            {k: v[rows] for k, v in self.aux.items()},
            self.mask[rows],
            Schedule(ra[pad_sel, n_new:], rw[pad_sel, n_new:]),
            ig.IGState(self.acc_act[pad_sel], self.f_x[rows], self.f_b[rows]),
        )
        hop_key = ("hop", hop_bucket, eng._spec.accum, n_new, self.chunk,
                   eng.fused, eng.use_kernels, eng.attn, eng._mesh_key)
        hbs = eng.stats.hop_bucket(hop_bucket)
        # the IGState (arg 5) is donated: escalation reuses the (B, *F)
        # f32 accumulator buffer in place instead of copying each rung
        # (DESIGN.md §10); it is rebuilt fresh per hop and never read
        # back after the call, so donation is always safe here
        hop = eng._executable(
            hop_key, hbs, eng._hop_fn_for(self.m0), hop_args, donate=(5,)
        )
        res2, st2 = eng._timed_call(hbs, hop, hop_args)
        ast = eng.stats.adaptive
        ast.hop_calls += 1
        ast.launched_steps += B2 * n_new
        ast.total_steps += len(act) * n_new

        d2 = np.asarray(res2.delta)
        pt2 = np.asarray(res2.attributions.sum(-1))
        acc2 = np.asarray(st2.acc)
        keep = []
        for slot, r in enumerate(act):  # real survivors occupy slots [0, len(act))
            self.delta[r] = d2[slot]
            self.per_token[r] = pt2[slot]
            self.m_used[r] = rung
            self.hops[r] += 1
            if d2[slot] > self.threshold[r]:
                keep.append(slot)
        self.act = [act[s] for s in keep]
        self.a_act, self.w_act = ra[keep], rw[keep]
        self.acc_act = acc2[keep]
        return self.active

    def degrade(self) -> int:
        """Abandon the remaining ladder; current-rung results become the
        fallback. Returns how many real rows were degraded (each counted on
        ``EngineStats.degraded``). Idempotent once drained."""
        n = len(self.act)
        if n:
            self._degraded.update(self.act)
            self.eng.stats.degraded += n
            self.act = []
        return n

    def results(self) -> list[dict]:
        """One result dict per real request (``bb.indices`` order); finalizes
        the aggregate adaptive counters exactly once."""
        if self._results is not None:
            return self._results
        eng, bb = self.eng, self.bb
        ast = eng.stats.adaptive
        out = []
        for row, ri in enumerate(bb.indices):
            converged = bool(self.delta[row] <= self.threshold[row])
            ast.converged += converged
            ast.early_exits += converged and int(self.m_used[row]) < eng.m_ladder[-1]
            mu = int(self.m_used[row])
            ast.m_used[mu] = ast.m_used.get(mu, 0) + 1
            out.append(
                {
                    "request": ri,
                    "token_scores": self.per_token[row, : bb.lens[row]],
                    "raw_token_scores": self.per_token[row],
                    "delta": float(self.delta[row]),
                    "threshold": float(self.threshold[row]),
                    "f_x": float(self.f_x[row]),
                    "f_baseline": float(self.f_b[row]),
                    "bucket": bb.bucket,
                    "m_used": mu,
                    "hops": int(self.hops[row]),
                    "converged": converged,
                    "degraded": row in self._degraded,
                }
            )
        # hop-zero evidence: ONLY base-rung starts contribute (an elevated
        # start's m_used is floored at m0 — feeding it back would ratchet
        # the quantile upward forever); degraded rows never converged by
        # fiat, not by δ, so they are no evidence either
        if self.m0 == eng.m:
            eng._record_m_used(
                bb.bucket[1],
                [r["m_used"] for r in out if not r["degraded"]],
            )
        self._results = out
        return out
