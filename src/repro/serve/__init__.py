from repro.serve.engine import ServeEngine, make_serve_step, make_prefill_step
from repro.serve.explain_engine import EngineStats, ExplainEngine, ExplainRequest
from repro.serve.explain_service import ExplainService
from repro.serve.batching import BucketBatch, bucket_for, plan_buckets, pow2_ladder

__all__ = [
    "ServeEngine",
    "make_serve_step",
    "make_prefill_step",
    "ExplainEngine",
    "EngineStats",
    "ExplainService",
    "ExplainRequest",
    "BucketBatch",
    "bucket_for",
    "plan_buckets",
    "pow2_ladder",
]
