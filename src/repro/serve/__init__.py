from repro.serve.engine import (
    ServeEngine,
    make_decode_chunk,
    make_decode_loop,
    make_prefill_step,
    make_serve_step,
    sample_token,
)
from repro.serve.explain_engine import (
    AdaptiveBucketRun,
    EngineStats,
    ExplainEngine,
    ExplainRequest,
)
from repro.serve.explain_service import ExplainService
from repro.serve.batching import BucketBatch, bucket_for, plan_buckets, pow2_ladder
from repro.serve.scheduler import (
    BATCH,
    EXPLAIN,
    INTERACTIVE,
    GenerateRequest,
    MixedScheduler,
    SLOClass,
    TenantPolicy,
    Ticket,
)
from repro.serve.autotune import (
    AutotuneCache,
    HotpathConfig,
    autotune_engine,
    bucket_key,
    chunk_candidates,
)
from repro.serve.result_cache import ResultCache
from repro.serve.warm_state import (
    WarmRestoreReport,
    load_warm_state,
    save_warm_state,
)

__all__ = [
    "ServeEngine",
    "make_serve_step",
    "make_prefill_step",
    "make_decode_loop",
    "make_decode_chunk",
    "sample_token",
    "ExplainEngine",
    "EngineStats",
    "ExplainService",
    "ExplainRequest",
    "AdaptiveBucketRun",
    "BucketBatch",
    "bucket_for",
    "plan_buckets",
    "pow2_ladder",
    "MixedScheduler",
    "GenerateRequest",
    "Ticket",
    "SLOClass",
    "TenantPolicy",
    "INTERACTIVE",
    "BATCH",
    "EXPLAIN",
    "AutotuneCache",
    "HotpathConfig",
    "autotune_engine",
    "bucket_key",
    "chunk_candidates",
    "ResultCache",
    "WarmRestoreReport",
    "load_warm_state",
    "save_warm_state",
]
