from repro.serve.engine import ServeEngine, make_serve_step, make_prefill_step
from repro.serve.explain_engine import EngineStats, ExplainEngine, ExplainRequest
from repro.serve.explain_service import ExplainService
from repro.serve.batching import BucketBatch, bucket_for, plan_buckets, pow2_ladder
from repro.serve.autotune import (
    AutotuneCache,
    HotpathConfig,
    autotune_engine,
    bucket_key,
    chunk_candidates,
)

__all__ = [
    "ServeEngine",
    "make_serve_step",
    "make_prefill_step",
    "ExplainEngine",
    "EngineStats",
    "ExplainService",
    "ExplainRequest",
    "BucketBatch",
    "bucket_for",
    "plan_buckets",
    "pow2_ladder",
    "AutotuneCache",
    "HotpathConfig",
    "autotune_engine",
    "bucket_key",
    "chunk_candidates",
]
