from repro.serve.engine import ServeEngine, make_serve_step, make_prefill_step
from repro.serve.explain_service import ExplainService, ExplainRequest

__all__ = [
    "ServeEngine",
    "make_serve_step",
    "make_prefill_step",
    "ExplainService",
    "ExplainRequest",
]
