"""Roofline-driven per-bucket autotuner for the stage-2 hot path (§10).

The serving engine's stage-2 executables have three latency knobs that the
compiler cannot pick for us: the scan ``chunk`` (how many interpolation steps
fold into the batch axis per grad call — small chunks bound memory, large
chunks amortize dispatch) and the Pallas ``block_k``/``block_f`` tile sizes
(VMEM residency of the fused interp/accum kernels). The right values depend
on the bucket shape AND the device, so they are tuned per
``(bucket, device_kind)`` and persisted:

  1. every candidate is AOT-compiled and priced from
     ``compiled.cost_analysis()`` — bytes-accessed over HBM bandwidth and
     FLOPs over peak give the roofline bound (``repro.roofline.
     hotpath_terms``); candidates that the roofline already rules out are
     never measured;
  2. the surviving few run a short measured sweep (warmed wall-clock,
     median of ``rounds``); the winner is the measured-fastest;
  3. winners land in ``results/autotune_<device>.json`` keyed by
     ``bucket_key`` (bucket shape + accumulator class + schedule + m +
     n_int + fused), which ``ExplainEngine(autotune=True)`` loads at
     construction — steady-state serving then runs every bucket at its
     tuned config with zero extra compiles (the tuned chunk is part of the
     executable cache key, exactly like the untuned one).

The adaptive m-ladder is NOT tuned per bucket: escalation re-batches
survivors across bucket shapes mid-flight, and the §7 resume contract
requires one chunk along the whole ladder — a per-bucket chunk would change
the scan boundaries between rungs. Adaptive serving keeps the engine-wide
``chunk``; the tuned configs apply to the fixed-m path.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

import jax
import numpy as np

from repro.roofline import cost_analysis_dict, hardware_for, hotpath_terms

DEFAULT_BLOCK_K = 8
DEFAULT_BLOCK_F = 512


@dataclass(frozen=True)
class HotpathConfig:
    """One tuned stage-2 configuration for a bucket.

    ``attn_block_q``/``attn_block_k`` are the flash-attention kernel tilings
    (0 = the model config's defaults); they only matter for engines serving
    an ``attn_impl == "flash"`` model, where the attention blocks are baked
    into the differentiated model function itself.
    """

    chunk: int
    block_k: int = DEFAULT_BLOCK_K
    block_f: int = DEFAULT_BLOCK_F
    attn_block_q: int = 0
    attn_block_k: int = 0


def device_kind() -> str:
    """Sanitized ``jax.Device.device_kind`` of device 0 (cache-file suffix)."""
    kind = jax.devices()[0].device_kind
    return re.sub(r"[^a-z0-9]+", "_", kind.lower()).strip("_")


def cache_path(results_dir: str = "results", device: Optional[str] = None) -> str:
    """``results/autotune_<device>.json`` — one cache file per device kind."""
    return os.path.join(results_dir, f"autotune_{device or device_kind()}.json")


def bucket_key(
    bucket: tuple[int, int],
    accum: str,
    schedule: str,
    m: int,
    n_int: int,
    fused: bool,
    attn: str = "auto",
) -> str:
    """Cache key for one bucket's tuned config (DESIGN.md §10).

    Keyed by everything that changes the compiled stage-2 program EXCEPT the
    knobs being tuned: the bucket shape, the accumulator CLASS (methods
    sharing an accumulator share executables, §8), the schedule family, the
    (m, n_int) budget, whether stage 2 is fused, and the model's attention
    implementation (``"+flash"`` suffix — a flash model compiles a different
    program than the materializing one, so their tuned configs never alias).
    The device rides the cache FILENAME (``cache_path``), not the key.
    """
    tag = "fused" if fused else "unfused"
    if attn != "auto":
        tag += f"+{attn}"
    return f"B{bucket[0]}xS{bucket[1]}/{accum}/{schedule}/m{m}/n{n_int}/{tag}"


@dataclass
class AutotuneCache:
    """On-disk ``bucket_key -> tuned config + measurements`` map."""

    device: str = ""
    entries: dict = field(default_factory=dict)

    @classmethod
    def load(cls, results_dir: str = "results", device: Optional[str] = None):
        """Load the device's cache; a missing file is an empty cache.

        NEVER raises on a bad file: a corrupted/truncated JSON payload, a
        non-dict payload, or a payload recorded for a DIFFERENT device kind
        (someone copied a results dir between machines — its tuned chunks
        would silently mis-tune this device) all warn and return an empty
        cache. A broken autotune file may cost re-tuning, never serving.
        """
        device = device or device_kind()
        path = cache_path(results_dir, device)
        if not os.path.exists(path):
            return cls(device=device)
        try:
            with open(path) as fh:
                payload = json.load(fh)
            if not isinstance(payload, dict) or not isinstance(
                payload.get("entries", {}), dict
            ):
                raise ValueError(f"malformed payload {type(payload).__name__}")
        except (json.JSONDecodeError, ValueError, OSError) as e:
            warnings.warn(
                f"AutotuneCache: unreadable cache at {path} ({e}); "
                "starting with an empty cache",
                stacklevel=2,
            )
            return cls(device=device)
        recorded = payload.get("device", device)
        if recorded != device:
            warnings.warn(
                f"AutotuneCache: {path} was tuned for device {recorded!r}, "
                f"not {device!r}; ignoring its entries",
                stacklevel=2,
            )
            return cls(device=device)
        return cls(device=device, entries=payload.get("entries", {}))

    def entries_fingerprint(self) -> str:
        """sha256 of the loaded entries — rides the result-cache key (a
        tuned chunk changes scan boundaries and therefore attribution bits;
        ``ExplainEngine.request_cache_key``)."""
        blob = json.dumps(self.entries, sort_keys=True, default=str)
        return hashlib.sha256(blob.encode()).hexdigest()

    def save(self, results_dir: str = "results") -> str:
        os.makedirs(results_dir, exist_ok=True)
        path = cache_path(results_dir, self.device)
        with open(path, "w") as fh:
            json.dump({"device": self.device, "entries": self.entries}, fh, indent=1)
        return path

    def config_for(self, key: str) -> Optional[HotpathConfig]:
        e = self.entries.get(key)
        if e is None:
            return None
        return HotpathConfig(
            chunk=int(e["chunk"]),
            block_k=int(e.get("block_k", DEFAULT_BLOCK_K)),
            block_f=int(e.get("block_f", DEFAULT_BLOCK_F)),
            attn_block_q=int(e.get("attn_block_q", 0)),
            attn_block_k=int(e.get("attn_block_k", 0)),
        )

    def put(self, key: str, cfg: HotpathConfig, metrics: dict) -> None:
        self.entries[key] = {
            "chunk": cfg.chunk, "block_k": cfg.block_k, "block_f": cfg.block_f,
            "attn_block_q": cfg.attn_block_q, "attn_block_k": cfg.attn_block_k,
            **metrics,
        }


def chunk_candidates(m: int) -> list[int]:
    """Power-of-two divisors of ``m`` (ascending, ``m`` itself last).

        >>> chunk_candidates(8)
        [1, 2, 4, 8]
        >>> chunk_candidates(12)
        [1, 2, 4, 12]
    """
    out = [c for c in (2**i for i in range(m.bit_length())) if m % c == 0]
    if m not in out:
        out.append(m)
    return out


def _median_latency(call, args, rounds: int) -> float:
    call(args)  # warm (compile already done AOT; first call pays transfers)
    ts = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        jax.block_until_ready(call(args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def autotune_engine(
    engine,
    requests: Sequence,
    *,
    rounds: int = 3,
    max_measured: int = 3,
    block_k_grid: Sequence[int] = (DEFAULT_BLOCK_K,),
    block_f_grid: Sequence[int] = (DEFAULT_BLOCK_F,),
    attn_block_grid: Sequence[tuple[int, int]] = ((0, 0),),
    results_dir: str = "results",
    save: bool = True,
) -> dict:
    """Tune (chunk, block_k, block_f[, attn blocks]) per touched bucket.

    ``engine`` is an ``ExplainEngine``; ``requests`` is sample traffic whose
    plan buckets define what gets tuned (tune with the traffic you serve).
    Candidate configs are compiled standalone — the engine's executable
    cache and stats are untouched — priced by their roofline bound
    (``hotpath_terms`` under ``hardware_for(device_kind)``), and only the
    ``max_measured`` roofline-best run the measured sweep. Block grids
    beyond the defaults only matter when the engine injects Pallas kernels
    (``use_kernels=True``); the default single-point grids keep the sweep
    to a chunk scan. ``attn_block_grid`` sweeps (attn_block_q, attn_block_k)
    flash-attention tilings and only applies to flash engines ((0, 0) = the
    model config's blocks); it is ignored — one (0, 0) point — otherwise.

    Returns a report dict (per-bucket candidates + winners); with ``save``
    the winners are persisted to ``results/autotune_<device>.json`` for
    ``ExplainEngine(autotune=True)`` to load.
    """
    from repro.serve.batching import plan_buckets  # local: avoid import cycle

    hw = hardware_for(jax.devices()[0].device_kind)
    cache = AutotuneCache.load(results_dir)
    # mirror ExplainEngine.explain's plan exactly — path-ensemble methods
    # replicate requests n_samples× BEFORE bucketing, so the tuned bucket
    # shapes must come from the expanded traffic or the keys never match
    n = engine.n_samples
    expanded = (
        list(requests) if n == 1 else [r for r in requests for _ in range(n)]
    )
    plan = plan_buckets(
        expanded,
        seq_buckets=engine.seq_buckets,
        batch_buckets=engine.batch_buckets,
        max_batch=engine.max_batch,
        pad_id=engine.pad_id,
        batch_multiple=engine.dp,
    )
    attn_grid = (
        tuple(attn_block_grid)
        if getattr(engine, "attn", "auto") == "flash"
        else ((0, 0),)
    )
    report = {"device": cache.device, "hw": hw.name, "buckets": {}}
    seen: set[tuple[int, int]] = set()
    for bb in plan:
        if bb.bucket in seen:
            continue
        seen.add(bb.bucket)
        args = engine._bucket_inputs(bb)
        sds = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), args)
        cands = []
        for chunk in chunk_candidates(engine.m):
            for bk in block_k_grid:
                for bf in block_f_grid:
                    for abq, abk in attn_grid:
                        cfg = HotpathConfig(chunk, bk, bf, abq, abk)
                        fn = engine._attr_fn_at(cfg)
                        compiled = jax.jit(fn).lower(*sds).compile()
                        terms = hotpath_terms(cost_analysis_dict(compiled), hw)
                        cands.append({"cfg": cfg, "compiled": compiled, **terms})
        # roofline prune: only the predicted-fastest few get measured
        cands.sort(key=lambda c: c["bound_s"])
        for c in cands[:max_measured]:
            c["latency_s"] = _median_latency(
                lambda a, ex=c["compiled"]: ex(*a), args, rounds
            )
        best = min(cands[:max_measured], key=lambda c: c["latency_s"])
        key = bucket_key(
            bb.bucket, engine._spec.accum, engine.schedule, engine.m,
            engine.n_int, engine.fused, attn=getattr(engine, "attn", "auto"),
        )
        cache.put(
            key,
            best["cfg"],
            {
                "bytes_accessed": best["bytes_accessed"],
                "latency_s": best["latency_s"],
                "bound_s": best["bound_s"],
                "dominant": best["dominant"],
            },
        )
        report["buckets"][key] = {
            "winner": vars(best["cfg"]) | {"latency_s": best["latency_s"]},
            "candidates": [
                {
                    **vars(c["cfg"]),
                    "bytes_accessed": c["bytes_accessed"],
                    "bound_s": c["bound_s"],
                    "latency_s": c.get("latency_s"),
                }
                for c in cands
            ],
        }
    if save:
        report["path"] = cache.save(results_dir)
    return report
