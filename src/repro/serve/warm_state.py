"""Warm-start persistence: a restarted engine explains with zero compiles.

``ExplainEngine`` reaches steady state by AOT-compiling one executable per
(bucket, method-class, schedule, m, ...) key — seconds each. On restart that
whole set is gone. This module persists it (ISSUE 10), alongside the
autotune entries and the adaptive hop-zero δ-history, with the checkpoint
manager's atomicity discipline (``checkpoint.manager.atomic_dir``: tmp-dir
write, per-file sha256 manifest, one ``os.replace``).

Two serialized forms per executable, tried in order at restore:

  * **native** (``jax.experimental.serialize_executable``): the compiled
    XLA executable itself — a true zero-compile restore (measured ~200×
    faster cold-start-to-first-explanation on the reduced LM). Pickle-level
    and device-level fragile, so it is only trusted when the manifest's
    recorded jax version AND device kind match the current process exactly;
  * **portable** (``jax.export`` StableHLO): versioned and
    device-independent, but XLA re-compiles the deserialized module at load
    (~1.4× — it saves tracing/lowering only). The fallback when the native
    payload is stale or refuses to load.

Any mismatch — corrupted file (sha256), different model fingerprint or
engine knobs (``ExplainEngine.warm_context``), unreadable pickle — warns
and falls back COLD: a warm state can make a restart slow again, never
wrong. Mesh-sharded executables are skipped at save (their shardings bind
process topology); mesh engines re-compile as before.

    eng = ExplainEngine(cfg, params, ...)
    eng.explain(traffic)                     # warm the executable set
    save_warm_state(eng, "results/warm")     # atomic, content-hashed
    ...process restarts...
    eng2 = ExplainEngine(cfg, params, ...)   # same model + knobs
    report = load_warm_state(eng2, "results/warm")
    eng2.explain(traffic)                    # zero compiles (report.via)
"""
from __future__ import annotations

import json
import os
import pickle
import warnings
from dataclasses import dataclass
from typing import Any

import jax
import numpy as np
from jax import export as jexport
from jax.experimental import serialize_executable as _se

from repro.checkpoint.manager import atomic_dir, sha256_file
from repro.core import ig, perturb
from repro.core.schedule import Schedule
from repro.serve.autotune import device_kind

_MANIFEST = "manifest.json"
_NATIVE = "executables.pkl"
_PORTABLE = "exports.pkl"
_STATE = "state.json"
_FORMAT = 1


def _register_trees() -> None:
    """jax.export refuses unregistered NamedTuples in arg/result trees; the
    engine's programs carry these four. Registration is process-global and
    idempotent only by name — tolerate re-import."""
    for nt in (ig.IGResult, ig.IGState, Schedule, perturb.PerturbResult):
        try:
            jexport.register_namedtuple_serialization(
                nt, serialized_name=f"repro.{nt.__name__}"
            )
        except ValueError:
            pass  # already registered under this name


_register_trees()


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _pack_sds(sds: Any) -> tuple:
    """A pickle-stable form of a ShapeDtypeStruct tree: leaf (shape, dtype
    name) pairs + the treedef (PyTreeDefs pickle; ShapeDtypeStructs are not
    guaranteed to across jax versions)."""
    leaves, treedef = jax.tree.flatten(sds)
    return [(tuple(s.shape), s.dtype.name) for s in leaves], treedef


def _unpack_sds(packed: tuple) -> Any:
    specs, treedef = packed
    return jax.tree.unflatten(
        treedef, [jax.ShapeDtypeStruct(s, _np_dtype(d)) for s, d in specs]
    )


@dataclass
class WarmRestoreReport:
    """What ``load_warm_state`` did: ``restored`` with ``executables``
    entries via ``"native"`` or ``"export"``, or cold with a ``reason``."""

    restored: bool
    via: str = ""
    executables: int = 0
    reason: str = ""


def _cold(reason: str) -> WarmRestoreReport:
    warnings.warn(
        f"warm_state: {reason}; starting cold (correctness is unaffected)",
        stacklevel=3,
    )
    return WarmRestoreReport(restored=False, reason=reason)


def save_warm_state(engine: Any, directory: str) -> str:
    """Persist the engine's executable set + autotune entries + δ-history.

    Written with ``atomic_dir``: a crash mid-save leaves any previous warm
    state intact. Returns the directory path. Sharded executables and any
    entry ``jax.export`` cannot serialize are skipped with a warning — the
    restored engine simply compiles those keys again.
    """
    # the δ-history may imply elevated starting rungs the run itself never
    # compiled (history accumulates as it serves) — close the set first
    if getattr(engine, "hop_zero", False):
        engine.precompile_hop_zero_starts()
    # blobs stashed by a prior load_warm_state: a RESTORED executable has no
    # export info (its builder fn never ran this process) and a deserialized
    # executable cannot be re-serialized (the payload loses linked symbols),
    # so restore→save carries the original blobs forward instead of dropping
    # the entry — the cycle must never shrink the warm state
    carried = getattr(engine, "_warm_saved", {"native": {}, "portable": {}})
    native: list[dict] = []
    portable: list[dict] = []
    skipped = 0
    for key, (compiled, shardings) in engine._cache.items():
        if shardings is not None:
            skipped += 1
            continue
        info = engine._export_info.get(key)
        if info is None:
            kept = False
            if key in carried["native"]:
                native.append(carried["native"][key])
                kept = True
            if key in carried["portable"]:
                portable.append(carried["portable"][key])
                kept = True
            if not kept:
                skipped += 1
            continue
        fn, sds, donate = info
        payload, in_tree, out_tree = _se.serialize(compiled)
        native.append(
            {"key": key, "payload": payload, "in_tree": in_tree,
             "out_tree": out_tree}
        )
        try:
            exp = jexport.export(jax.jit(fn, donate_argnums=donate))(*sds)
            portable.append(
                {"key": key, "blob": exp.serialize(), "sds": _pack_sds(sds)}
            )
        except Exception as e:  # noqa: BLE001 — portable form is best-effort
            warnings.warn(
                f"warm_state: jax.export could not serialize {key[:2]}: {e}; "
                "the native payload still covers this entry",
                stacklevel=2,
            )
    if skipped:
        warnings.warn(
            f"warm_state: skipped {skipped} sharded/unexportable executables "
            "(mesh engines recompile on restart)",
            stacklevel=2,
        )
    state = {
        "autotune_device": (
            engine._autotune_cache.device if engine._autotune_cache else ""
        ),
        "autotune_entries": (
            engine._autotune_cache.entries if engine._autotune_cache else {}
        ),
        "delta_hist": {
            f"{s}:{meth}": list(map(int, hist))
            for (s, meth), hist in engine._delta_hist.items()
        },
    }
    with atomic_dir(directory) as tmp:
        with open(os.path.join(tmp, _NATIVE), "wb") as fh:
            pickle.dump(native, fh)
        with open(os.path.join(tmp, _PORTABLE), "wb") as fh:
            pickle.dump(portable, fh)
        with open(os.path.join(tmp, _STATE), "w") as fh:
            json.dump(state, fh)
        manifest = {
            "format": _FORMAT,
            "jax_version": jax.__version__,
            "device_kind": device_kind(),
            "context": engine.warm_context(),
            "n_executables": len(
                {b["key"] for b in native} | {b["key"] for b in portable}
            ),
            "files": {
                name: sha256_file(os.path.join(tmp, name))
                for name in (_NATIVE, _PORTABLE, _STATE)
            },
        }
        with open(os.path.join(tmp, _MANIFEST), "w") as fh:
            json.dump(manifest, fh, indent=1)
    return directory


def load_warm_state(engine: Any, directory: str) -> WarmRestoreReport:
    """Validate + restore a persisted warm state into ``engine``.

    Restore order matters: autotune entries land first (executable keys
    carry the resolved per-bucket ``HotpathConfig``, so the engine must
    resolve the same configs the save-time engine did), then the δ-history,
    then the executables — native form when the manifest's jax version and
    device kind match this process, else the portable ``jax.export`` form.
    EVERY validation failure falls back cold with a warning; a partial
    native restore is rolled back before trying the portable form.
    """
    mpath = os.path.join(directory, _MANIFEST)
    if not os.path.isfile(mpath):
        return WarmRestoreReport(restored=False, reason="no warm state")
    try:
        with open(mpath) as fh:
            manifest = json.load(fh)
    except (json.JSONDecodeError, OSError) as e:
        return _cold(f"unreadable manifest ({e})")
    if manifest.get("format") != _FORMAT:
        return _cold(f"unknown format {manifest.get('format')!r}")
    for name, digest in manifest.get("files", {}).items():
        path = os.path.join(directory, name)
        if not os.path.isfile(path) or sha256_file(path) != digest:
            return _cold(f"corrupted or missing shard {name!r}")
    if manifest.get("context") != engine.warm_context():
        return _cold("engine context mismatch (different model or knobs)")

    try:
        with open(os.path.join(directory, _STATE)) as fh:
            state = json.load(fh)
    except (json.JSONDecodeError, OSError) as e:
        return _cold(f"unreadable state ({e})")
    if engine._autotune_cache is not None and state.get("autotune_entries"):
        engine._autotune_cache.entries = dict(state["autotune_entries"])
    hist = {}
    for skey, values in state.get("delta_hist", {}).items():
        s, meth = skey.split(":", 1)
        hist[(int(s), meth)] = [int(v) for v in values]
    engine._delta_hist.update(hist)

    native_ok = (
        manifest.get("jax_version") == jax.__version__
        and manifest.get("device_kind") == device_kind()
    )
    if native_ok:
        restored: dict = {}
        try:
            with open(os.path.join(directory, _NATIVE), "rb") as fh:
                blobs = pickle.load(fh)
            for b in blobs:
                restored[b["key"]] = (
                    _se.deserialize_and_load(
                        b["payload"], b["in_tree"], b["out_tree"]
                    ),
                    None,
                )
            engine._cache.update(restored)
            _stash_blobs(engine, directory, with_native=True)
            return WarmRestoreReport(
                restored=True, via="native", executables=len(restored)
            )
        except Exception as e:  # noqa: BLE001 — stale native payloads degrade
            warnings.warn(
                f"warm_state: native restore failed ({e}); "
                "trying the portable jax.export form",
                stacklevel=2,
            )
    try:
        with open(os.path.join(directory, _PORTABLE), "rb") as fh:
            blobs = pickle.load(fh)
        restored = {}
        for b in blobs:
            exp = jexport.deserialize(b["blob"])
            sds = _unpack_sds(b["sds"])
            # donation is not re-requested here: the exported module is
            # re-compiled by XLA anyway and donation is a perf hint only
            restored[b["key"]] = (jax.jit(exp.call).lower(*sds).compile(), None)
        engine._cache.update(restored)
        _stash_blobs(engine, directory, with_native=False)
        return WarmRestoreReport(
            restored=True, via="export", executables=len(restored)
        )
    except Exception as e:  # noqa: BLE001 — never let a bad blob kill serving
        return _cold(f"portable restore failed ({e})")


def _stash_blobs(engine: Any, directory: str, *, with_native: bool) -> None:
    """Keep the restored blobs on the engine so ``save_warm_state`` can carry
    them forward (restored executables cannot be re-serialized). The native
    payloads are carried only when they were trusted at load (version and
    device matched) — a new save's manifest records the CURRENT jax version,
    and it must never vouch for a stale payload."""
    stash = {"native": {}, "portable": {}}
    try:
        if with_native:
            with open(os.path.join(directory, _NATIVE), "rb") as fh:
                stash["native"] = {b["key"]: b for b in pickle.load(fh)}
        with open(os.path.join(directory, _PORTABLE), "rb") as fh:
            stash["portable"] = {b["key"]: b for b in pickle.load(fh)}
    except Exception:  # noqa: BLE001 — the stash is best-effort
        pass
    engine._warm_saved = stash
