"""MixedScheduler — one admission-controlled queue for generate AND explain.

The repo's two serving halves historically ran split-brain: ``ServeEngine``
decoded with a donated-cache ``lax.scan`` while ``ExplainEngine`` re-ran the
same forwards from scratch in a separate process. This module fuses them
behind one bounded request queue, so a real mixed workload pays the model
once and gets admission control:

  * **Bounded queue, backpressure, per-tenant rate/priority classes** —
    ``submit()`` rejects (never blocks, never drops silently) when the queue
    is full (``rejected_backpressure``) or the tenant's token bucket is dry
    (``rejected_rate``); every request carries an ``SLOClass`` whose priority
    orders the dispatch heap.
  * **KV/logit probe reuse** — a generate request with ``explain=True``
    attributes its prompt toward the first emitted token by DONATING the
    decode prefill's chosen-token log-prob as the explain stage-1 endpoint
    ``f(x)`` (``ExplainRequest.f_x``): the α=1 probe forward and the
    completeness endpoint forward are never re-run. At float32 compute the
    donated value is bit-identical to the forward the standalone engine
    would have run (benchmarks/mixed_serving.py gates this); later streamed
    positions (``explain_stream=True``) ride the same executables without a
    donated endpoint, because incremental decode-step logits are NOT bitwise
    equal to a fresh forward (softmax over the padded KV buffer
    reassociates) and the reuse contract refuses to donate approximations.
  * **δ-aware preemption** — adaptive escalation hops
    (``explain_engine.AdaptiveBucketRun``) are the scheduler's lowest
    -priority work items: decode chunks always dispatch ahead of pending
    hops (each deferral counted on ``EngineStats.preempted``), so explain
    traffic can never starve decode; conversely every hop that does run uses
    exactly the executables standalone serving warmed (shared cache keys —
    the zero-steady-state-recompile invariant spans both traffic kinds).
  * **Fault degradation, not death** — every model-executing item runs under
    ``runtime.fault.RetryPolicy``; on exhaustion the AFFECTED requests
    degrade to a fallback result (decode keeps the tokens emitted so far,
    explain falls back to the last completed rung or zero scores) and the
    engine keeps serving. A ``StragglerMonitor`` observes per-item wall
    times. ``EngineStats`` carries the ``degraded``/``preempted``/
    ``queue_depth`` counters.

The dispatch loop is synchronous and cooperative (``step()`` runs exactly
one work item): preemption happens BETWEEN compiled-program calls, which is
the only place it can happen on an accelerator anyway, and the loop is
driven either inline (``run_until_idle``) or from a host event loop.
"""
from __future__ import annotations

import heapq
import time
import warnings
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.fault import FaultConfig, RetryPolicy, StragglerMonitor
from repro.serve.batching import bucket_for, pad_rows, plan_buckets
from repro.serve.engine import make_decode_chunk, make_prefill_step, sample_token
from repro.serve.explain_engine import (
    AdaptiveBucketRun,
    BucketStats,
    ExplainEngine,
    ExplainRequest,
)

# -- request classes ---------------------------------------------------------


@dataclass(frozen=True)
class SLOClass:
    """A latency class: ``priority`` orders the dispatch heap (lower = more
    urgent); ``target_p99_ms`` is the class's reported SLO target (0 = none)."""

    name: str
    priority: int
    target_p99_ms: float = 0.0


INTERACTIVE = SLOClass("interactive", 0, 150.0)
BATCH = SLOClass("batch", 1, 1500.0)
EXPLAIN = SLOClass("explain", 2, 0.0)

# hop items sit BELOW every request class: δ-escalation is strictly
# best-effort work and must never starve decode (ISSUE 8 / ROADMAP)
_PRIO_EXPLAIN_WORK = 10
_PRIO_HOP = 20


@dataclass(frozen=True)
class TenantPolicy:
    """Token-bucket admission: ``rate`` requests/s refill, ``burst`` capacity."""

    rate: float = float("inf")
    burst: int = 8


@dataclass(frozen=True)
class GenerateRequest:
    """A decode request, optionally with attribution riding along.

    ``explain=True`` attributes the prompt toward the FIRST emitted token
    with the donated-endpoint contract (bit-exact at f32 compute);
    ``explain_stream=True`` additionally attributes every later emitted
    token (prompt+prefix → token) as tokens stream out — those ride the same
    warmed explain executables but self-probe (no donated endpoint; see the
    module docstring for why). ``seed=None`` decodes greedily; a seed
    samples at ``temperature``.
    """

    tokens: np.ndarray  # (S,) int32 prompt
    num_tokens: int
    tenant: str = "default"
    slo: SLOClass = INTERACTIVE
    explain: bool = False
    explain_stream: bool = False
    temperature: float = 0.0
    seed: Optional[int] = None


@dataclass
class Ticket:
    """The caller's handle: filled in as the scheduler makes progress.

    ``status`` ∈ queued | running | done | degraded | rejected_backpressure |
    rejected_rate. ``tokens`` accumulates emitted ids; ``attributions``
    accumulates per-position explain result dicts (each tagged ``pos`` /
    ``token``) in emission order; explain-only tickets get ``result``.
    """

    id: int
    kind: str  # "generate" | "explain"
    status: str = "queued"
    tenant: str = "default"
    slo: SLOClass = EXPLAIN
    tokens: Optional[np.ndarray] = None
    attributions: list = field(default_factory=list)
    result: Optional[dict] = None
    degraded: bool = False
    submitted_s: float = 0.0
    finished_s: float = 0.0
    # internal completion tracking
    _decode_done: bool = False
    _pending_explains: int = 0

    @property
    def latency_s(self) -> float:
        return self.finished_s - self.submitted_s


class _TokenBucket:
    def __init__(self, policy: TenantPolicy, time_fn: Callable[[], float]):
        self.policy = policy
        self.tokens = float(policy.burst)
        self.time_fn = time_fn
        self._t = time_fn()

    def try_take(self) -> bool:
        now = self.time_fn()
        if self.policy.rate != float("inf"):
            self.tokens = min(
                float(self.policy.burst),
                self.tokens + (now - self._t) * self.policy.rate,
            )
        self._t = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


# -- internal work-item payloads --------------------------------------------


@dataclass
class _GenGroup:
    """Same-shape generate requests batched for one prefill + decode stream.

    Grouping key: exact prompt length (prefill logits of a padded prompt
    would attend over pad tokens — NOT the same forward, so no padding in S),
    num_tokens, and the sampling config. The batch axis pads up the batch
    ladder by repeating the last row; pad-row outputs are dropped.
    """

    tickets: list  # real tickets, row-aligned with prompts
    requests: list  # the GenerateRequests, row-aligned with tickets
    prompts: np.ndarray  # (B_pad, S) int32
    n_real: int
    num_tokens: int
    temperature: float
    seed: Optional[int]
    priority: int


@dataclass
class _DecodeStream:
    group: _GenGroup
    cache: Any  # device KV cache, carried chunk to chunk
    last_tok: Any  # (B, 1) device
    remaining: int
    emitted: int  # tokens emitted per row so far (incl. the prefill token)


class MixedScheduler:
    """The unified serving path over one ``ExplainEngine``'s model+params.

    Decode executables (prefill per exact (B, S), decode chunks) are
    AOT-compiled into the scheduler's own cache but counted on the ENGINE's
    hit/miss stats — the "combined executable set" the zero-recompile gate
    watches is one set. Explain work goes through the engine's own buckets,
    start/hop executables and stats, so mixed and standalone traffic are
    indistinguishable to the compile cache.

    Args:
        engine: the ``ExplainEngine`` (its cfg/params also serve decode).
        max_len: static KV-cache length (prompt+generation must fit).
        max_queue: bounded-queue capacity (backpressure above it).
        decode_chunk: tokens per preemptible decode work item.
        tenants: name → ``TenantPolicy`` (absent tenants are unlimited).
        fault_cfg / time_fn: fault policy knobs and the clock (injectable
            for tests).
    """

    def __init__(
        self,
        engine: ExplainEngine,
        *,
        max_len: int = 128,
        max_queue: int = 64,
        decode_chunk: int = 8,
        tenants: Optional[dict] = None,
        fault_cfg: FaultConfig = FaultConfig(backoff_base_s=0.0),
        time_fn: Callable[[], float] = time.monotonic,
    ):
        assert engine.n_samples == 1, (
            "MixedScheduler serves per-row methods; path-ensemble methods "
            "(n_samples > 1) go through ExplainEngine.explain directly"
        )
        self.engine = engine
        self.max_len = max_len
        self.max_queue = max_queue
        self.decode_chunk = decode_chunk
        self.tenants = tenants or {}
        self.time_fn = time_fn
        self._buckets = {
            name: _TokenBucket(pol, time_fn) for name, pol in self.tenants.items()
        }
        self.retry = RetryPolicy(fault_cfg)
        self.monitor = StragglerMonitor(fault_cfg)
        # test/benchmark fault injection: called as fault_hook(kind, payload)
        # at the top of every (retried) work-item attempt; raise to inject a
        # failure, sleep to inject a straggler
        self.fault_hook: Optional[Callable[[str, Any], None]] = None

        self._prefill_fn = make_prefill_step(engine.cfg, max_len)
        self._chunk_fn = make_decode_chunk(engine.cfg)
        self._exec_cache: dict[tuple, Any] = {}
        self.decode_stats: dict[tuple, BucketStats] = {}

        self._heap: list = []  # (priority, seq, kind, payload)
        self._seq = 0
        self._next_id = 0
        self.tickets: list[Ticket] = []
        self._pending_gen: list[tuple[Ticket, GenerateRequest]] = []
        self._pending_exp: list[tuple[Ticket, int, Optional[int], ExplainRequest]] = []
        self._gen_flush_queued = False
        self._exp_flush_queued = False
        self.latencies: dict[str, list[float]] = {}
        self.rejected_backpressure = 0
        self.rejected_rate = 0

    # -- admission -----------------------------------------------------------

    @property
    def queue_depth(self) -> int:
        return len(self._heap) + len(self._pending_gen) + len(self._pending_exp)

    def submit(
        self,
        req: Union[GenerateRequest, ExplainRequest],
        *,
        tenant: str = "default",
        slo: Optional[SLOClass] = None,
    ) -> Ticket:
        """Admit one request; returns its ``Ticket`` immediately.

        Rejection (full queue / dry tenant bucket) and admission-time
        degradation (a prompt no ladder rung or the KV cache can hold — a
        poisoned request must not reach, and kill, the dispatch loop) are
        reported on the ticket, never raised.
        """
        is_gen = isinstance(req, GenerateRequest)
        t = Ticket(
            id=self._next_id,
            kind="generate" if is_gen else "explain",
            tenant=req.tenant if is_gen else tenant,
            slo=(slo or req.slo) if is_gen else (
                slo or (BATCH if self.engine._spec.forward_only else EXPLAIN)
            ),
            submitted_s=self.time_fn(),
        )
        self._next_id += 1
        self.tickets.append(t)
        if not is_gen:
            hit = self._cached_result(req)
            if hit is not None:
                # content-addressed replay (serve.result_cache): a hit is
                # admitted BEFORE backpressure and rate checks — it costs no
                # queue slot, no tenant budget, and never preempts decode,
                # so cached traffic cannot push fresh traffic into rejection
                t.result = hit
                t._decode_done = True
                t._pending_explains = 0
                self._finish(t)
                return t
        if self.queue_depth >= self.max_queue:
            t.status = "rejected_backpressure"
            self.rejected_backpressure += 1
            return t
        bucket = self._buckets.get(t.tenant)
        if bucket is not None and not bucket.try_take():
            t.status = "rejected_rate"
            self.rejected_rate += 1
            return t
        try:  # poisoned-size admission check: degrade, don't explode later
            bucket_for(len(req.tokens), self.engine.seq_buckets)
            if is_gen and len(req.tokens) + req.num_tokens > self.max_len:
                raise ValueError("prompt + generation exceeds KV capacity")
        except ValueError:
            self._degrade_ticket(t, reason="admission")
            return t
        if is_gen:
            if req.num_tokens <= 0:
                t.tokens = np.zeros((0,), np.int32)
                self._finish(t)
                return t
            t.tokens = np.zeros((0,), np.int32)
            self._pending_gen.append((t, req))
            if not self._gen_flush_queued:
                self._gen_flush_queued = True
                self._push(t.slo.priority, "gen_flush", None)
        else:
            t._pending_explains = 1
            t._decode_done = True
            self._pending_exp.append((t, -1, None, req))
            if not self._exp_flush_queued:
                self._exp_flush_queued = True
                self._push(_PRIO_EXPLAIN_WORK, "exp_flush", None)
        return t

    # -- dispatch loop -------------------------------------------------------

    def _push(self, priority: int, kind: str, payload: Any) -> None:
        heapq.heappush(self._heap, (priority, self._seq, kind, payload))
        self._seq += 1

    def step(self) -> bool:
        """Dispatch exactly one work item; False when idle."""
        if not self._heap:
            return False
        self.engine.stats.queue_depth = self.queue_depth
        prio, _, kind, payload = heapq.heappop(self._heap)
        if kind in ("prefill", "decode") and any(
            k in ("hop", "exp_fwd") for _, _, k, _ in self._heap
        ):
            # δ-aware preemption: this decode work runs AHEAD of queued
            # escalation hops — count the deferral. Forward-only mask
            # batches (``exp_fwd``) sit at the same rung: they are BATCH
            # -class throughput work that always yields to latency traffic
            self.engine.stats.preempted += 1
        handler = {
            "gen_flush": self._do_gen_flush,
            "exp_flush": self._do_exp_flush,
            "prefill": self._do_prefill,
            "decode": self._do_decode,
            "exp_fixed": self._do_exp_fixed,
            "exp_fwd": self._do_exp_fwd,
            "exp_start": self._do_exp_start,
            "hop": self._do_hop,
        }[kind]
        handler(payload)
        self.engine.stats.queue_depth = self.queue_depth
        return True

    def run_until_idle(self) -> None:
        while self.step():
            pass

    # -- flush markers: coalesce pending requests into batched items ---------

    def _do_gen_flush(self, _payload) -> None:
        self._gen_flush_queued = False
        pending, self._pending_gen = self._pending_gen, []
        groups: dict[tuple, list[tuple[Ticket, GenerateRequest]]] = {}
        for t, r in pending:
            key = (len(r.tokens), r.num_tokens, r.temperature, r.seed)
            groups.setdefault(key, []).append((t, r))
        for (S, num_tokens, temp, seed), members in groups.items():
            rows, B = pad_rows(
                list(range(len(members))), self.engine.batch_buckets
            )
            prompts = np.stack(
                [np.asarray(members[i][1].tokens, np.int32) for i in rows]
            )
            grp = _GenGroup(
                tickets=[m[0] for m in members],
                requests=[m[1] for m in members],
                prompts=prompts,
                n_real=len(members),
                num_tokens=num_tokens,
                temperature=temp,
                seed=seed,
                priority=min(m[0].slo.priority for m in members),
            )
            self._push(grp.priority, "prefill", grp)

    def _do_exp_flush(self, _payload) -> None:
        self._exp_flush_queued = False
        pending, self._pending_exp = self._pending_exp, []
        forward_only = self.engine._spec.forward_only
        if forward_only:
            # forward-only buckets self-probe both endpoints inside ONE
            # executable class — a decode-donated f_x would fork the compile
            # key for nothing (there is no gradient pass to save)
            pending = [
                (t, pos, tok, replace(r, f_x=None) if r.f_x is not None else r)
                for (t, pos, tok, r) in pending
            ]
        reqs = [p[3] for p in pending]
        plan = plan_buckets(
            reqs,
            seq_buckets=self.engine.seq_buckets,
            batch_buckets=self.engine.batch_buckets,
            max_batch=self.engine.max_batch,
            pad_id=self.engine.pad_id,
            batch_multiple=self.engine.dp,
        )
        for bb in plan:
            reqmap = [pending[i] for i in bb.indices]
            if forward_only:
                # perturbation mask batches are preemptible BATCH-class
                # work: queued at the hop rung so interactive decode always
                # dispatches first (and counts the deferral, step())
                self._push(_PRIO_HOP, "exp_fwd", (bb, reqmap))
            elif self.engine.adaptive:
                run = AdaptiveBucketRun(self.engine, bb)
                self._push(_PRIO_EXPLAIN_WORK, "exp_start", (run, reqmap))
            else:
                self._push(_PRIO_EXPLAIN_WORK, "exp_fixed", (bb, reqmap))

    # -- decode items --------------------------------------------------------

    def _aot(self, key: tuple, fn, args: tuple, *, static=(), donate=()):
        """AOT-compile one decode executable; counted on the ENGINE's
        hit/miss stats so the mixed path's compile set is one set."""
        ent = self._exec_cache.get(key)
        if ent is not None:
            self.engine.stats.hits += 1
            return ent
        self.engine.stats.misses += 1
        bs = self.decode_stats.setdefault(key, BucketStats())
        bs.compiles += 1
        t0 = time.perf_counter()
        sds = [
            a if i in static
            else jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), a)
            for i, a in enumerate(args)
        ]
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message=".*donated buffers were not usable.*"
            )
            ent = (
                jax.jit(fn, static_argnums=static, donate_argnums=donate)
                .lower(*sds)
                .compile()
            )
        bs.compile_s += time.perf_counter() - t0
        self._exec_cache[key] = ent
        return ent

    def _do_prefill(self, grp: _GenGroup) -> None:
        B, S = grp.prompts.shape
        batch = {"tokens": jnp.asarray(grp.prompts)}
        ex = self._aot(
            ("dprefill", B, S), self._prefill_fn, (self.engine.params, batch)
        )
        ok, out = self._run_item("prefill", grp, lambda: ex(self.engine.params, batch))
        if not ok:
            for t in grp.tickets:
                self._degrade_ticket(t, reason="prefill")
            return
        logits, cache = out
        lg = logits[:, -1].astype(jnp.float32)
        if grp.seed is None:
            tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        else:
            tok = sample_token(
                lg,
                jax.random.fold_in(jax.random.PRNGKey(grp.seed), 2**32 - 1),
                jnp.asarray(grp.temperature, jnp.float32),
            )
        # the chosen token's log-prob IS the explain endpoint f(x) — the
        # donated-probe contract (module docstring; bit-exact at f32)
        lp = jax.nn.log_softmax(lg, axis=-1)[jnp.arange(lg.shape[0]), tok]
        tok_np, lp_np = np.asarray(tok), np.asarray(lp)
        for row in range(grp.n_real):
            t, req = grp.tickets[row], grp.requests[row]
            t.status = "running"
            t.tokens = np.append(t.tokens, tok_np[row]).astype(np.int32)
            if req.explain:
                self._enqueue_explain(
                    t,
                    pos=0,
                    token=int(tok_np[row]),
                    prompt=np.asarray(req.tokens, np.int32),
                    f_x=float(lp_np[row]),
                )
        if grp.num_tokens > 1:
            stream = _DecodeStream(
                group=grp,
                cache=cache,
                last_tok=tok[:, None],
                remaining=grp.num_tokens - 1,
                emitted=1,
            )
            self._push(grp.priority, "decode", stream)
        else:
            for t in grp.tickets:
                t._decode_done = True
                self._maybe_finish(t)

    def _do_decode(self, st: _DecodeStream) -> None:
        grp = st.group
        n = min(self.decode_chunk, st.remaining)
        B = grp.prompts.shape[0]
        seed = grp.seed if grp.seed is not None else 0
        key = jax.random.fold_in(jax.random.PRNGKey(seed), st.emitted)
        temp = jnp.asarray(
            grp.temperature if grp.seed is not None else 0.0, jnp.float32
        )
        ex = self._aot(
            ("dchunk", B, n),
            self._chunk_fn,
            (self.engine.params, st.cache, st.last_tok, key, temp, n),
            static=(5,),
            donate=(1,),
        )
        ok, out = self._run_item(
            "decode",
            st,
            lambda: ex(self.engine.params, st.cache, st.last_tok, key, temp),
        )
        if not ok:
            # the cache may have been donated into the failed call: the
            # emitted-so-far prefix is the fallback result
            for t in grp.tickets:
                self._degrade_ticket(t, reason="decode", keep_tokens=True)
            return
        toks, lps, st.cache = out
        toks_np = np.asarray(toks)
        for row in range(grp.n_real):
            t, req = grp.tickets[row], grp.requests[row]
            if t.degraded:
                continue
            for k in range(n):
                pos = st.emitted + k
                tok_id = int(toks_np[row, k])
                t.tokens = np.append(t.tokens, tok_id).astype(np.int32)
                if req.explain_stream:
                    # streamed positions self-probe: incremental decode-step
                    # logits are not bitwise a fresh forward, so no donation
                    prefix = np.concatenate(
                        [np.asarray(req.tokens, np.int32), t.tokens[:pos]]
                    )
                    self._enqueue_explain(
                        t, pos=pos, token=tok_id, prompt=prefix, f_x=None
                    )
        st.last_tok = toks[:, -1:]
        st.remaining -= n
        st.emitted += n
        if st.remaining > 0:
            self._push(grp.priority, "decode", st)
        else:
            for t in grp.tickets:
                t._decode_done = True
                self._maybe_finish(t)

    # -- explain items -------------------------------------------------------

    def _cached_result(self, req: ExplainRequest) -> Optional[dict]:
        """Consult the engine's content-addressed result cache (a fresh copy
        on hit, raw row trimmed — tickets carry caller-facing dicts)."""
        rc = self.engine.result_cache
        if rc is None:
            return None
        hit = rc.get(self.engine.request_cache_key(req))
        self.engine._sync_result_stats()
        if hit is not None:
            hit.pop("raw_token_scores", None)
        return hit

    def _cache_result(self, req: ExplainRequest, r: dict) -> None:
        """Insert one finished result (degraded fallbacks are never cached —
        replaying a fault-path zero vector forever would be wrong)."""
        rc = self.engine.result_cache
        if rc is not None and not r.get("degraded"):
            rc.put(self.engine.request_cache_key(req), r)
            self.engine._sync_result_stats()

    def _enqueue_explain(
        self,
        t: Ticket,
        *,
        pos: int,
        token: int,
        prompt: np.ndarray,
        f_x: Optional[float],
    ) -> None:
        if len(prompt) > max(self.engine.seq_buckets):
            self._deliver_degraded(t, pos, token, n_tokens=len(prompt))
            return
        req = ExplainRequest(tokens=prompt, target=token, f_x=f_x)
        hit = self._cached_result(req)
        if hit is not None:
            # per-token replay for generate+explain tickets: this position's
            # attribution never reaches the explain queue
            t._pending_explains += 1
            self._deliver(t, pos, token, hit)
            return
        t._pending_explains += 1
        self._pending_exp.append((t, pos, token, req))
        if not self._exp_flush_queued:
            self._exp_flush_queued = True
            self._push(_PRIO_EXPLAIN_WORK, "exp_flush", None)

    def _do_exp_fixed(self, payload) -> None:
        bb, reqmap = payload
        ok, res = self._run_item(
            "exp_fixed", bb, lambda: self.engine._run_bucket(bb)
        )
        if not ok:
            self.engine.stats.degraded += len(reqmap)
            for (t, pos, token, req) in reqmap:
                self._deliver_degraded(t, pos, token, n_tokens=len(req.tokens))
            return
        per_token = np.asarray(res.attributions.sum(-1))
        for row, (t, pos, token, req) in enumerate(reqmap):
            r = {
                "token_scores": per_token[row, : bb.lens[row]],
                "delta": float(res.delta[row]),
                "f_x": float(res.f_x[row]),
                "f_baseline": float(res.f_baseline[row]),
                "bucket": bb.bucket,
                "degraded": False,
                "raw_token_scores": per_token[row],
            }
            self._cache_result(req, r)
            self._deliver(t, pos, token, r)

    def _do_exp_fwd(self, payload) -> None:
        bb, reqmap = payload
        ok, res = self._run_item(
            "exp_fwd", bb, lambda: self.engine._run_bucket_fwd(bb)
        )
        if not ok:
            self.engine.stats.degraded += len(reqmap)
            for (t, pos, token, req) in reqmap:
                self._deliver_degraded(t, pos, token, n_tokens=len(req.tokens))
            return
        # perturbation scores are per POSITION already — no feature axis
        per_token = np.asarray(res.attributions)
        for row, (t, pos, token, req) in enumerate(reqmap):
            r = {
                "token_scores": per_token[row, : bb.lens[row]],
                "delta": float(res.delta[row]),
                "f_x": float(res.f_x[row]),
                "f_baseline": float(res.f_baseline[row]),
                "bucket": bb.bucket,
                "degraded": False,
                "raw_token_scores": per_token[row],
            }
            self._cache_result(req, r)
            self._deliver(t, pos, token, r)

    def _do_exp_start(self, payload) -> None:
        run, reqmap = payload
        ok, _ = self._run_item("exp_start", run, run.start)
        if not ok:
            # rung 0 never ran: there is no partial result to fall back to
            self.engine.stats.degraded += len(reqmap)
            for (t, pos, token, req) in reqmap:
                self._deliver_degraded(t, pos, token, n_tokens=len(req.tokens))
            return
        if run.active:
            self._push(_PRIO_HOP, "hop", payload)
        else:
            self._deliver_run(run, reqmap)

    def _do_hop(self, payload) -> None:
        run, reqmap = payload
        ok, _ = self._run_item("hop", run, run.hop)
        if not ok:
            # the completed rungs stand: degrade ONLY the still-active rows
            run.degrade()
        if run.active:
            self._push(_PRIO_HOP, "hop", payload)
        else:
            self._deliver_run(run, reqmap)

    def _deliver_run(self, run: AdaptiveBucketRun, reqmap) -> None:
        # results arrive in bb.indices order — exactly reqmap's order
        for r, (t, pos, token, req) in zip(run.results(), reqmap):
            r.pop("request", None)
            self._cache_result(req, r)
            self._deliver(t, pos, token, r)

    # -- completion / degradation -------------------------------------------

    def _deliver(self, t: Ticket, pos: int, token: Optional[int], r: dict) -> None:
        r.pop("raw_token_scores", None)
        if t.kind == "explain":
            t.result = r
        else:
            t.attributions.append({"pos": pos, "token": token, **r})
        if r.get("degraded"):
            t.degraded = True
        t._pending_explains -= 1
        self._maybe_finish(t)

    def _deliver_degraded(
        self, t: Ticket, pos: int, token: Optional[int], *, n_tokens: int
    ) -> None:
        """Zero-attribution fallback for a request whose explain work could
        not run at all (fault exhaustion / unservable size)."""
        t.degraded = True
        self._deliver(
            t,
            pos,
            token,
            {
                "token_scores": np.zeros((n_tokens,), np.float32),
                "delta": float("inf"),
                "degraded": True,
                "converged": False,
            },
        )

    def _degrade_ticket(
        self, t: Ticket, *, reason: str, keep_tokens: bool = False
    ) -> None:
        t.degraded = True
        self.engine.stats.degraded += 1
        if t.kind == "generate" and (t.tokens is None or not keep_tokens):
            t.tokens = np.zeros((0,), np.int32)
        t._decode_done = True
        t._pending_explains = 0
        t.status = "degraded"
        t.finished_s = self.time_fn()
        self._record_latency(t)

    def _maybe_finish(self, t: Ticket) -> None:
        if t._decode_done and t._pending_explains <= 0 and t.status not in (
            "done",
            "degraded",
        ):
            self._finish(t)

    def _finish(self, t: Ticket) -> None:
        t.status = "degraded" if t.degraded else "done"
        if t.attributions:
            # bucket interleave may deliver out of emission order; the
            # per-token stream the caller sees is position-ordered
            t.attributions.sort(key=lambda a: a["pos"])
        t.finished_s = self.time_fn()
        self._record_latency(t)

    def _record_latency(self, t: Ticket) -> None:
        self.latencies.setdefault(t.slo.name, []).append(t.latency_s)

    def _run_item(self, kind: str, payload: Any, fn: Callable):
        """One retried, straggler-observed work item. Returns (ok, result);
        ``ok=False`` means the retry policy exhausted — the caller degrades
        the affected requests and the loop keeps serving."""
        t0 = time.perf_counter()
        def attempt():
            if self.fault_hook is not None:
                self.fault_hook(kind, payload)
            return fn()
        try:
            out, ok = self.retry(attempt), True
        except Exception:  # noqa: BLE001 — degradation boundary
            out, ok = None, False
        self.monitor.observe(time.perf_counter() - t0)
        return ok, out

    # -- reporting -----------------------------------------------------------

    def latency_summary(self) -> dict:
        """Per-SLO-class p50/p99 (seconds) over completed tickets."""
        out = {}
        for name, vals in self.latencies.items():
            v = np.asarray(vals)
            out[name] = {
                "n": int(v.size),
                "p50_s": float(np.percentile(v, 50)),
                "p99_s": float(np.percentile(v, 99)),
            }
        return out
