"""Compatibility shim: the historical ExplainService API over ExplainEngine.

The batched-IG serving logic lives in ``repro.serve.explain_engine`` now —
shape-bucketed batching, masked padding, and the compiled-executable cache.
This shim keeps the original one-model/one-method constructor and the
``explain(requests) -> list[dict]`` contract, with two upgrades: requests no
longer need equal sequence lengths (they are bucketed and masked), and
``method`` now names an attribution method from ``repro.core.methods``
(ig / idgi / noise_tunnel / expected_grad) while ``schedule`` names the
interpolation schedule family (uniform / paper / warp / gauss / refine).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from repro.configs.base import ArchConfig
from repro.serve.explain_engine import ExplainEngine, ExplainRequest

__all__ = ["ExplainService", "ExplainRequest"]


@dataclass
class ExplainService:
    cfg: ArchConfig
    params: Any
    method: str = "ig"  # attribution method (repro.core.methods.METHODS)
    schedule: str = "paper"  # schedule family (repro.core.schedule.SCHEDULES)
    m: int = 64
    n_int: int = 4
    chunk: int = 0
    pad_id: int = 0  # baseline token (see ExplainEngine._bucket_inputs)
    # adaptive iso-convergence (DESIGN.md §7): m becomes the base rung of a
    # pow-2 ladder topping out at m_max; requests exit as soon as
    # δ ≤ tol·|f_x − f_baseline| and report their per-request m_used.
    adaptive: bool = False
    tol: float = 1e-2
    m_max: int = 0
    # path-ensemble methods (0/0.0 = the method's registered defaults)
    n_samples: int = 0
    sigma: float = 0.0
    # fused stage 2, Pallas kernel injection, and per-(bucket, device)
    # tuned configs (DESIGN.md §10)
    fused: bool = False
    use_kernels: bool = False
    autotune: bool = False

    def __post_init__(self):
        self._engine = ExplainEngine(
            self.cfg,
            self.params,
            method=self.method,
            schedule=self.schedule,
            m=self.m,
            n_int=self.n_int,
            chunk=self.chunk,
            pad_id=self.pad_id,
            adaptive=self.adaptive,
            tol=self.tol,
            m_max=self.m_max,
            n_samples=self.n_samples,
            sigma=self.sigma,
            fused=self.fused,
            use_kernels=self.use_kernels,
            autotune=self.autotune,
        )

    @property
    def engine(self) -> ExplainEngine:
        return self._engine

    def explain(self, requests: list[ExplainRequest]) -> list[dict]:
        """Bucket the requests (any S), run the method, return token scores."""
        return self._engine.explain(requests)
