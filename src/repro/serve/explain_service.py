"""Batched IG explanation serving — the paper's end product as a service.

A request asks "why did the model predict ``target`` at the end of
``tokens``?". The service embeds the prompt, runs NUIG in embedding space
(stage 1 probe + stage 2 attribution, one compiled program each), and
reduces (pos, d_model) attributions to per-token scores.

This is where the paper's static-stage-2 design pays off on TPU: requests
are batched and the interpolation-step axis folds into the batch axis, so
the whole explanation pipeline is data-parallel under pjit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.api import Explainer
from repro.models.registry import Model


@dataclass(frozen=True)
class ExplainRequest:
    tokens: np.ndarray  # (S,) int32 prompt
    target: int  # token id whose next-token log-prob is attributed


@dataclass
class ExplainService:
    cfg: ArchConfig
    params: Any
    method: str = "paper"
    m: int = 64
    n_int: int = 4
    chunk: int = 0
    pad_id: int = 0  # baseline token (see explain())

    def __post_init__(self):
        self.model = Model(self.cfg)
        self._f = self.model.target_logprob_fn(self.params)
        self._explainer = Explainer(
            self._f, method=self.method, m=self.m, n_int=self.n_int, chunk=self.chunk
        )
        self._jitted = jax.jit(self._attribute_batch)

    def _attribute_batch(self, embeds, baseline, targets):
        return self._explainer.attribute(embeds, baseline, targets)

    def explain(self, requests: list[ExplainRequest]) -> list[dict]:
        """Batch the requests (same S), run NUIG, return per-token scores."""
        S = len(requests[0].tokens)
        assert all(len(r.tokens) == S for r in requests), "batch requires equal S"
        tokens = jnp.asarray(np.stack([r.tokens for r in requests]))
        targets = jnp.asarray([r.target for r in requests], jnp.int32)
        embeds = self.model.embed_inputs(self.params, {"tokens": tokens})
        # PAD-token embedding, not zeros: RMSNorm backbones are scale-
        # invariant through their first norm, so a ray through the origin
        # has (near-)zero gradient a.e. and completeness can never converge.
        from repro.core.baselines import pad_embedding

        baseline = pad_embedding(
            self.params["embed"]["embedding"], embeds, pad_id=self.pad_id
        )
        res = self._jitted(embeds, baseline, targets)
        per_token = np.asarray(res.attributions.sum(-1))  # (B, S)
        return [
            {
                "token_scores": per_token[i],
                "delta": float(res.delta[i]),
                "f_x": float(res.f_x[i]),
                "f_baseline": float(res.f_baseline[i]),
            }
            for i in range(len(requests))
        ]
