"""Method-zoo quality bench: insertion/deletion AUC + latency per
method × schedule on the trained paper CNN, PLUS the gradient-vs-
perturbation bake-off -> results/BENCH_quality.json.

The MethodSpec registry (DESIGN.md §8) promises that every attribution
method rides every schedule family through one compiled pipeline; this bench
is the quantitative half of that promise: for each (method, schedule) cell it
records heatmap quality (insertion AUC up / deletion AUC down = better
feature ordering — ``repro.core.metrics``), the completeness gap δ, and the
warmed end-to-end wall latency of the jitted explainer (compile time paid
outside the timed call, as in serving).

The bake-off extends the table across the CLASS boundary: the forward-only
perturbation methods (occlusion / RISE / LIME, ``repro.core.perturb``) score
the same trained CNN (via a cell grid — pixels share their cell's score) and
the trained reduced ViT (patch features) at a FORWARD-MATCHED budget
P = 2·m — each of the gradient class's m interpolation steps costs one
forward + one backward pass, so 2m forwards is the same model-evaluation
budget. Gates folded into ``pass``: insertion AUC > deletion AUC for every
perturbation method × workload cell, and the forward-only serving path
replays with ZERO steady-state recompiles.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    cnn_prob_fn,
    eval_batch,
    load_or_train_cnn,
    load_or_train_vit,
)
from repro.core import metrics, perturb
from repro.core.api import Explainer
from repro.core.methods import METHODS

DEFAULT_SCHEDULES = ("uniform", "paper", "warp")
CNN_CELL = 4  # 32x32x3 -> 8x8 grid of 4x4x3 cells (S=64 positions)


def _timed_auc(f, x, bl, t, attribute_fn, score_to_attr, *, auc_steps):
    """Compile+warm, one timed call, then the insertion/deletion curves.

    ``attribute_fn(x, bl, t)`` is the jitted unit under test;
    ``score_to_attr`` maps its output to pixel/feature attributions in the
    space ``metrics.insertion_deletion_auc`` ranks (the AUC comparability
    contract across the class boundary)."""
    res = jax.block_until_ready(attribute_fn(x, bl, t))
    t0 = time.perf_counter()
    res = jax.block_until_ready(attribute_fn(x, bl, t))
    wall = time.perf_counter() - t0
    attr = score_to_attr(res)
    ins, dele = metrics.insertion_deletion_auc(f, x, bl, attr, t, steps=auc_steps)
    return {
        "insertion_auc": float(jnp.mean(ins)),
        "deletion_auc": float(jnp.mean(dele)),
        "latency_ms": 1e3 * wall,
    }, res


def _bakeoff_workloads(batch_size: int):
    """The two bake-off substrates, each exposing the SAME cell contract:
    (name, pixel/feature f, x, baseline, targets, position lift/unlift).

    The bake-off scores the target-class LOGIT, not the probability: the
    trained bench models are saturated (f32 prob exactly 1.0), so a small
    occlusion's probability drop is EXACTLY zero and every perturbation
    heatmap degenerates to argsort-of-zeros — the logit still moves, and
    the insertion>deletion ordering only needs a monotone response."""
    from repro.configs.paper_cnn import CONFIG as CNN_CONFIG
    from repro.models import cnn, vit

    cnn_params = load_or_train_cnn()

    def f_cnn(imgs, target):
        logits = cnn.forward(CNN_CONFIG, cnn_params, imgs)
        return jnp.take_along_axis(logits, target[:, None], axis=-1)[:, 0]

    x, t = eval_batch(batch_size)
    img_shape = tuple(x.shape[1:])

    vit_cfg, vit_params = load_or_train_vit()
    feats = vit.patchify(vit_cfg, x)

    def f_vit(fe, target):
        e = vit.embed_features(vit_cfg, vit_params, fe)
        logits = vit.pool_logits(vit_cfg, vit_params, vit.encode(vit_cfg, vit_params, e))
        return jnp.take_along_axis(logits, target[:, None], axis=-1)[:, 0]

    return {
        "cnn": {
            # perturbation positions are image CELLS: occlude a 4x4x3 patch,
            # every pixel inherits its cell's score for the AUC ranking
            "f": f_cnn,
            "x": x,
            "baseline": jnp.zeros_like(x),
            "t": t,
            "pos_f": perturb.cell_fn(f_cnn, img_shape, CNN_CELL),
            "pos_x": perturb.image_to_cells(x, CNN_CELL),
            "scores_to_attr": lambda s: perturb.cell_scores_to_pixels(
                s, img_shape, CNN_CELL
            ),
        },
        "vit": {
            # positions are the model's own patches; feature-space AUC
            "f": f_vit,
            "x": feats,
            "baseline": jnp.zeros_like(feats),
            "t": t,
            "pos_f": f_vit,
            "pos_x": feats,
            "scores_to_attr": lambda s: jnp.broadcast_to(
                s[..., None], s.shape + (feats.shape[-1],)
            ),
        },
    }


def _forward_replay_recompiles(n_masks: int) -> dict:
    """Serve the forward-only class through ExplainEngine on the reduced-ViT
    feature workload and replay: steady state must be PURE cache hits (the
    same zero-recompile wall the gradient class is held to)."""
    from repro.models import vit
    from repro.serve import ExplainEngine, ExplainRequest

    vit_cfg, vit_params = load_or_train_vit()
    x, t = eval_batch(2)
    feats = np.asarray(vit.patchify(vit_cfg, x), np.float32)
    reqs = [
        ExplainRequest(
            tokens=np.arange(feats.shape[1], dtype=np.int32),
            target=int(t[i]),
            features=feats[i],
        )
        for i in range(feats.shape[0])
    ]
    out = {}
    for method in ("occlusion", "rise", "lime"):
        eng = ExplainEngine(
            vit_cfg, vit_params, method=method, n_masks=n_masks,
            seq_buckets=(feats.shape[1],),
        )
        eng.explain(reqs)  # warm: compiles counted here
        warmed = eng.stats.misses
        eng.explain(reqs)  # replay: must be hits only
        out[method] = eng.stats.misses - warmed
    return out


def run(
    batch_size: int = 4,
    *,
    m: int = 32,
    n_int: int = 4,
    n_samples: int = 2,
    sigma: float = 0.05,
    schedules=DEFAULT_SCHEDULES,
    auc_steps: int = 8,
    smoke: bool = False,
) -> dict:
    if smoke:
        batch_size = min(batch_size, 2)
        m = 16
        schedules = ("paper",)
    n_masks = 2 * m  # forward-matched budget: m grad steps ≈ 2m forwards
    params = load_or_train_cnn()
    f = cnn_prob_fn(params)
    x, t = eval_batch(batch_size)
    bl = jnp.zeros_like(x)

    out = {
        "m": m,
        "n_int": n_int,
        "n_samples": n_samples,
        "sigma": sigma,
        "n_masks": n_masks,
        "batch": int(x.shape[0]),
        "auc_steps": auc_steps,
        "smoke": smoke,
        "cells": {},
        "bakeoff": {},
    }
    print(f"\n== method-zoo quality (m={m}, n_int={n_int}, B={x.shape[0]}) ==")
    print("method,schedule,insertion_auc,deletion_auc,delta,latency_ms")
    gradient_methods = [
        name for name in sorted(METHODS) if not METHODS[name].forward_only
    ]
    for method in gradient_methods:
        for sched_name in schedules:
            ex = Explainer(
                f,
                method=method,
                schedule=sched_name,
                m=m,
                n_int=n_int,
                n_samples=n_samples,
                sigma=sigma,
            )
            cell, res = _timed_auc(
                f, x, bl, t, ex.jitted(), lambda r: r.attributions,
                auc_steps=auc_steps,
            )
            cell["delta"] = float(jnp.mean(res.delta))
            out["cells"][f"{method}/{sched_name}"] = cell
            print(
                f"{method},{sched_name},{cell['insertion_auc']:.4f},"
                f"{cell['deletion_auc']:.4f},{cell['delta']:.5f},"
                f"{cell['latency_ms']:.1f}"
            )

    # -- gradient-vs-perturbation bake-off (forward-matched budgets) --------
    print(f"\n== bake-off (gradient m={m} vs perturbation P={n_masks}) ==")
    print("workload,method,class,insertion_auc,deletion_auc,latency_ms")
    perturbation_methods = [
        name for name in sorted(METHODS) if METHODS[name].forward_only
    ]
    for wname, w in _bakeoff_workloads(batch_size).items():
        rows: dict = {}
        # gradient anchor at the same model-evaluation budget
        ex = Explainer(w["f"], method="ig", schedule="paper", m=m, n_int=n_int)
        cell, _ = _timed_auc(
            w["f"], w["x"], w["baseline"], w["t"], ex.jitted(),
            lambda r: r.attributions, auc_steps=auc_steps,
        )
        cell["class"] = "gradient"
        cell["budget"] = f"m={m}"
        rows["ig"] = cell
        pos_bl = jnp.zeros_like(w["pos_x"])
        for method in perturbation_methods:
            pe = perturb.PerturbExplainer(w["pos_f"], method=method, n_masks=n_masks)
            attribute = jax.jit(lambda xi, bli, ti, pe=pe: pe.attribute(xi, bli, ti))
            cell, _ = _timed_auc(
                w["f"], w["x"], w["baseline"], w["t"],
                # positions are cells/patches: attribute in the position
                # view, rank in the pixel/feature view
                lambda _x, _b, ti: attribute(w["pos_x"], pos_bl, ti),
                lambda r: w["scores_to_attr"](r.attributions),
                auc_steps=auc_steps,
            )
            cell["class"] = "forward_only"
            cell["budget"] = f"P={n_masks}"
            rows[method] = cell
        out["bakeoff"][wname] = rows
        for method, cell in rows.items():
            print(
                f"{wname},{method},{cell['class']},{cell['insertion_auc']:.4f},"
                f"{cell['deletion_auc']:.4f},{cell['latency_ms']:.1f}"
            )

    # -- forward-only serving wall: zero steady-state recompiles on replay --
    replays = _forward_replay_recompiles(16 if smoke else n_masks)
    out["forward_replay_recompiles"] = replays
    print(f"forward-only replay recompiles: {replays}")

    # gates aggregated into the JSON: every gradient cell AND every
    # perturbation × workload cell must order features better than chance,
    # and forward-only replay must be pure cache hits
    cells_ok = all(
        c["insertion_auc"] > c["deletion_auc"] for c in out["cells"].values()
    )
    bakeoff_ok = all(
        cell["insertion_auc"] > cell["deletion_auc"]
        for rows in out["bakeoff"].values()
        for name, cell in rows.items()
        if cell["class"] == "forward_only"
    )
    replay_ok = all(v == 0 for v in replays.values())
    out["pass"] = bool(cells_ok and bakeoff_ok and replay_ok)
    print(
        f"quality gates: cells={'PASS' if cells_ok else 'FAIL'} "
        f"bakeoff={'PASS' if bakeoff_ok else 'FAIL'} "
        f"replay={'PASS' if replay_ok else 'FAIL'}"
    )
    return out


def main():
    run()


if __name__ == "__main__":
    main()
