"""Method-zoo quality bench: insertion/deletion AUC + latency per
method × schedule on the trained paper CNN -> results/BENCH_quality.json.

The MethodSpec registry (DESIGN.md §8) promises that every attribution
method rides every schedule family through one compiled pipeline; this bench
is the quantitative half of that promise: for each (method, schedule) cell it
records heatmap quality (insertion AUC up / deletion AUC down = better
feature ordering — ``repro.core.metrics``), the completeness gap δ, and the
warmed end-to-end wall latency of the jitted explainer (compile time paid
outside the timed call, as in serving).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import cnn_prob_fn, eval_batch, load_or_train_cnn
from repro.core import metrics
from repro.core.api import Explainer
from repro.core.methods import METHODS

DEFAULT_SCHEDULES = ("uniform", "paper", "warp")


def run(
    batch_size: int = 4,
    *,
    m: int = 32,
    n_int: int = 4,
    n_samples: int = 2,
    sigma: float = 0.05,
    schedules=DEFAULT_SCHEDULES,
    auc_steps: int = 8,
) -> dict:
    params = load_or_train_cnn()
    f = cnn_prob_fn(params)
    x, t = eval_batch(batch_size)
    bl = jnp.zeros_like(x)

    out = {
        "m": m,
        "n_int": n_int,
        "n_samples": n_samples,
        "sigma": sigma,
        "batch": int(x.shape[0]),
        "auc_steps": auc_steps,
        "cells": {},
    }
    print(f"\n== method-zoo quality (m={m}, n_int={n_int}, B={x.shape[0]}) ==")
    print("method,schedule,insertion_auc,deletion_auc,delta,latency_ms")
    for method in sorted(METHODS):
        for sched_name in schedules:
            ex = Explainer(
                f,
                method=method,
                schedule=sched_name,
                m=m,
                n_int=n_int,
                n_samples=n_samples,
                sigma=sigma,
            )
            attribute = ex.jitted()
            res = jax.block_until_ready(attribute(x, bl, t))  # compile + warm
            t0 = time.perf_counter()
            res = jax.block_until_ready(attribute(x, bl, t))
            wall = time.perf_counter() - t0
            ins, dele = metrics.insertion_deletion_auc(
                f, x, bl, res.attributions, t, steps=auc_steps
            )
            cell = {
                "insertion_auc": float(jnp.mean(ins)),
                "deletion_auc": float(jnp.mean(dele)),
                "delta": float(jnp.mean(res.delta)),
                "latency_ms": 1e3 * wall,
            }
            out["cells"][f"{method}/{sched_name}"] = cell
            print(
                f"{method},{sched_name},{cell['insertion_auc']:.4f},"
                f"{cell['deletion_auc']:.4f},{cell['delta']:.5f},"
                f"{cell['latency_ms']:.1f}"
            )
    # sanity aggregated into the JSON: every method must order features
    # better than chance (insertion above deletion) on the confident CNN
    out["pass"] = bool(
        all(
            c["insertion_auc"] > c["deletion_auc"] for c in out["cells"].values()
        )
    )
    print(f"quality gate (insertion > deletion for every cell): "
          f"{'PASS' if out['pass'] else 'FAIL'}")
    return out


def main():
    run()


if __name__ == "__main__":
    main()
