"""Shared benchmark substrate: a TRAINED small inception-style classifier.

The paper's observation (Fig. 3: classification probability rises sharply in
a small α-interval) only manifests on a *confident* model, so we train the
CNN to high accuracy on a deterministic synthetic 10-class task first
(quadrant-pattern images). Trained params are cached in results/.

All benchmarks print CSV-ish tables AND return dicts so run.py can aggregate
into results/benchmarks.json (EXPERIMENTS.md §Paper-claims reads from it).
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import CONFIG as CNN_CONFIG
from repro.models import cnn

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
_CKPT = os.path.join(RESULTS_DIR, "bench_cnn_params.npz")
_VIT_CKPT = os.path.join(RESULTS_DIR, "bench_vit_params.npz")


def synthetic_images(key: jax.Array, n: int, cfg=CNN_CONFIG, *, background_frac: float = 0.0):
    """Class 1..9 = bright blob at a class-specific location + texture;
    class 0 = BACKGROUND (any pattern at low contrast).

    The background class is the key to reproducing the paper's regime: like
    ImageNet models, the trained classifier then has a *contrast threshold* —
    along the black→image IG path the prediction stays "background" until a
    sharp transition α*, concentrating gradient mass in a narrow interval
    (paper Fig. 3). ``background_frac``>0 mixes in dimmed copies labeled 0
    for training; eval batches use frac 0 and labels 1..9.
    """
    kx, kn, kb, ks = jax.random.split(key, 4)
    labels = jax.random.randint(kx, (n,), 1, cfg.num_classes)
    s = cfg.image_size
    yy, xx = jnp.mgrid[0:s, 0:s].astype(jnp.float32) / s
    cx = (labels % 3).astype(jnp.float32)[:, None, None] / 3.0 + 0.15
    cy = ((labels // 3) % 3).astype(jnp.float32)[:, None, None] / 3.0 + 0.15
    blob = jnp.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2) / 0.02))
    tex = jnp.sin((labels[:, None, None] + 2) * 3.0 * xx) * 0.3
    img = blob + tex + 0.1 * jax.random.normal(kn, (n, s, s))
    img = jnp.clip(img, 0, 2) / 2.0
    if background_frac > 0:
        # dim a random subset far below the contrast threshold -> class 0
        is_bg = jax.random.uniform(kb, (n,)) < background_frac
        scale = jax.random.uniform(ks, (n,), minval=0.02, maxval=0.25)
        img = jnp.where(is_bg[:, None, None], img * scale[:, None, None], img)
        labels = jnp.where(is_bg, 0, labels)
    return jnp.repeat(img[..., None], cfg.channels, axis=-1), labels


def train_cnn(key: jax.Array, steps: int = 300, batch: int = 64, lr: float = 2e-3):
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    cfg = CNN_CONFIG
    params = cnn.init(cfg, key)
    ocfg = AdamWConfig(lr=lr, warmup_steps=20, total_steps=steps, weight_decay=0.0)
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, k):
        imgs, labels = synthetic_images(k, batch, background_frac=0.35)

        def loss_fn(p):
            logits = cnn.forward(cfg, p, imgs)
            logp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adamw_update(ocfg, grads, opt, params)
        return params, opt, loss

    for i in range(steps):
        params, opt, loss = step(params, opt, jax.random.fold_in(key, i))
    return params, float(loss)


def load_or_train_cnn(key=None):
    key = key if key is not None else jax.random.PRNGKey(42)
    if os.path.exists(_CKPT):
        data = np.load(_CKPT)
        leaves, treedef = jax.tree.flatten(cnn.param_defs(CNN_CONFIG), is_leaf=lambda x: hasattr(x, "shape"))
        params = jax.tree.unflatten(treedef, [jnp.asarray(data[f"leaf_{i}"]) for i in range(len(leaves))])
        return params
    os.makedirs(RESULTS_DIR, exist_ok=True)
    params, loss = train_cnn(key)
    leaves = jax.tree.leaves(params)
    np.savez(_CKPT, **{f"leaf_{i}": np.asarray(p) for i, p in enumerate(leaves)})
    print(f"# trained bench CNN: final loss {loss:.4f}")
    return params


def train_vit(key: jax.Array, steps: int = 250, batch: int = 32, lr: float = 2e-3):
    """Train the reduced ViT on the same synthetic quadrant task as the CNN
    (reduced_vit shares the CNN's 32x32x3 / 10-class shapes by design)."""
    from repro.configs.vit import reduced_vit
    from repro.models import vit
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    cfg = reduced_vit()
    params = vit.init(cfg, key)
    ocfg = AdamWConfig(lr=lr, warmup_steps=20, total_steps=steps, weight_decay=0.0)
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, k):
        imgs, labels = synthetic_images(k, batch, cfg, background_frac=0.35)

        def loss_fn(p):
            logits = vit.forward(cfg, p, imgs)
            logp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adamw_update(ocfg, grads, opt, params)
        return params, opt, loss

    for i in range(steps):
        params, opt, loss = step(params, opt, jax.random.fold_in(key, i))
    return cfg, params, float(loss)


def load_or_train_vit(key=None):
    """TRAINED reduced ViT + its config — patch-level attributions only show
    the paper's sharp-transition regime on a confident model (same argument
    as ``load_or_train_cnn``). Cached in results/ like the CNN checkpoint."""
    from repro.configs.vit import reduced_vit
    from repro.models import vit

    cfg = reduced_vit()
    key = key if key is not None else jax.random.PRNGKey(43)
    if os.path.exists(_VIT_CKPT):
        data = np.load(_VIT_CKPT)
        leaves, treedef = jax.tree.flatten(
            vit.param_defs(cfg), is_leaf=lambda x: hasattr(x, "shape")
        )
        params = jax.tree.unflatten(
            treedef, [jnp.asarray(data[f"leaf_{i}"]) for i in range(len(leaves))]
        )
        return cfg, params
    os.makedirs(RESULTS_DIR, exist_ok=True)
    cfg, params, loss = train_vit(key)
    leaves = jax.tree.leaves(params)
    np.savez(_VIT_CKPT, **{f"leaf_{i}": np.asarray(p) for i, p in enumerate(leaves)})
    print(f"# trained bench ViT: final loss {loss:.4f}")
    return cfg, params


def vit_accuracy(params, n: int = 256) -> float:
    from repro.configs.vit import reduced_vit
    from repro.models import vit

    cfg = reduced_vit()
    imgs, labels = synthetic_images(jax.random.PRNGKey(99), n, cfg, background_frac=0.3)
    pred = jnp.argmax(vit.forward(cfg, params, imgs), -1)
    return float((pred == labels).mean())


def prompt_pool(rng, vocab_size: int, n: int, *, lengths=(5, 6, 7)) -> list:
    """``n`` distinct int32 prompts with cycled lengths — the unique-request
    pool that repeat traffic (``zipf_sample``) draws from. Shared by the
    mixed-serving and cold-start benchmarks so both sweep the same
    traffic shape."""
    return [
        rng.integers(1, vocab_size, int(lengths[i % len(lengths)])).astype(np.int32)
        for i in range(n)
    ]


def zipf_sample(rng, pool_size: int, n: int, *, alpha: float = 1.1) -> np.ndarray:
    """``n`` indices into a pool, rank-frequency p ∝ (rank+1)^-alpha.

    BOUNDED, unlike ``np.random.zipf`` (whose support is unbounded): every
    draw lands inside the pool, with the head ranks dominating — the
    repeat-heavy pattern production explain traffic shows, and what the
    content-addressed result cache (docs/caching.md) is built for.
    """
    ranks = np.arange(pool_size, dtype=np.float64)
    p = (ranks + 1.0) ** -alpha
    p /= p.sum()
    return rng.choice(pool_size, size=n, p=p)


def cnn_prob_fn(params):
    """f(images, targets) -> target-class probability (the paper's f)."""
    return partial(cnn.prob_fn, CNN_CONFIG, params)


def eval_batch(n: int = 8, key=None):
    """Confidently-classified eval images + their predicted labels."""
    key = key if key is not None else jax.random.PRNGKey(7)
    imgs, labels = synthetic_images(key, n)
    return imgs, labels


def accuracy(params, n=256) -> float:
    imgs, labels = synthetic_images(jax.random.PRNGKey(99), n, background_frac=0.3)
    pred = jnp.argmax(cnn.forward(CNN_CONFIG, params, imgs), -1)
    return float((pred == labels).mean())
