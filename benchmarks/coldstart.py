"""Cold-start + repeat-traffic gate (ISSUE 10) -> results/BENCH_coldstart.json.

Zipfian repeat traffic through the content-addressed attribution cache and a
save/restore cycle through the warm-start persistence, five claims gated:

  1. **hit bit-identity** — every cache hit replays attributions that are
     ``np.array_equal`` (and exact-equal delta / f_x / f_baseline) to a
     cache-disabled reference engine computing the same request fresh.
  2. **hit-path latency** — per S-bucket, the p50 single-request latency of
     a cache hit is <= ``HIT_RATIO_MAX`` of the warmed compute path: a hit
     is a key computation + dict copy, never a gradient step.
  3. **zero steady-state recompiles** — replaying the Zipf sample with the
     result cache enabled grows neither executable-cache misses nor result
     -cache misses.
  4. **warm restart** — ``save_warm_state`` then a FRESH engine +
     ``load_warm_state``: first explanation with zero compiles, and
     cold-start-to-first-explanation >= ``WARM_SPEEDUP_MIN``x faster than a
     fresh cold engine. The restore must come back ``restored=True`` (the
     native ``serialize_executable`` path on a same-process round-trip).
  5. **hop-zero** — with ``hop_zero=True``, fresh prompts landing in
     REPEAT buckets start at the δ-history quantile rung (mean adaptive
     hops strictly below the cold phase), while prompts in never-seen
     buckets keep traces (m_used / hops / delta / converged AND the
     attribution bytes) identical to a plain adaptive engine.

Ratchet (CI): against the committed ``BENCH_coldstart_baseline.json`` —
warm restart speedup must stay >= ``WARM_SPEEDUP_MIN`` and
``warm_to_first_s`` must not regress past ``RATCHET_SLACK``x the committed
time (checked only on a matching device kind; CI noise pads the slack).
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import RESULTS_DIR, prompt_pool, zipf_sample

HIT_RATIO_MAX = 0.05       # hit p50 <= 5% of warmed compute p50, per bucket
WARM_SPEEDUP_MIN = 5.0     # cold-to-first-explanation vs warm-restored
RATCHET_SLACK = 3.0        # warm_to_first_s regression bound vs baseline
BASELINE = os.path.join(RESULTS_DIR, "BENCH_coldstart_baseline.json")


def _mk_requests(prompts, target=3):
    from repro.serve import ExplainRequest

    return [ExplainRequest(tokens=p, target=target) for p in prompts]


def _engine(cfg, params, *, m, seq_buckets, **kw):
    from repro.serve import ExplainEngine

    return ExplainEngine(
        cfg, params, schedule="paper", m=m, n_int=4,
        seq_buckets=seq_buckets, **kw,
    )


def run(*, arch: str = "llama3-8b", smoke: bool = False, seed: int = 0) -> dict:
    from repro.configs import ARCHS, reduced
    from repro.models.registry import Model
    from repro.serve import load_warm_state, save_warm_state

    pool_n, draws, m = (6, 24, 4) if smoke else (16, 96, 8)
    seq_buckets = (8, 16)
    cfg = dataclasses.replace(reduced(ARCHS[arch]), compute_dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    pool = prompt_pool(rng, cfg.vocab_size, pool_n, lengths=(5, 6, 7, 12))
    idx = zipf_sample(rng, pool_n, draws)
    traffic = _mk_requests([pool[i] for i in idx])
    uniq = _mk_requests(pool)

    out = {
        "arch": arch, "smoke": smoke, "pool": pool_n, "draws": draws, "m": m,
        "device_kind": jax.devices()[0].device_kind, "gates": {},
    }
    failures: list[str] = []

    # -- gate 1+3: Zipf sweep, bit-identity vs a cache-disabled engine -------
    eng = _engine(cfg, params, m=m, seq_buckets=seq_buckets,
                  result_cache=64 << 20)
    ref = _engine(cfg, params, m=m, seq_buckets=seq_buckets)
    got = eng.explain(traffic)
    want = ref.explain(traffic)
    bit_ok = all(
        np.array_equal(g["token_scores"], w["token_scores"])
        and g["delta"] == w["delta"] and g["f_x"] == w["f_x"]
        and g["f_baseline"] == w["f_baseline"]
        for g, w in zip(got, want)
    )
    out["gates"]["hit_bit_identity"] = bit_ok
    if not bit_ok:
        failures.append("cache-hit attributions diverge from the fresh path")
    # the sweep already repeats inside one call batch? no — duplicate
    # requests in ONE batch are all computed (no intra-call dedup, the
    # bucket shapes must match the uncached engine); repeats across CALLS
    # hit. Replay the whole sample: every request must hit.
    exec_misses0, res_misses0 = eng.stats.misses, eng.stats.result_misses
    replay = eng.explain(traffic)
    recompiles = eng.stats.misses - exec_misses0
    res_misses = eng.stats.result_misses - res_misses0
    out["steady_state_recompiles"] = int(recompiles)
    out["replay_result_misses"] = int(res_misses)
    out["hit_rate"] = eng.stats.result_hit_rate
    out["result_bytes"] = eng.stats.result_bytes
    out["gates"]["zero_steady_state_recompiles"] = recompiles == 0
    out["gates"]["replay_all_hits"] = res_misses == 0
    if recompiles:
        failures.append(f"replay with result cache recompiled {recompiles}x")
    if res_misses:
        failures.append(f"replay missed the result cache {res_misses}x")
    if not all(
        np.array_equal(a["token_scores"], b["token_scores"])
        for a, b in zip(got, replay)
    ):
        failures.append("replayed hits are not bit-identical to round 1")
        out["gates"]["hit_bit_identity"] = False

    # -- gate 2: per-bucket hit-path p50 vs warmed compute p50 ---------------
    from repro.serve.batching import bucket_for

    per_bucket: dict[int, dict] = {}
    for req in uniq:
        s = bucket_for(len(req.tokens), seq_buckets)
        b = per_bucket.setdefault(s, {"hit_s": [], "compute_s": []})
        ref.explain([req])  # warmed single-request compute (executables hot)
        t0 = time.perf_counter()
        ref.explain([req])
        b["compute_s"].append(time.perf_counter() - t0)
        eng.explain([req])  # ensure cached (pool heads already are)
        t0 = time.perf_counter()
        eng.explain([req])
        b["hit_s"].append(time.perf_counter() - t0)
    hit_ok = True
    out["hit_latency"] = {}
    for s, b in sorted(per_bucket.items()):
        p50_hit = float(np.percentile(b["hit_s"], 50))
        p50_compute = float(np.percentile(b["compute_s"], 50))
        ratio = p50_hit / p50_compute
        out["hit_latency"][str(s)] = {
            "p50_hit_s": p50_hit, "p50_compute_s": p50_compute,
            "ratio": ratio,
        }
        print(f"coldstart S={s:<3d} p50 hit={1e6*p50_hit:7.1f}us "
              f"compute={1e3*p50_compute:7.2f}ms ratio={ratio:.4f}")
        if ratio > HIT_RATIO_MAX:
            hit_ok = False
            failures.append(
                f"S={s} hit p50 is {ratio:.3f} of compute (> {HIT_RATIO_MAX})"
            )
    out["gates"]["hit_latency"] = hit_ok

    # -- gate 4: warm-start persistence --------------------------------------
    # adaptive + hop_zero engine so the persisted state carries executables,
    # autotune-shaped knots AND the δ-history in one artifact. The source
    # serves TWO rounds before saving: round 1 builds the history, round 2
    # serves WITH it (elevated starting rungs and their hop shapes compile
    # here) — the saved executable set then covers exactly what a restored
    # engine replays, and round 2 is the apples-to-apples reference traffic.
    adaptive_kw = dict(adaptive=True, tol=1e-3, m_max=4 * m,
                       hop_zero=True, hop_zero_min=2, result_cache=64 << 20)
    warm_src = _engine(cfg, params, m=m, seq_buckets=seq_buckets, **adaptive_kw)
    warm_src.explain(traffic)
    round2_reqs = _mk_requests(pool, target=5)
    round2 = warm_src.explain(round2_reqs)
    # cold baseline: a FRESH engine serving the same round-2 traffic pays
    # construction + every compile before its first explanation
    t0 = time.perf_counter()
    cold = _engine(cfg, params, m=m, seq_buckets=seq_buckets, **adaptive_kw)
    cold.explain(round2_reqs)
    cold_to_first_s = time.perf_counter() - t0
    with tempfile.TemporaryDirectory() as td:
        state_dir = os.path.join(td, "warm")
        save_warm_state(warm_src, state_dir)
        t0 = time.perf_counter()
        warm = _engine(cfg, params, m=m, seq_buckets=seq_buckets, **adaptive_kw)
        rep = load_warm_state(warm, state_dir)
        first = warm.explain(round2_reqs)
        warm_to_first_s = time.perf_counter() - t0
    speedup = cold_to_first_s / warm_to_first_s
    out["warm"] = {
        "restored": rep.restored, "via": rep.via,
        "executables": rep.executables,
        "cold_to_first_s": cold_to_first_s,
        "warm_to_first_s": warm_to_first_s,
        "speedup": speedup, "warm_compiles": warm.stats.compiles,
    }
    print(f"coldstart cold_to_first={cold_to_first_s:.2f}s "
          f"warm_to_first={warm_to_first_s:.2f}s speedup={speedup:.1f}x "
          f"via={rep.via} compiles={warm.stats.compiles}")
    warm_ok = (
        rep.restored and warm.stats.compiles == 0
        and speedup >= WARM_SPEEDUP_MIN
    )
    out["gates"]["warm_restart"] = warm_ok
    if not warm_ok:
        failures.append(
            f"warm restart: restored={rep.restored} via={rep.via!r} "
            f"compiles={warm.stats.compiles} speedup={speedup:.1f}x "
            f"(need 0 compiles and >= {WARM_SPEEDUP_MIN}x)"
        )
    # identical restored history -> identical rung choices -> the restored
    # engine must produce the source's round-2 bytes exactly
    if not all(
        np.array_equal(a["token_scores"], b["token_scores"])
        and a.get("m_used") == b.get("m_used")
        and a.get("hops") == b.get("hops")
        for a, b in zip(first, round2)
    ):
        failures.append("warm-restored attributions diverge from the source")
        out["gates"]["warm_restart"] = False

    # -- gate 5: hop-zero reduces hops on repeat buckets, never-seen intact --
    hz = _engine(cfg, params, m=m, seq_buckets=(8, 16, 32), adaptive=True,
                 tol=1e-4, m_max=4 * m, hop_zero=True, hop_zero_min=2)
    cold_run = hz.explain(traffic, return_raw=True)
    hops_cold = float(np.mean([r["hops"] for r in cold_run]))
    fresh = _mk_requests(prompt_pool(rng, cfg.vocab_size, pool_n,
                                     lengths=(5, 6, 7, 12)))
    warm_run = hz.explain(fresh, return_raw=True)
    hops_warm = float(np.mean([r["hops"] for r in warm_run]))
    # never-seen bucket (S=32): traces + bytes identical to plain adaptive
    unseen = _mk_requests(prompt_pool(rng, cfg.vocab_size, 4, lengths=(20, 24)))
    hz_unseen = hz.explain(unseen, return_raw=True)
    plain = _engine(cfg, params, m=m, seq_buckets=(8, 16, 32), adaptive=True,
                    tol=1e-4, m_max=4 * m)
    plain_unseen = plain.explain(unseen, return_raw=True)
    traces_equal = all(
        a["m_used"] == b["m_used"] and a["hops"] == b["hops"]
        and a["delta"] == b["delta"] and a["converged"] == b["converged"]
        and np.array_equal(a["token_scores"], b["token_scores"])
        for a, b in zip(hz_unseen, plain_unseen)
    )
    out["hop_zero"] = {
        "mean_hops_cold": hops_cold, "mean_hops_repeat_bucket": hops_warm,
        "unseen_traces_equal": traces_equal,
        "history": {f"{s}:{meth}": len(h)
                    for (s, meth), h in hz._delta_hist.items()},
    }
    print(f"coldstart hop_zero mean_hops {hops_cold:.2f} -> {hops_warm:.2f} "
          f"(repeat buckets), unseen_traces_equal={traces_equal}")
    hz_ok = hops_warm < hops_cold and traces_equal
    out["gates"]["hop_zero"] = hz_ok
    if not hz_ok:
        failures.append(
            f"hop-zero: mean hops {hops_cold:.2f} -> {hops_warm:.2f}, "
            f"unseen_traces_equal={traces_equal}"
        )

    # -- ratchet vs the committed baseline ------------------------------------
    if os.path.exists(BASELINE):
        with open(BASELINE) as fh:
            base = json.load(fh)
        if base.get("device_kind") == out["device_kind"] and base.get(
            "smoke"
        ) == smoke:
            bound = RATCHET_SLACK * base["warm"]["warm_to_first_s"]
            ok = warm_to_first_s <= bound
            out["ratchet"] = {
                "baseline_warm_to_first_s": base["warm"]["warm_to_first_s"],
                "bound_s": bound, "ok": ok,
            }
            out["gates"]["ratchet"] = ok
            if not ok:
                failures.append(
                    f"warm_to_first {warm_to_first_s:.2f}s regressed past "
                    f"{bound:.2f}s ({RATCHET_SLACK}x committed baseline)"
                )
        else:
            out["ratchet"] = {"skipped": "device kind or size mismatch"}

    out["failures"] = failures
    out["pass"] = not failures
    print(f"coldstart gates={out['gates']} pass={out['pass']}")
    return out
