"""Paper Fig. 2(a) + Fig. 6(a,b): measured wall-clock latency.

Fig 2(a): latency vs m (normalized to m=1) — jitted end-to-end IG call.
Fig 6(a): latency at iso-delta_th per schedule, speedup vs uniform.
Fig 6(b): stage-1 (probe) latency overhead as % of total.

CPU wall-clock here; the step-count reductions are hardware-independent
(the paper's own argument), and §Roofline covers the TPU-side terms.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import cnn_prob_fn, eval_batch, load_or_train_cnn
from repro.core import ig, probes, schedule
from repro.core.api import Explainer


def _time(fn, *args, repeats: int = 5) -> float:
    fn(*args)  # warmup/compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(batch_size: int = 8, delta_grid=(0.02, 0.015, 0.01, 0.005), steps_to=None) -> dict:
    params = load_or_train_cnn()
    f = cnn_prob_fn(params)
    x, t = eval_batch(batch_size)
    bl = jnp.zeros_like(x)

    # ---- Fig 2(a): latency vs m (uniform schedule)
    lat_vs_m = {}
    for m in (1, 2, 4, 8, 16, 32, 64, 128, 256):
        sched = schedule.uniform(m)
        fn = jax.jit(lambda x, bl, t, s=sched: ig.attribute(f, x, bl, s, t).attributions)
        lat_vs_m[m] = _time(fn, x, bl, t)
    base = lat_vs_m[1]
    print("\n== Fig 2(a): latency vs m (normalized to m=1) ==")
    print("m,latency_s,normalized")
    for m, s in lat_vs_m.items():
        print(f"{m},{s:.4f},{s/base:.2f}")

    # ---- Fig 6(a): latency at iso-delta (needs steps_to from convergence)
    iso = {}
    if steps_to:
        print("\n== Fig 6(a): latency to meet delta_th (speedup vs uniform) ==")
        print("delta_th,method,m,latency_s,speedup")
        for th in delta_grid:
            u_m = steps_to["uniform"].get(th)
            if not u_m:
                continue
            u_fn = jax.jit(
                lambda x, bl, t, s=schedule.uniform(u_m): ig.attribute(f, x, bl, s, t).attributions
            )
            u_lat = _time(u_fn, x, bl, t)
            iso[th] = {"uniform": {"m": u_m, "latency_s": u_lat, "speedup": 1.0}}
            print(f"{th},uniform,{u_m},{u_lat:.4f},1.00")
            for name in steps_to:
                if name == "uniform" or steps_to[name].get(th) is None:
                    continue
                m = steps_to[name][th]
                n_int = int(name.split("_n")[-1]) if "_n" in name else 4
                method = name.split("_n")[0] if "_n" in name else name
                ex = Explainer(f, schedule=method, m=m, n_int=n_int)
                fn = jax.jit(lambda x, bl, t, e=ex: e.attribute(x, bl, t).attributions)
                lat = _time(fn, x, bl, t)
                iso[th][name] = {"m": m, "latency_s": lat, "speedup": u_lat / lat}
                print(f"{th},{name},{m},{lat:.4f},{u_lat/lat:.2f}")

    # ---- Fig 6(b): probe (stage-1) overhead fraction
    print("\n== Fig 6(b): stage-1 probe overhead (% of total latency) ==")
    print("n_int,m,probe_s,total_s,overhead_pct")
    overhead = {}
    for n_int in (2, 4, 8, 16):
        probe_fn = jax.jit(lambda x, bl, t, n=n_int: probes.boundary_values(f, x, bl, t, n))
        probe_lat = _time(probe_fn, x, bl, t)
        for m in (64, 256):
            ex = Explainer(f, schedule="paper", m=m, n_int=n_int)
            fn = jax.jit(lambda x, bl, t, e=ex: e.attribute(x, bl, t).attributions)
            total = _time(fn, x, bl, t)
            pct = 100.0 * probe_lat / total
            overhead[f"n{n_int}_m{m}"] = {"probe_s": probe_lat, "total_s": total, "pct": pct}
            print(f"{n_int},{m},{probe_lat:.4f},{total:.4f},{pct:.1f}")

    return {"latency_vs_m": {str(k): v for k, v in lat_vs_m.items()},
            "iso_delta": {str(k): v for k, v in iso.items()},
            "probe_overhead": overhead}


def mesh_run(
    mesh_spec: str = "2,1",
    *,
    arch: str = "llama3-8b",
    requests: int = 8,
    rounds: int = 3,
    m: int = 8,
    n_int: int = 4,
    seed: int = 0,
) -> dict:
    """Mesh scaling sweep (DESIGN.md §9) -> results/BENCH_mesh.json payload.

    Serves identical mixed-length traffic through a single-device engine and
    a (data=dp, model=tp) mesh-sharded engine and records, per engine:
    warmed round wall-clock, per-bucket latency, compiles. Gates (the "pass"
    bit): sharded attributions match single-device within tolerance, replayed
    traffic performs zero recompiles on BOTH engines, and the sharded engine
    never hit the replication fallback (mesh-divisible padding worked).
    CPU wall-clock is reported but not gated — on a forced-host-device CPU
    "mesh" the dp shards share one physical socket, so the interesting
    scaling number comes from real multi-chip runs of the same code path.
    """
    from repro.configs import ARCHS, reduced
    from repro.launch.explain import make_traffic
    from repro.launch.mesh import make_explain_mesh, parse_mesh_arg
    from repro.models.registry import Model
    from repro.serve import ExplainEngine

    dp, tp = parse_mesh_arg(mesh_spec)
    assert jax.device_count() >= dp * tp, (
        f"need {dp * tp} devices, have {jax.device_count()}; launch with "
        f"XLA_FLAGS=--xla_force_host_platform_device_count={dp * tp}"
    )
    cfg = reduced(ARCHS[arch])
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    mesh = make_explain_mesh(dp, tp)

    out = {"mesh": {"data": dp, "model": tp}, "devices": jax.device_count(),
           "arch": arch, "m": m, "requests": requests, "rounds": rounds}
    results = {}
    for label, eng_mesh in (("single", None), (f"dp{dp}_tp{tp}", mesh)):
        engine = ExplainEngine(cfg, params, m=m, n_int=n_int, mesh=eng_mesh)
        rng = np.random.default_rng(seed)  # same traffic for both engines
        walls, outs = [], []
        for _ in range(rounds):
            reqs = make_traffic(cfg, requests, 9, 48, rng)
            t0 = time.perf_counter()
            outs.append(engine.explain(reqs))
            walls.append(time.perf_counter() - t0)
        # replay the SAME warmed traffic (fresh rng, same seed): the
        # zero-recompile contract is about seen shapes — new random draws
        # could legitimately touch an unseen bucket and fail the gate
        warmed_misses = engine.stats.misses
        rng2 = np.random.default_rng(seed)
        for _ in range(rounds):
            engine.explain(make_traffic(cfg, requests, 9, 48, rng2))
        results[label] = {
            "wall_s": walls,
            "warmed_wall_s": walls[-1],
            "compiles": warmed_misses,
            "steady_state_recompiles": engine.stats.misses - warmed_misses,
            "mesh_fallbacks": engine.stats.mesh_fallbacks,
            "outs": outs,
        }
        print(f"mesh-bench [{label}] walls={[f'{w:.2f}' for w in walls]} "
              f"compiles={warmed_misses} fallbacks={engine.stats.mesh_fallbacks}")

    single, sharded = results["single"], results[f"dp{dp}_tp{tp}"]
    max_diff = 0.0
    for o1, o2 in zip(single.pop("outs"), sharded.pop("outs")):
        for r1, r2 in zip(o1, o2):
            max_diff = max(max_diff, float(np.max(np.abs(
                r1["token_scores"] - r2["token_scores"]))))
    ok = (
        max_diff < 5e-4
        and single["steady_state_recompiles"] == 0
        and sharded["steady_state_recompiles"] == 0
        and sharded["mesh_fallbacks"] == 0
    )
    out.update(engines=results, parity_max_abs_diff=max_diff,
               speedup=single["warmed_wall_s"] / max(sharded["warmed_wall_s"], 1e-9),
               **{"pass": ok})
    print(f"mesh-bench parity max|Δ|={max_diff:.2e} "
          f"speedup(warmed)={out['speedup']:.2f}x pass={ok}")
    return out


def main():
    run()


if __name__ == "__main__":
    main()
