"""Beyond-paper generality: NUIG on LM-family archs (embedding-space IG).

Setup notes that materially differ from the vision case (both discovered by
measurement; see EXPERIMENTS.md):

* baseline = PAD-token embedding, NOT zeros. RMSNorm backbones are scale-
  invariant in their first normalization, so f is (nearly) constant along a
  ray through the origin and zero-baseline IG cannot satisfy completeness —
  delta stays at |f(x)-f(0)| for every schedule. The pad-embedding baseline
  (standard in Captum-style LLM attribution) restores a well-behaved path.
* f = next-token PROBABILITY (the paper's metric), not log-prob — the
  saturating shape is what stage 1 probes for.

We report (a) the probability profile along the path (paper Fig 3 analogue),
(b) how concentrated the paper schedule's step allocation is, and (c) deltas
at iso-m. On CPU-scale trained-toy LMs the deltas sit at a noise floor that
masks iso-convergence gains (honest negative); the full quantitative win is
demonstrated on the vision benchmark, the paper's own domain.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core import ig, probes, schedule
from repro.core.baselines import pad_embedding
from repro.data import DataConfig, SyntheticLM
from repro.models.registry import Model
from repro.optim import AdamWConfig
from repro.train import TrainConfig, make_train_state, make_train_step

DEFAULT_ARCHS = ("llama3-8b", "qwen3-moe-30b-a3b", "mamba2-780m", "jamba-v0.1-52b")


def _train_reduced(cfg, steps: int = 40, seq: int = 64, batch: int = 8):
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=steps),
        microbatches=1,
        remat=False,
    )
    state = make_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    data = SyntheticLM(DataConfig(cfg.vocab_size, seq, batch, seed=0))
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, m = step(state, b)
    return state.params, float(m["loss"])


def run(arch_ids=DEFAULT_ARCHS, m: int = 32, n_int: int = 8, batch: int = 4, seq: int = 64) -> dict:
    out = {}
    print("\n== LM-family NUIG transfer (pad-embedding baseline, prob target) ==")
    for arch in arch_ids:
        cfg = reduced(ARCHS[arch])
        model = Model(cfg)
        params, loss = _train_reduced(cfg)
        data = SyntheticLM(DataConfig(cfg.vocab_size, seq, batch, seed=123))
        toks = jnp.asarray(data.batch_at(0)["tokens"])
        e = model.embed_inputs(params, {"tokens": toks})
        flog = model.target_logprob_fn(params)
        f = lambda xs, t: jnp.exp(flog(xs, t))  # noqa: E731 — paper's prob metric
        h, _ = model.forward_hidden(params, {"tokens": toks})
        t = jnp.argmax(model.logits(params, h[:, -1]), -1).astype(jnp.int32)
        bl = pad_embedding(params["embed"]["embedding"], e, pad_id=0)

        vals = probes.boundary_values(f, e, bl, t, n_int)
        profile = np.asarray(vals.mean(0))
        alloc = np.asarray(
            schedule.allocate_steps(schedule.normalized_deltas(vals), m).mean(0)
        )
        deltas = {
            "uniform": float(ig.attribute(f, e, bl, schedule.uniform(m), t).delta.mean()),
            "paper": float(ig.attribute(f, e, bl, schedule.paper(vals, m), t).delta.mean()),
            "warp": float(ig.attribute(f, e, bl, schedule.warp(vals, m), t).delta.mean()),
        }
        frange = float((f(e, t) - f(bl, t)).mean())
        # concentration: fraction of steps landing in the top-2 intervals
        conc = float(np.sort(alloc)[-2:].sum() / alloc.sum())
        out[arch] = {
            "train_loss": loss,
            "prob_profile": profile.tolist(),
            "alloc_top2_frac": conc,
            "f_range": frange,
            **deltas,
        }
        print(
            f"{arch}: loss={loss:.2f} f_range={frange:.3f} "
            f"profile={np.round(profile, 4).tolist()}"
        )
        print(
            f"  alloc={alloc.round(1).tolist()} (top-2 intervals take {conc*100:.0f}% of steps)  "
            f"delta: uniform={deltas['uniform']:.5f} paper={deltas['paper']:.5f} "
            f"warp={deltas['warp']:.5f}"
        )
    return out


def main():
    run()


if __name__ == "__main__":
    main()
