"""Paper Fig. 5(a,b) + Fig. 2(b): convergence delta vs steps, per schedule.

Fig 5(a): delta(m) for uniform / paper(n_int=2,4,8,16) / warp / gauss.
Fig 5(b): min steps to reach delta_th, + reduction factor vs uniform.
Also reproduces the paper's n_int>8 degradation observation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import cnn_prob_fn, eval_batch, load_or_train_cnn
from repro.core import ig, probes, schedule

M_GRID = (16, 24, 32, 48, 64, 96, 128, 192, 256, 384)
DELTA_GRID = (0.02, 0.015, 0.01, 0.005)


def method_schedules(f, x, bl, t):
    """method name -> (schedule builder taking m, probe_forward_count)."""
    out = {"uniform": (lambda m: schedule.uniform(m), 0)}
    for n_int in (2, 4, 8, 16):
        vals = probes.boundary_values(f, x, bl, t, n_int)
        out[f"paper_n{n_int}"] = (
            lambda m, v=vals: schedule.paper(v, m),
            n_int + 1,
        )
    vals8 = probes.boundary_values(f, x, bl, t, 8)
    out["warp_n8"] = (lambda m: schedule.warp(vals8, m), 9)
    out["gauss_n8"] = (lambda m: schedule.gauss(vals8, m), 9)
    return out


def run(batch_size: int = 8, m_grid=M_GRID, delta_grid=DELTA_GRID) -> dict:
    params = load_or_train_cnn()
    f = cnn_prob_fn(params)
    x, t = eval_batch(batch_size)
    bl = jnp.zeros_like(x)

    methods = method_schedules(f, x, bl, t)
    curves: dict[str, list] = {}
    for name, (build, _probe_cost) in methods.items():
        n_int = int(name.split("_n")[-1]) if "_n" in name else 0
        ds = []
        for m in m_grid:
            if m < n_int:  # paper allocation needs >= 1 step per interval
                ds.append(float("nan"))
                continue
            res = ig.attribute(f, x, bl, build(m), t)
            ds.append(float(res.delta.mean()))
        curves[name] = ds

    # Fig 5(b): min m meeting each threshold
    steps_to = {name: {} for name in methods}
    for name, ds in curves.items():
        for th in delta_grid:
            ok = [m for m, d in zip(m_grid, ds) if not np.isnan(d) and d <= th]
            steps_to[name][th] = min(ok) if ok else None

    print("\n== Fig 5(a): mean convergence delta vs total steps m ==")
    print("m," + ",".join(methods))
    for i, m in enumerate(m_grid):
        print(f"{m}," + ",".join(f"{curves[n][i]:.5f}" for n in methods))

    print("\n== Fig 5(b): steps to reach delta_th (x-fold reduction vs uniform) ==")
    print("delta_th," + ",".join(methods))
    for th in delta_grid:
        row = [str(th)]
        for n in methods:
            s = steps_to[n][th]
            if s is None:
                row.append("-")
            elif n == "uniform":
                row.append(f"{s}")
            else:
                u = steps_to["uniform"][th]
                row.append(f"{s} ({u/s:.1f}x)" if u and s else f"{s}")
        print(",".join(row))

    return {"m_grid": list(m_grid), "curves": curves, "steps_to_threshold": steps_to}


def main():
    run()


if __name__ == "__main__":
    main()
