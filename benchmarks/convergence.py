"""Paper Fig. 5(a,b) + Fig. 2(b): convergence delta vs steps, per schedule.

Fig 5(a): delta(m) for uniform / paper(n_int=2,4,8,16) / warp / gauss.
Fig 5(b): min steps to reach delta_th, + reduction factor vs uniform.
Also reproduces the paper's n_int>8 degradation observation.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import cnn_prob_fn, eval_batch, load_or_train_cnn
from repro.core import ig, probes, schedule
from repro.core.api import Explainer

M_GRID = (16, 24, 32, 48, 64, 96, 128, 192, 256, 384)
DELTA_GRID = (0.02, 0.015, 0.01, 0.005)


def method_schedules(f, x, bl, t):
    """method name -> (schedule builder taking m, probe_forward_count)."""
    out = {"uniform": (lambda m: schedule.uniform(m), 0)}
    for n_int in (2, 4, 8, 16):
        vals = probes.boundary_values(f, x, bl, t, n_int)
        out[f"paper_n{n_int}"] = (
            lambda m, v=vals: schedule.paper(v, m),
            n_int + 1,
        )
    vals8 = probes.boundary_values(f, x, bl, t, 8)
    out["warp_n8"] = (lambda m: schedule.warp(vals8, m), 9)
    out["gauss_n8"] = (lambda m: schedule.gauss(vals8, m), 9)
    return out


def run(batch_size: int = 8, m_grid=M_GRID, delta_grid=DELTA_GRID) -> dict:
    params = load_or_train_cnn()
    f = cnn_prob_fn(params)
    x, t = eval_batch(batch_size)
    bl = jnp.zeros_like(x)

    methods = method_schedules(f, x, bl, t)
    curves: dict[str, list] = {}
    for name, (build, _probe_cost) in methods.items():
        n_int = int(name.split("_n")[-1]) if "_n" in name else 0
        ds = []
        for m in m_grid:
            if m < n_int:  # paper allocation needs >= 1 step per interval
                ds.append(float("nan"))
                continue
            res = ig.attribute(f, x, bl, build(m), t)
            ds.append(float(res.delta.mean()))
        curves[name] = ds

    # Fig 5(b): min m meeting each threshold
    steps_to = {name: {} for name in methods}
    for name, ds in curves.items():
        for th in delta_grid:
            ok = [m for m, d in zip(m_grid, ds) if not np.isnan(d) and d <= th]
            steps_to[name][th] = min(ok) if ok else None

    print("\n== Fig 5(a): mean convergence delta vs total steps m ==")
    print("m," + ",".join(methods))
    for i, m in enumerate(m_grid):
        print(f"{m}," + ",".join(f"{curves[n][i]:.5f}" for n in methods))

    print("\n== Fig 5(b): steps to reach delta_th (x-fold reduction vs uniform) ==")
    print("delta_th," + ",".join(methods))
    for th in delta_grid:
        row = [str(th)]
        for n in methods:
            s = steps_to[n][th]
            if s is None:
                row.append("-")
            elif n == "uniform":
                row.append(f"{s}")
            else:
                u = steps_to["uniform"][th]
                row.append(f"{s} ({u/s:.1f}x)" if u and s else f"{s}")
        print(",".join(row))

    return {"m_grid": list(m_grid), "curves": curves, "steps_to_threshold": steps_to}


# ---------------------------------------------- adaptive iso-convergence


def adaptive_run(
    batch_size: int = 8,
    *,
    tol: float = 1e-2,
    m0: int = 64,
    m_max: int = 256,
    n_int: int = 8,
    methods=("paper", "warp"),
    smoke: bool = False,
) -> dict:
    """Steps-to-tolerance: δ-feedback adaptive ladder vs fixed-m uniform.

    Fixed-m baseline: the smallest pow-2 rung m where EVERY example meets the
    per-example relative tolerance δ ≤ tol·|f(x) − f(x′)| costs B·m gradient
    steps (the whole batch pays the worst example's budget — that is the
    over-provisioning the adaptive path removes). Adaptive: each example pays
    the rung it converged at (``info["total_steps"]`` = Σ m_used).

    Each adaptive config runs twice against one executable cache; the second
    (measured) run must report zero compiles — the CI gate for "ladder hops
    only ever hit warmed executables". Returns a dict for BENCH_adaptive.json
    with ``pass`` aggregating the two assertions.
    """
    if smoke:
        batch_size = min(batch_size, 4)
    params = load_or_train_cnn()
    f = cnn_prob_fn(params)
    x, t = eval_batch(batch_size)
    bl = jnp.zeros_like(x)
    B = int(x.shape[0])
    ladder = schedule.m_ladder(m0, m_max)

    # -- fixed-m uniform baseline: smallest rung meeting tol for all
    # examples. Searched from far below the adaptive base rung so the
    # baseline is never handicapped by the adaptive ladder's starting point.
    uniform_m = None
    uniform_deltas = {}
    for m in schedule.m_ladder(min(8, m0), m_max):
        res = ig.attribute(f, x, bl, schedule.uniform(m), t)
        rel_ok = np.asarray(res.delta) <= tol * np.abs(
            np.asarray(res.f_x) - np.asarray(res.f_baseline)
        )
        uniform_deltas[m] = float(res.delta.mean())
        if bool(rel_ok.all()):
            uniform_m = m
            break
    uniform_steps = B * uniform_m if uniform_m else None

    out = {
        "tol": tol,
        "m0": m0,
        "m_max": m_max,
        "batch": B,
        "ladder": list(ladder),
        "uniform_fixed_m": uniform_m,
        "uniform_steps": uniform_steps,
        "uniform_mean_delta_by_m": uniform_deltas,
        "methods": {},
    }
    print(f"\n== adaptive iso-convergence (tol={tol} rel, ladder {ladder}) ==")
    print(f"uniform fixed-m baseline: m={uniform_m} -> {uniform_steps} grad steps")

    ok = uniform_steps is not None
    for method in methods:
        ex = Explainer(f, schedule=method, m=m0, n_int=n_int)
        cache: dict = {}
        ex.attribute_adaptive(x, bl, t, tol=tol, m_max=m_max, cache=cache)  # warm
        t0 = time.perf_counter()
        res, info = ex.attribute_adaptive(x, bl, t, tol=tol, m_max=m_max, cache=cache)
        wall = time.perf_counter() - t0
        entry = {
            "total_steps": info["total_steps"],
            "probe_forwards": info["probe_forwards"],
            "m_used": [int(v) for v in info["m_used"]],
            "hops": [int(v) for v in info["hops"]],
            "converged": [bool(v) for v in info["converged"]],
            "mean_delta": float(np.mean(info["delta"])),
            "warmed_compiles": info["compiles"],  # second run: must be 0
            "wall_s": wall,
            "speedup_vs_uniform": (
                uniform_steps / info["total_steps"] if uniform_steps else None
            ),
        }
        out["methods"][method] = entry
        speedup = (
            f"{entry['speedup_vs_uniform']:.2f}x" if entry["speedup_vs_uniform"] else "-"
        )
        print(
            f"adaptive[{method}]: steps={info['total_steps']} "
            f"(+{info['probe_forwards']} probe fwds) m_used={entry['m_used']} "
            f"converged={sum(entry['converged'])}/{B} speedup={speedup}"
        )
        ok = ok and all(entry["converged"])
        ok = ok and entry["warmed_compiles"] == 0
        ok = ok and (uniform_steps is None or info["total_steps"] < uniform_steps)

    out["pass"] = bool(ok)
    print(f"adaptive gate: {'PASS' if ok else 'FAIL'}")
    return out


def main():
    run()


if __name__ == "__main__":
    main()
