"""Paper Fig. 3(b,c): information content along the IG path.

(b) target-class probability f(x(α)) vs α — shows the sharp rise inside a
    small interval (the paper's core observation);
(c) per-step contribution to the attribution sum, |Σ_i g_i(α)·(x-x')_i| vs α
    — shows the gradient mass concentrates in the same interval.

Also reports the paper's "at α=0.25 the probability reaches >90% of its
final value" style statistic on our trained classifier.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import cnn_prob_fn, eval_batch, load_or_train_cnn
from repro.core.paths import interpolate


def run(batch_size: int = 8, n_points: int = 41) -> dict:
    params = load_or_train_cnn()
    f = cnn_prob_fn(params)
    x, t = eval_batch(batch_size)
    bl = jnp.zeros_like(x)
    alphas = jnp.linspace(0.0, 1.0, n_points)

    xi = interpolate(x, bl, alphas)  # (B, K, H, W, C)
    B, K = xi.shape[:2]
    flat = xi.reshape((B * K,) + x.shape[1:])
    tt = jnp.repeat(t, K)
    probs = f(flat, tt).reshape(B, K)

    grad_f = jax.grad(lambda xs, tg: f(xs, tg).sum())
    g = grad_f(flat, tt).reshape(xi.shape)
    contrib = jnp.abs(
        jnp.sum(g * (x - bl)[:, None], axis=tuple(range(2, x.ndim + 1)))
    )  # (B, K)

    p = np.asarray(probs.mean(0))
    c = np.asarray(contrib.mean(0))
    print("\n== Fig 3(b,c): probability and gradient contribution along the path ==")
    print("alpha,prob,contribution")
    for i in range(0, n_points, 2):
        print(f"{float(alphas[i]):.3f},{p[i]:.4f},{c[i]:.4f}")

    # the paper's alpha=0.25 statistic
    final = p[-1]
    k25 = int(round(0.25 * (n_points - 1)))
    frac25 = p[k25] / final if final > 0 else float("nan")
    # where does prob cross 90% of final?
    cross = next((float(alphas[i]) for i in range(n_points) if p[i] >= 0.9 * final), 1.0)
    print(f"\nprob(0.25)/prob(1.0) = {frac25:.3f}   alpha at 90% of final = {cross:.3f}")

    # gradient mass concentration: smallest alpha-interval holding 80% of mass
    total = c.sum()
    order = np.argsort(-c)
    cum = np.cumsum(c[order])
    k80 = int(np.searchsorted(cum, 0.8 * total)) + 1
    frac_path = k80 / n_points
    print(f"80% of gradient mass lies in {100*frac_path:.0f}% of the path")

    return {
        "alphas": [float(a) for a in alphas],
        "prob_mean": p.tolist(),
        "contrib_mean": c.tolist(),
        "prob_frac_at_025": float(frac25),
        "alpha_at_90pct": float(cross),
        "mass80_path_frac": float(frac_path),
    }


def main():
    run()


if __name__ == "__main__":
    main()
