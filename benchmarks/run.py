"""Benchmark harness entry point: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Order:
  pathinfo     — Fig 3(b,c)  information content along the path
  convergence  — Fig 5(a,b) + Fig 2(b)  delta vs m; steps to delta_th
  latency      — Fig 2(a) + Fig 6(a,b)  wall-clock; iso-delta speedup; overhead
  quality      — beyond-paper: method-zoo insertion/deletion AUC + latency
                 per method × schedule -> results/BENCH_quality.json
  hotpath      — beyond-paper: fused-vs-materializing stage 2 bytes/latency
                 + adaptive trace parity -> results/BENCH_hotpath.json
  attention    — beyond-paper (--attention): flash custom-VJP vs
                 materializing attention on LM + ViT traffic
                 -> results/BENCH_attention.json
  mixed        — beyond-paper (--mixed): unified generate+explain serving
                 (donated-endpoint bit-identity, zero-recompile replay,
                 hop preemption, SLO under stragglers)
                 -> results/BENCH_mixed.json
  lm_convergence — beyond-paper: NUIG on the assigned LM families
  roofline     — §Roofline table from the dry-run artifacts

Aggregated JSON lands in results/benchmarks.json; every targeted sweep
(--adaptive/--quality/--mesh/--hotpath) also appends a one-line summary
record to results/BENCH_trajectory.jsonl so the perf trajectory tracks ALL
benchmark axes across PRs, not just tools/perf_iterate.py runs.
"""
from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks import (
    attention,
    convergence,
    hotpath,
    latency,
    lm_convergence,
    pathinfo,
    quality,
    roofline_bench,
)
from benchmarks.common import RESULTS_DIR, accuracy, load_or_train_cnn

TRAJECTORY = os.path.join(RESULTS_DIR, "BENCH_trajectory.jsonl")


def _write(name: str, payload: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def _trajectory(kind: str, summary: dict) -> None:
    """Append one summary record per sweep to the perf trajectory."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    rec = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S"), "kind": kind, **summary}
    with open(TRAJECTORY, "a") as fh:
        fh.write(json.dumps(rec, default=str) + "\n")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller grids (CI)")
    ap.add_argument(
        "--adaptive",
        action="store_true",
        help="adaptive iso-convergence bench only -> results/BENCH_adaptive.json",
    )
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny adaptive gate for CI: exit 1 if adaptive loses to fixed-m uniform",
    )
    ap.add_argument(
        "--quality",
        action="store_true",
        help="method-zoo AUC/latency bench only -> results/BENCH_quality.json",
    )
    ap.add_argument(
        "--mesh",
        default="",
        metavar="DP,TP",
        help="mesh scaling sweep only (e.g. 2,1) -> results/BENCH_mesh.json; "
        "forces DP*TP virtual host devices if fewer exist",
    )
    ap.add_argument(
        "--hotpath",
        action="store_true",
        help="fused stage-2 bandwidth gate only -> results/BENCH_hotpath.json "
        "(with --smoke: the CI-sized config)",
    )
    ap.add_argument(
        "--mixed",
        action="store_true",
        help="mixed-serving gate only (unified generate+explain scheduler: "
        "donated-endpoint bit-identity, zero-recompile replay, hop "
        "preemption, decode SLO under injected stragglers) "
        "-> results/BENCH_mixed.json (with --smoke: the CI-sized config)",
    )
    ap.add_argument(
        "--attention",
        action="store_true",
        help="attention hot-path gate only (flash custom-VJP vs materializing "
        "on the LM + ViT workloads) -> results/BENCH_attention.json "
        "(with --smoke: the CI-sized config)",
    )
    ap.add_argument(
        "--coldstart",
        action="store_true",
        help="cold-start + repeat-traffic gate only (content-addressed "
        "result cache bit-identity and hit latency, warm-start restore "
        "with zero compiles, hop-zero rung elevation) "
        "-> results/BENCH_coldstart.json (with --smoke: the CI-sized config)",
    )
    args = ap.parse_args()

    if args.mesh:
        # must win the race with JAX backend init (benchmarks only import
        # jax at module load; nothing has touched a device yet)
        from repro.launch.mesh import ensure_host_devices, parse_mesh_arg

        dp, tp = parse_mesh_arg(args.mesh)
        ensure_host_devices(dp * tp)
        out = latency.mesh_run(args.mesh, requests=8, rounds=3)
        path = _write("BENCH_mesh.json", out)
        _trajectory("mesh", {
            "mesh": out["mesh"], "speedup": out["speedup"],
            "parity_max_abs_diff": out["parity_max_abs_diff"],
            "pass": out["pass"],
        })
        print(f"# mesh bench -> {path}")
        return 0 if out["pass"] else 1

    if args.hotpath:
        out = hotpath.run(smoke=args.smoke)
        path = _write("BENCH_hotpath.json", out)
        _trajectory("hotpath", {
            "latency_ratio": {
                k: v["latency_ratio"] for k, v in out["methods"].items()
            },
            "traces_equal": all(
                v["traces_equal"] for v in out["methods"].values()
            ),
            "autotune_recompiles": out["autotune"]["steady_state_recompiles"],
            "pass": out["pass"],
        })
        print(f"# hotpath bench -> {path}")
        return 0 if out["pass"] else 1

    if args.mixed:
        from benchmarks import mixed_serving

        out = mixed_serving.run(smoke=args.smoke)
        path = _write("BENCH_mixed.json", out)
        _trajectory("mixed", {
            "smoke": args.smoke,
            "gates": out["gates"],
            "steady_state_recompiles": out["steady_state_recompiles"],
            "p99_decode_only_s": out["slo"]["p99_decode_only_s"],
            "p99_mixed_straggler_s": out["slo"]["p99_mixed_straggler_s"],
            "pass": out["pass"],
        })
        print(f"# mixed-serving bench -> {path}")
        return 0 if out["pass"] else 1

    if args.coldstart:
        from benchmarks import coldstart

        out = coldstart.run(smoke=args.smoke)
        path = _write("BENCH_coldstart.json", out)
        _trajectory("coldstart", {
            "smoke": args.smoke,
            "gates": out["gates"],
            "hit_rate": out["hit_rate"],
            "steady_state_recompiles": out["steady_state_recompiles"],
            "warm_speedup": out["warm"]["speedup"],
            "warm_to_first_s": out["warm"]["warm_to_first_s"],
            "pass": out["pass"],
        })
        print(f"# coldstart bench -> {path}")
        return 0 if out["pass"] else 1

    if args.attention:
        out = attention.run(smoke=args.smoke)
        path = _write("BENCH_attention.json", out)
        _trajectory("attention", {
            "latency_ratio": {
                k: v["latency_ratio"] for k, v in out["workloads"].items()
            },
            "traces_equal": all(
                mv["traces_equal"]
                for wv in out["workloads"].values()
                for mv in wv["methods"].values()
            ),
            "autotune_recompiles": {
                k: v["autotune"]["steady_state_recompiles"]
                for k, v in out["workloads"].items()
            },
            "pass": out["pass"],
        })
        print(f"# attention bench -> {path}")
        return 0 if out["pass"] else 1

    # --quality must be checked BEFORE the bare-smoke adaptive gate: with
    # both flags set the caller wants the CI-sized bake-off, not adaptive
    if args.quality:
        out = quality.run(smoke=args.smoke)
        path = _write("BENCH_quality.json", out)
        _trajectory("quality", {
            "smoke": args.smoke,
            "cells": len(out.get("cells", {})),
            "bakeoff_workloads": len(out.get("bakeoff", {})),
            "forward_replay_recompiles": out.get("forward_replay_recompiles"),
            "pass": out["pass"],
        })
        print(f"# quality bench -> {path}")
        return 0 if out["pass"] else 1

    if args.adaptive or args.smoke:
        out = convergence.adaptive_run(
            batch_size=4 if args.smoke else 8, smoke=args.smoke
        )
        path = _write("BENCH_adaptive.json", out)
        _trajectory("adaptive", {
            "smoke": args.smoke,
            "speedups": {
                k: v.get("speedup_vs_uniform")
                for k, v in out.get("methods", {}).items()
            },
            "pass": out["pass"],
        })
        print(f"# adaptive bench -> {path}")
        return 0 if out["pass"] else 1

    t0 = time.time()
    params = load_or_train_cnn()
    acc = accuracy(params)
    print(f"# bench CNN accuracy: {acc:.3f}")
    assert acc > 0.8, "benchmark classifier must be confident (paper Fig 3 regime)"

    out = {"cnn_accuracy": acc}
    out["pathinfo"] = pathinfo.run(batch_size=4 if args.fast else 8)
    m_grid = (8, 16, 32, 64, 128) if args.fast else convergence.M_GRID
    conv = convergence.run(batch_size=4 if args.fast else 8, m_grid=m_grid)
    out["convergence"] = conv
    out["latency"] = latency.run(
        batch_size=4 if args.fast else 8, steps_to=conv["steps_to_threshold"]
    )
    out["quality"] = quality.run(batch_size=4 if args.fast else 8)
    _write("BENCH_quality.json", out["quality"])
    # hotpath always runs the smoke config inside the full sweep: the full
    # fused-vs-unfused grid is the targeted --hotpath run's job
    out["hotpath"] = hotpath.run(smoke=True)
    _write("BENCH_hotpath.json", out["hotpath"])
    _trajectory("hotpath", {"smoke": True, "pass": out["hotpath"]["pass"]})
    out["lm_convergence"] = lm_convergence.run(
        arch_ids=("llama3-8b",) if args.fast else lm_convergence.DEFAULT_ARCHS,
        m=16 if args.fast else 32,
    )
    out["roofline_pod16x16"] = roofline_bench.run("pod16x16")
    out["roofline_pod2x16x16"] = roofline_bench.run("pod2x16x16")

    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "benchmarks.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1, default=str)
    print(f"\n# benchmarks done in {time.time()-t0:.0f}s -> {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
