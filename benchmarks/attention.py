"""Attention-model hot-path benchmark -> results/BENCH_attention.json.

Serves the SAME mixed traffic through two ExplainEngines that differ only in
``attn``: materializing (``attn="auto"`` — XLA attention, whose backward
re-reads the (B·K, H, S, S) probability tensor saved by the forward) vs
flash (``attn="flash"`` — the Pallas custom-VJP kernel, whose backward
recomputes probabilities blockwise from O(S·D) row residuals). Two workloads
ride the sweep: the reduced llama3-8b token LM and the TRAINED reduced ViT
(patch-feature requests through the same bucketed engine). Gates:

  1. **bytes** — flash ``cost_analysis`` bytes accessed strictly below the
     materializing path at every bucket past the analytic crossover
     S > D+2 (the VJP memory contract, docs/attention.md: flash re-reads
     S·(D+2) residual rows where materializing re-reads S² probabilities —
     below the crossover the contract itself predicts no win, so those
     buckets gate no-worse within ``SMALL_BUCKET_SLACK``);
  2. **parity** — fixed-m attribution scores agree within float32 tolerance;
  3. **traces** — δ-adaptive escalation (``m_used``/``hops``/``converged``)
     is IDENTICAL materializing vs flash, for every method in the zoo;
  4. **autotune** — the flash engine tunes (chunk, attn_block_q/k) per
     bucket (``serve.autotune`` with ``attn_block_grid``) and replays the
     traffic with ZERO steady-state recompiles;
  5. **ratchet** — flash bytes per bucket may not regress beyond 2% vs the
     committed results/BENCH_attention_baseline.json.

Latency is recorded but NOT gated (``latency_gated: false``): on a CPU host
the Pallas kernel runs in interpret mode — a jax-level emulation 2-4x
slower than XLA attention — so the wall-clock claim belongs to compiled
backends; the bytes/parity/trace claims are what a CPU CI host can hold.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import numpy as np

from benchmarks.common import (
    RESULTS_DIR,
    load_or_train_vit,
    synthetic_images,
    vit_accuracy,
)
from benchmarks.hotpath import _warmed_wall
from repro.core.methods import METHODS

BASELINE = os.path.join(RESULTS_DIR, "BENCH_attention_baseline.json")
BYTES_REGRESSION_SLACK = 1.02
# buckets below the S > D+2 analytic crossover (where even the contract
# predicts no flash bytes win): gate no-worse within this multiple
SMALL_BUCKET_SLACK = 1.02
# fixed-m score parity flash vs materializing: same f32 program modulo the
# attention contraction order; observed max-abs diffs are <1e-4
PARITY_TOL = 1e-3
# (attn_block_q, attn_block_k) sweep for the flash autotune leg; (0, 0) is
# the model config's defaults, the others re-tile the custom-VJP kernels
ATTN_BLOCK_GRID = ((0, 0), (32, 32), (64, 64))


def _attn_layers(cfg) -> int:
    specs = getattr(cfg, "layer_specs", None)
    if specs is None:  # VitConfig: every layer is an attention block
        return int(cfg.num_layers)
    return sum(1 for s in specs if s.mixer in ("attn", "local"))


def analytic_attn_bwd_bytes(cfg, bucket: tuple[int, int]) -> dict:
    """The memory contract the bytes gate measures, in closed form: the
    materializing backward re-reads the f32 probability tensor
    (L·B·H·Sq·Sk·4 bytes), the flash backward re-reads only the per-row
    residuals o/lse/delta (L·B·H·Sq·(D+2)·4) and recomputes P blockwise."""
    B, S = bucket
    L, H, D = _attn_layers(cfg), cfg.num_heads, cfg.resolved_head_dim
    return {
        "materializing": float(4 * L * B * H * S * S),
        "flash": float(4 * L * B * H * S * (D + 2)),
    }


def _lm_workload(requests: int, seed: int):
    from repro.configs import ARCHS, reduced
    from repro.launch.explain import make_traffic
    from repro.models.registry import model_for

    cfg = dataclasses.replace(reduced(ARCHS["llama3-8b"]), compute_dtype="float32")
    params = model_for(cfg).init(jax.random.PRNGKey(seed))
    reqs = make_traffic(cfg, requests, 9, 28, np.random.default_rng(seed))
    return cfg, params, reqs, {}


def _vit_workload(requests: int, seed: int):
    from repro.models import vit
    from repro.serve import ExplainRequest

    cfg, params = load_or_train_vit()
    imgs, labels = synthetic_images(jax.random.PRNGKey(seed + 1), requests, cfg)
    feats = np.asarray(vit.patchify(cfg, imgs), np.float32)
    reqs = [
        ExplainRequest(
            tokens=np.arange(cfg.num_patches, dtype=np.int32),
            target=int(t),
            features=f,
        )
        for f, t in zip(feats, labels)
    ]
    return cfg, params, reqs, {"seq_buckets": (cfg.num_patches,)}


def run(
    *,
    requests: int = 6,
    m: int = 8,
    n_int: int = 4,
    tol: float = 1e-2,
    rounds: int = 3,
    smoke: bool = False,
    seed: int = 0,
) -> dict:
    from repro.serve import ExplainEngine, autotune_engine

    if smoke:
        requests, m, rounds = 6, 8, 3
    out = {
        "m": m, "n_int": n_int, "requests": requests, "rounds": rounds,
        "tol": tol, "device_kind": jax.devices()[0].device_kind,
        "attn_block_grid": [list(p) for p in ATTN_BLOCK_GRID],
        "workloads": {},
    }
    failures: list[str] = []

    for wname, make in (("llama3-8b", _lm_workload), ("vit_s16", _vit_workload)):
        cfg, params, reqs, ekw = make(requests, seed)
        wrow: dict = {"buckets": {}, "methods": {}}
        if wname == "vit_s16":
            wrow["accuracy"] = vit_accuracy(params)

        # -- fixed-m fused engines: bytes / latency / score parity ----------
        engines: dict = {}
        scores: dict = {}
        walls: dict = {}
        for label, attn in (("materializing", "auto"), ("flash", "flash")):
            eng = ExplainEngine(
                cfg, params, m=m, n_int=n_int, fused=True, attn=attn, **ekw
            )
            res = eng.explain(reqs)
            scores[label] = [np.asarray(r["token_scores"], np.float32) for r in res]
            walls[label] = _warmed_wall(eng, reqs, rounds)
            engines[label] = eng
        parity = max(
            float(np.max(np.abs(a - b)))
            for a, b in zip(scores["materializing"], scores["flash"])
        )
        wrow["score_parity"] = {"max_abs_diff": parity, "tol": PARITY_TOL}
        if parity > PARITY_TOL:
            failures.append(
                f"{wname}: flash scores diverge from materializing by {parity}"
            )

        for b in sorted(engines["materializing"].stats.buckets):
            name = f"B{b[0]}xS{b[1]}"
            brow: dict = {}
            for label in ("materializing", "flash"):
                bs = engines[label].stats.buckets[b]
                brow[label] = {
                    "bytes_accessed": bs.bytes_accessed,
                    "peak_bytes": bs.peak_bytes,
                    "mean_latency_ms": 1e3 * bs.mean_latency_s,
                }
            brow["analytic_attn_bwd_bytes"] = analytic_attn_bwd_bytes(cfg, b)
            wrow["buckets"][name] = brow
            bm = brow["materializing"]["bytes_accessed"]
            bf = brow["flash"]["bytes_accessed"]
            ana = brow["analytic_attn_bwd_bytes"]
            if ana["flash"] < ana["materializing"]:
                # past the crossover: the kernel contract predicts a win
                if not bf < bm:
                    failures.append(
                        f"{wname}/{name}: flash bytes {bf} !< materializing {bm}"
                    )
            elif bf > SMALL_BUCKET_SLACK * bm:
                failures.append(
                    f"{wname}/{name}: flash bytes {bf} > "
                    f"{SMALL_BUCKET_SLACK}x materializing {bm} below crossover"
                )
        wrow["warmed_wall_s"] = dict(walls)
        wrow["latency_ratio"] = walls["flash"] / walls["materializing"]

        # -- adaptive trace parity per method -------------------------------
        for method in sorted(
            n for n in METHODS if not METHODS[n].forward_only
        ):
            traces: dict = {}
            for label, attn in (("materializing", "auto"), ("flash", "flash")):
                eng = ExplainEngine(
                    cfg, params, method=method, m=m, n_int=n_int,
                    adaptive=True, tol=tol, m_max=4 * m, fused=True,
                    attn=attn, **ekw,
                )
                res = eng.explain(reqs)
                traces[label] = [
                    (r["m_used"], r["hops"], r["converged"]) for r in res
                ]
            eq = traces["materializing"] == traces["flash"]
            wrow["methods"][method] = {
                "traces_equal": eq,
                "traces": {
                    k: [list(map(int, t[:2])) + [bool(t[2])] for t in v]
                    for k, v in traces.items()
                },
            }
            if not eq:
                failures.append(f"{wname}/{method}: adaptive traces diverge {traces}")
            print(f"attention [{wname}/{method:13s}] traces_equal={eq}")

        # -- flash autotune incl. attention tilings + zero-recompile replay -
        base_eng = ExplainEngine(
            cfg, params, m=m, n_int=n_int, fused=True, attn="flash", **ekw
        )
        tune_report = autotune_engine(
            base_eng, reqs, rounds=rounds, results_dir=RESULTS_DIR,
            attn_block_grid=ATTN_BLOCK_GRID,
        )
        tuned = ExplainEngine(
            cfg, params, m=m, n_int=n_int, fused=True, attn="flash",
            autotune=True, autotune_dir=RESULTS_DIR, **ekw,
        )
        tuned_wall = _warmed_wall(tuned, reqs, rounds)
        warmed_misses = tuned.stats.misses
        tuned.explain(reqs)
        recompiles = tuned.stats.misses - warmed_misses
        wrow["autotune"] = {
            "winners": {k: v["winner"] for k, v in tune_report["buckets"].items()},
            "tuned_warmed_wall_s": tuned_wall,
            "steady_state_recompiles": recompiles,
        }
        if recompiles:
            failures.append(f"{wname}: autotuned replay recompiled {recompiles}x")
        out["workloads"][wname] = wrow
        print(
            f"attention [{wname}] latency flash/materializing="
            f"{wrow['latency_ratio']:.2f} parity={parity:.2e}"
        )

    total_m = sum(w["warmed_wall_s"]["materializing"] for w in out["workloads"].values())
    total_f = sum(w["warmed_wall_s"]["flash"] for w in out["workloads"].values())
    out["total_latency_ratio"] = total_f / total_m
    out["latency_gated"] = False  # interpret-mode walls: recorded, not gated

    # -- flash-bytes ratchet vs the committed baseline ----------------------
    if os.path.exists(BASELINE):
        with open(BASELINE) as fh:
            base = json.load(fh)
        for wname, wrow in out["workloads"].items():
            for bname, cur in wrow["buckets"].items():
                prev = (
                    base.get("workloads", {}).get(wname, {})
                    .get("buckets", {}).get(bname)
                )
                if prev and cur["flash"]["bytes_accessed"] > (
                    BYTES_REGRESSION_SLACK * prev["flash"]["bytes_accessed"]
                ):
                    failures.append(
                        f"{wname}/{bname}: flash bytes "
                        f"{cur['flash']['bytes_accessed']} regressed vs "
                        f"baseline {prev['flash']['bytes_accessed']}"
                    )
        out["baseline_checked"] = True
    else:
        out["baseline_checked"] = False

    out["failures"] = failures
    out["pass"] = not failures
    print(
        f"attention pass={out['pass']}"
        + (f" failures={failures}" if failures else "")
    )
    return out


def main():
    run(smoke=True)


if __name__ == "__main__":
    main()
