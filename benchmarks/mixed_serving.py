"""Mixed-serving gate (ISSUE 8) -> results/BENCH_mixed.json.

One ``MixedScheduler`` serves generate AND explain traffic over one
``ExplainEngine`` and four claims are gated:

  1. **bit-identity** — a generate request with ``explain=True`` attributes
     its prompt toward the first emitted token by donating the decode
     prefill's chosen-token log-prob as the stage-1 endpoint ``f(x)``. At
     ``compute_dtype=float32`` the resulting attribution must be BITWISE
     equal (``np.array_equal`` on token_scores, exact-equal delta / f_x /
     f_baseline and identical ``m_used``/``hops``/``converged`` traces) to
     the standalone ``ExplainEngine.explain`` path that re-runs the probe
     forward itself.
  2. **zero steady-state recompiles** — replaying the identical mixed
     workload after warmup must not grow ``engine.stats.misses``. Decode
     executables (prefill / chunk) and explain executables (start / hop)
     are ONE combined set: mixed traffic reuses the hop executables that
     standalone explain traffic warmed, and vice versa.
  3. **δ-aware preemption** — with adaptive escalation hops queued, a newly
     submitted interactive generate request dispatches AHEAD of them
     (``engine.stats.preempted`` > 0) and still completes.
  4. **SLO under stragglers** — with injected stragglers (and one poisoned
     request) on the explain path, interactive decode p99 stays within a
     structural bound of the decode-only baseline: hops are the lowest
     -priority items, so decode can wait behind at most the one explain
     item already executing, never the whole escalation backlog. The
     straggler monitor must flag the slow items and ONLY the poisoned
     request may degrade.

Everything runs at ``compute_dtype=float32`` — the donated-endpoint
contract is bit-exact there and NOT at bf16 (docs/serving.md).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import prompt_pool

# SLO gate slack: the structural claim is "decode waits behind at most one
# in-flight explain item"; 2 injected-sleep units plus a CI-noise pad bound
# that without gating raw wall-clock
STRAGGLER_S = 0.25
SLO_PAD_S = 1.0


def _p99(tickets) -> float:
    return float(np.percentile([t.latency_s for t in tickets], 99))


def run(
    *,
    arch: str = "llama3-8b",
    requests: int = 6,
    gen_tokens: int = 3,
    m: int = 8,
    n_int: int = 4,
    tol: float = 1e-3,
    smoke: bool = False,
    seed: int = 0,
) -> dict:
    from repro.configs import ARCHS, reduced
    from repro.models.registry import Model
    from repro.runtime.fault import FaultConfig, StragglerMonitor
    from repro.serve import (
        INTERACTIVE,
        ExplainEngine,
        ExplainRequest,
        GenerateRequest,
        MixedScheduler,
    )

    if smoke:
        requests, gen_tokens, m = 4, 2, 8
    # bit-exactness of the donated endpoint needs f32 compute (docs/serving.md)
    cfg = dataclasses.replace(reduced(ARCHS[arch]), compute_dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    engine = ExplainEngine(
        cfg, params, m=m, n_int=n_int, seq_buckets=(8, 16, 32),
        adaptive=True, tol=tol, m_max=4 * m,
    )
    sched = MixedScheduler(engine, max_len=16, decode_chunk=2)
    rng = np.random.default_rng(seed)
    prompts = prompt_pool(rng, cfg.vocab_size, requests)

    out = {
        "arch": arch, "requests": requests, "gen_tokens": gen_tokens,
        "m": m, "n_int": n_int, "tol": tol, "smoke": smoke,
        "device_kind": jax.devices()[0].device_kind, "gates": {},
    }
    failures: list[str] = []

    def submit_workload():
        tickets = []
        for p in prompts:
            tickets.append(sched.submit(GenerateRequest(
                tokens=p, num_tokens=gen_tokens, explain=True, slo=INTERACTIVE,
            )))
        sched.run_until_idle()
        return tickets

    # -- gate 1: donated-endpoint bit-identity vs the standalone engine ------
    t0 = time.perf_counter()
    tickets = submit_workload()
    out["warmup_wall_s"] = time.perf_counter() - t0
    standalone = engine.explain([
        ExplainRequest(tokens=p, target=int(t.tokens[0]))
        for p, t in zip(prompts, tickets)
    ])
    mismatches = []
    for i, (t, ref) in enumerate(zip(tickets, standalone)):
        got = next(a for a in t.attributions if a["pos"] == 0)
        checks = {
            "token_scores": np.array_equal(got["token_scores"], ref["token_scores"]),
            "delta": got["delta"] == ref["delta"],
            "f_x": got["f_x"] == ref["f_x"],
            "f_baseline": got["f_baseline"] == ref["f_baseline"],
            "m_used": got["m_used"] == ref["m_used"],
            "hops": got["hops"] == ref["hops"],
            "converged": got["converged"] == ref["converged"],
        }
        if not all(checks.values()):
            mismatches.append((i, [k for k, v in checks.items() if not v]))
    out["gates"]["bit_identical"] = not mismatches
    out["traces"] = [
        {"m_used": r["m_used"], "hops": r["hops"], "converged": r["converged"]}
        for r in standalone
    ]
    if mismatches:
        failures.append(f"donated-endpoint attribution diverges: {mismatches}")
    if any(t.status != "done" for t in tickets):
        failures.append(
            f"warmup statuses {[t.status for t in tickets]} not all done"
        )

    # -- gate 2: zero steady-state recompiles across the combined set --------
    misses0 = engine.stats.misses
    t0 = time.perf_counter()
    submit_workload()
    out["replay_wall_s"] = time.perf_counter() - t0
    recompiles = engine.stats.misses - misses0
    out["steady_state_recompiles"] = recompiles
    out["gates"]["zero_recompiles"] = recompiles == 0
    if recompiles:
        failures.append(f"steady-state replay recompiled {recompiles}x")

    # -- gate 3: escalation hops are preemptible — decode dispatches first ---
    preempted0 = engine.stats.preempted
    sched.submit(ExplainRequest(tokens=prompts[0], target=7))
    while not any(k == "hop" for _, _, k, _ in sched._heap):
        if not sched.step():
            break
    hop_was_queued = any(k == "hop" for _, _, k, _ in sched._heap)
    t_gen = sched.submit(GenerateRequest(
        tokens=prompts[1], num_tokens=2, slo=INTERACTIVE,
    ))
    sched.run_until_idle()
    out["preempted"] = engine.stats.preempted - preempted0
    out["gates"]["preemption"] = (
        hop_was_queued and out["preempted"] > 0 and t_gen.status == "done"
    )
    if not out["gates"]["preemption"]:
        failures.append(
            f"preemption gate: hop_queued={hop_was_queued} "
            f"preempted={out['preempted']} gen={t_gen.status}"
        )

    # -- gate 4: decode SLO holds under injected explain stragglers ----------
    # decode-only baseline on the warmed scheduler
    base_tickets = [
        sched.submit(GenerateRequest(tokens=p, num_tokens=gen_tokens,
                                     slo=INTERACTIVE))
        for p in prompts
    ]
    sched.run_until_idle()
    p99_base = _p99(base_tickets)

    # fresh monitor so its EWMA reflects warmed steady-state walls, not the
    # compile-phase seconds-scale items it warmed up on
    sched.monitor = StragglerMonitor(FaultConfig())
    # poisoned request gets a unique (·, 16) bucket: every attempt on that
    # bucket's explain items fails, so ONLY it degrades
    poison_prompt = rng.integers(1, cfg.vocab_size, 12).astype(np.int32)

    def hook(kind, payload):
        if kind in ("exp_start", "hop", "exp_fixed"):
            bucket = payload.bb.bucket if hasattr(payload, "bb") else payload.bucket
            if bucket[1] == 16:
                raise RuntimeError("injected poison")
            time.sleep(STRAGGLER_S)

    sched.fault_hook = hook
    degraded0 = engine.stats.degraded
    exp_tickets = [
        sched.submit(ExplainRequest(tokens=p, target=3)) for p in prompts[:3]
    ]
    t_poison = sched.submit(ExplainRequest(tokens=poison_prompt, target=3))
    slo_tickets = [
        sched.submit(GenerateRequest(tokens=p, num_tokens=gen_tokens,
                                     slo=INTERACTIVE))
        for p in prompts
    ]
    sched.run_until_idle()
    sched.fault_hook = None
    p99_mixed = _p99(slo_tickets)
    out["slo"] = {
        "p99_decode_only_s": p99_base,
        "p99_mixed_straggler_s": p99_mixed,
        "bound_s": p99_base + 2 * STRAGGLER_S + SLO_PAD_S,
        "stragglers_flagged": len(sched.monitor.flagged),
        "degraded": engine.stats.degraded - degraded0,
    }
    ok_slo = p99_mixed <= out["slo"]["bound_s"]
    ok_flag = len(sched.monitor.flagged) > 0
    ok_degrade = (
        t_poison.status == "degraded"
        and all(t.status == "done" for t in exp_tickets)
        and all(t.status == "done" for t in slo_tickets)
        and engine.stats.degraded > degraded0
    )
    out["gates"]["slo_under_stragglers"] = ok_slo
    out["gates"]["stragglers_flagged"] = ok_flag
    out["gates"]["degrade_only_affected"] = ok_degrade
    if not ok_slo:
        failures.append(
            f"interactive p99 {p99_mixed:.3f}s exceeds bound "
            f"{out['slo']['bound_s']:.3f}s (decode-only {p99_base:.3f}s)"
        )
    if not ok_flag:
        failures.append("straggler monitor flagged nothing under injection")
    if not ok_degrade:
        failures.append(
            f"degradation gate: poison={t_poison.status} "
            f"others={[t.status for t in exp_tickets + slo_tickets]}"
        )

    out["latency_summary"] = sched.latency_summary()
    out["failures"] = failures
    out["pass"] = not failures
    print(
        f"mixed_serving bit_identical={out['gates']['bit_identical']} "
        f"recompiles={recompiles} preempted={out['preempted']} "
        f"p99 {p99_base:.3f}s -> {p99_mixed:.3f}s "
        f"flagged={out['slo']['stragglers_flagged']} pass={out['pass']}"
    )
    if failures:
        print(f"mixed_serving failures: {failures}")
    return out


def main():
    run(smoke=True)


if __name__ == "__main__":
    main()
