"""Hot-path bandwidth benchmark (DESIGN.md §10) -> results/BENCH_hotpath.json.

Serves identical mixed-length traffic through a FUSED stage-2 engine and the
materializing (unfused) oracle engine and gates three claims, per smoke
bucket and per attribution method:

  1. **bytes** — the fused fixed-m executable's ``cost_analysis`` bytes
     accessed is strictly lower than the materializing path's at every
     bucket (riemann-class methods; IDGI's quadratic accumulator needs its
     per-step gradients either way, so its gate is no-worse);
  2. **latency** — warmed fused wall-clock is no worse than unfused on the
     aggregate across the four methods (min-of-rounds per engine, small
     CI-noise allowance; per-method ratios are recorded, not gated —
     single-method walls jitter ±50% on shared hosts);
  3. **traces** — δ-adaptive serving escalates IDENTICALLY: per-request
     ``m_used`` / ``hops`` / ``converged`` from the fused engine equal the
     unfused engine's exactly, for all four methods.

The sweep runs at ``compute_dtype=float32``: the trace gate isolates
program-structure effects, and under bf16 the weight-seeded fused backward
legitimately rounds cotangents at a different scale (≲0.5% relative —
tolerance-tested in tests/test_hotpath.py, not trace-gated here).

The autotuner rides the same sweep: every bucket is tuned
(``serve.autotune``), the tuned engine must replay traffic with ZERO
steady-state recompiles, and its warmed latency is recorded. If a committed
baseline exists (results/BENCH_hotpath_baseline.json), fused bytes-accessed
per bucket must not regress beyond 2% — the CI ratchet.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import RESULTS_DIR
from repro.core.methods import METHODS

BASELINE = os.path.join(RESULTS_DIR, "BENCH_hotpath_baseline.json")
# warmed-latency gate allowance: CPU CI wall-clock is noisy; the claim
# "fused is no worse" is gated at this multiple of the unfused median and
# the raw medians ride the artifact for inspection
LATENCY_SLACK = 1.25
BYTES_REGRESSION_SLACK = 1.02


def _warmed_wall(engine, reqs, rounds=3):
    """Min-of-rounds warmed wall — the noise-robust latency estimator: the
    best observed round is the one least polluted by scheduler jitter on a
    shared CI host, and fusion can only shift the floor, not the noise."""
    engine.explain(reqs)  # compile + warm
    ts = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        engine.explain(reqs)
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))


def run(
    *,
    arch: str = "llama3-8b",
    requests: int = 8,
    m: int = 16,
    n_int: int = 4,
    tol: float = 1e-2,
    rounds: int = 3,
    smoke: bool = False,
    seed: int = 0,
    attn: str = "auto",
) -> dict:
    from repro.configs import ARCHS, reduced
    from repro.launch.explain import make_traffic
    from repro.models.registry import Model
    from repro.serve import ExplainEngine, autotune_engine

    if smoke:
        requests, m, rounds = 6, 8, 3
    cfg = dataclasses.replace(reduced(ARCHS[arch]), compute_dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    reqs = make_traffic(cfg, requests, 9, 28, np.random.default_rng(seed))

    out = {
        "arch": arch, "m": m, "n_int": n_int, "requests": requests,
        "rounds": rounds, "tol": tol, "attn": attn,
        "device_kind": jax.devices()[0].device_kind,
        "methods": {}, "gates": {},
    }
    failures: list[str] = []

    # -- per-method fused-vs-unfused sweep (fixed-m bytes/latency + traces) --
    for method in sorted(n for n in METHODS if not METHODS[n].forward_only):
        spec = METHODS[method]
        row: dict = {"accum": spec.accum}
        for label, fused in (("unfused", False), ("fused", True)):
            eng = ExplainEngine(
                cfg, params, method=method, m=m, n_int=n_int, fused=fused,
                attn=attn,
            )
            wall = _warmed_wall(eng, reqs, rounds)
            row[label] = {
                "warmed_wall_s": wall,
                "buckets": {
                    f"B{b[0]}xS{b[1]}": {
                        "bytes_accessed": bs.bytes_accessed,
                        "peak_bytes": bs.peak_bytes,
                        "mean_latency_ms": 1e3 * bs.mean_latency_s,
                    }
                    for b, bs in sorted(eng.stats.buckets.items())
                },
            }
        # bytes gate: strict reduction for grad-linear (riemann) classes,
        # no-worse for quadratic ones (per-step grads are irreducible)
        for bucket in row["unfused"]["buckets"]:
            bu = row["unfused"]["buckets"][bucket]["bytes_accessed"]
            bf = row["fused"]["buckets"][bucket]["bytes_accessed"]
            if spec.grad_linear and not bf < bu:
                failures.append(f"{method}/{bucket}: fused bytes {bf} !< {bu}")
            if not spec.grad_linear and bf > bu:
                failures.append(f"{method}/{bucket}: fused bytes {bf} > {bu}")
        wu, wf = row["unfused"]["warmed_wall_s"], row["fused"]["warmed_wall_s"]
        row["latency_ratio"] = wf / wu

        # adaptive trace parity: identical escalation per request
        traces = {}
        for label, fused in (("unfused", False), ("fused", True)):
            eng = ExplainEngine(
                cfg, params, method=method, m=m, n_int=n_int,
                adaptive=True, tol=tol, m_max=4 * m, fused=fused, attn=attn,
            )
            res = eng.explain(reqs)
            traces[label] = [
                (r["m_used"], r["hops"], r["converged"]) for r in res
            ]
        row["traces_equal"] = traces["unfused"] == traces["fused"]
        row["traces"] = {
            k: [list(map(int, t[:2])) + [bool(t[2])] for t in v]
            for k, v in traces.items()
        }
        if not row["traces_equal"]:
            failures.append(f"{method}: adaptive traces diverge {traces}")
        out["methods"][method] = row
        print(
            f"hotpath [{method:13s}] latency fused/unfused={row['latency_ratio']:.2f} "
            f"traces_equal={row['traces_equal']}"
        )

    # latency gate on the AGGREGATE across the method zoo: per-method wall
    # ratios jitter ±50% on shared CI hosts (noise_tunnel and expected_grad
    # run the same riemann executables yet measure differently run to run),
    # while the four-method sum is stable; per-method ratios stay in the
    # artifact for inspection
    total_u = sum(r["unfused"]["warmed_wall_s"] for r in out["methods"].values())
    total_f = sum(r["fused"]["warmed_wall_s"] for r in out["methods"].values())
    out["total_latency_ratio"] = total_f / total_u
    if total_f > LATENCY_SLACK * total_u:
        failures.append(
            f"fused warmed latency {total_f:.3f}s > {LATENCY_SLACK}x "
            f"unfused {total_u:.3f}s across the method zoo"
        )

    # -- autotune + zero-recompile replay (fused, default method) -----------
    base_eng = ExplainEngine(cfg, params, m=m, n_int=n_int, fused=True, attn=attn)
    tune_report = autotune_engine(
        base_eng, reqs, rounds=rounds, results_dir=RESULTS_DIR
    )
    tuned = ExplainEngine(
        cfg, params, m=m, n_int=n_int, fused=True, attn=attn,
        autotune=True, autotune_dir=RESULTS_DIR,
    )
    tuned_wall = _warmed_wall(tuned, reqs, rounds)
    warmed_misses = tuned.stats.misses
    tuned.explain(reqs)
    recompiles = tuned.stats.misses - warmed_misses
    out["autotune"] = {
        "winners": {k: v["winner"] for k, v in tune_report["buckets"].items()},
        "cache_path": tune_report.get("path"),
        "tuned_warmed_wall_s": tuned_wall,
        "steady_state_recompiles": recompiles,
    }
    if recompiles:
        failures.append(f"autotuned replay recompiled {recompiles}x")

    # -- bytes ratchet vs the committed baseline ----------------------------
    if os.path.exists(BASELINE):
        with open(BASELINE) as fh:
            base = json.load(fh)
        for method, row in out["methods"].items():
            for bucket, cur in row["fused"]["buckets"].items():
                prev = (
                    base.get("methods", {}).get(method, {})
                    .get("fused", {}).get("buckets", {}).get(bucket)
                )
                if prev and cur["bytes_accessed"] > BYTES_REGRESSION_SLACK * prev[
                    "bytes_accessed"
                ]:
                    failures.append(
                        f"{method}/{bucket}: fused bytes {cur['bytes_accessed']} "
                        f"regressed vs baseline {prev['bytes_accessed']}"
                    )
        out["baseline_checked"] = True
    else:
        out["baseline_checked"] = False

    out["failures"] = failures
    out["pass"] = not failures
    print(f"hotpath pass={out['pass']}" + (f" failures={failures}" if failures else ""))
    return out


def main():
    run(smoke=True)


if __name__ == "__main__":
    main()
