"""§Roofline table: renders the dry-run results (results/dryrun_*.json).

Run ``python -m repro.launch.dryrun`` (and ``--multi-pod``) first; this
benchmark aggregates the recorded per-cell cost/collective analysis into the
three-term roofline table that EXPERIMENTS.md §Roofline embeds.
"""
from __future__ import annotations

import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def load(mesh_name: str = "pod16x16") -> dict:
    path = os.path.join(RESULTS_DIR, f"dryrun_{mesh_name}.json")
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def run(mesh_name: str = "pod16x16") -> dict:
    results = load(mesh_name)
    if not results:
        print(f"# no dry-run results for {mesh_name}; run repro.launch.dryrun first")
        return {}
    rows = []
    print(f"\n== §Roofline ({mesh_name}): compute/memory/collective seconds per step ==")
    print("cell,compute_s,memory_s,collective_s,dominant,useful_ratio,roofline_frac,hbm_GiB/chip")
    for key in sorted(results):
        rec = results[key]
        if rec.get("status") == "skipped":
            print(f"{key},skipped ({rec.get('reason','')})")
            continue
        if rec.get("status") != "ok":
            print(f"{key},ERROR {rec.get('error','')[:80]}")
            continue
        r = rec["roofline"]
        hbm = rec.get("memory", {}).get("argument_size_in_bytes", 0) + rec.get(
            "memory", {}
        ).get("temp_size_in_bytes", 0)
        rows.append(r)
        print(
            f"{key},{r['compute_s']:.4f},{r['memory_s']:.4f},{r['collective_s']:.4f},"
            f"{r['dominant']},{r['useful_ratio']:.3f},{r['roofline_fraction']:.3f},"
            f"{hbm/2**30:.2f}"
        )
    if rows:
        doms = {}
        for r in rows:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        print(f"\n# dominant-term histogram: {doms}")
    return {"rows": rows}


def main():
    run("pod16x16")
    run("pod2x16x16")


if __name__ == "__main__":
    main()
