"""Flash-attention custom-VJP parity + attention-model serving contracts.

Kernel legs mirror tests/test_kernels.py: interpret-mode Pallas vs the
pure-jnp ref oracles on pad-exercising odd shapes, ragged kv lengths, and
GQA head maps, under the deploy numerics (f32, bf16; f64 opts in per-test
via jax.experimental.enable_x64). Engine legs pin the serving contracts the
attention-parity CI job gates: fused and unfused adaptive escalation traces
are EXACTLY equal on a flash LM, and a ViT engine serves patch-feature
requests with zero steady-state recompiles.
"""
import contextlib
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import (
    attention_ref,
    attention_vjp_ref,
    flash_attention,
)

KEY = jax.random.PRNGKey(0)

# (B, S, HQ, HKV, D): odd/prime S exercises the pad-to-block path, HQ != HKV
# exercises the GQA head map in both backward kernels.
SHAPES = [(1, 17, 4, 2, 8), (2, 33, 6, 6, 4)]


def _dtype_ctx(dtype):
    """x64 must be enabled around f64 parity cases (and only those)."""
    if dtype == jnp.float64:
        return jax.experimental.enable_x64()
    return contextlib.nullcontext()


def _tol(dtype):
    return {jnp.float32: 1e-4, jnp.float64: 1e-4, jnp.bfloat16: 3e-2}[dtype]


def _qkv(B, S, HQ, HKV, D, dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, HQ, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, HKV, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, HKV, D)).astype(dtype)
    return q, k, v


def _lengths(B, S, ragged):
    """Ragged kv lengths: every row keeps a different non-pow2 prefix."""
    if not ragged:
        return None
    return jnp.asarray(
        [max(1, (S * (b + 1)) // (B + 1)) for b in range(B)], jnp.int32
    )


def _t(x):
    return x.transpose(0, 2, 1, 3)  # model (B,S,H,D) <-> kernel (B,H,S,D)


def _ref_model_layout(q, k, v, *, causal, lengths):
    return _t(attention_ref(_t(q), _t(k), _t(v), causal=causal, lengths=lengths))


# --------------------------------------------------------------- forward


@pytest.mark.parametrize("ragged", [False, True])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,S,HQ,HKV,D", SHAPES)
def test_flash_forward_parity(B, S, HQ, HKV, D, causal, ragged):
    q, k, v = _qkv(B, S, HQ, HKV, D)
    lens = _lengths(B, S, ragged)
    got = flash_attention(q, k, v, causal=causal, lengths=lens, block_q=8, block_k=8)
    want = _ref_model_layout(q, k, v, causal=causal, lengths=lens)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )


# -------------------------------------------------------------- backward


def _grads(fn, q, k, v, do):
    def loss(q, k, v):
        return jnp.sum(fn(q, k, v).astype(jnp.float32) * do)

    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


@pytest.mark.parametrize("ragged", [False, True])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,S,HQ,HKV,D", SHAPES)
def test_flash_vjp_parity(B, S, HQ, HKV, D, causal, ragged):
    q, k, v = _qkv(B, S, HQ, HKV, D)
    lens = _lengths(B, S, ragged)
    do = jax.random.normal(jax.random.fold_in(KEY, 7), (B, S, HQ, D))

    got = _grads(
        lambda q, k, v: flash_attention(
            q, k, v, causal=causal, lengths=lens, block_q=8, block_k=8
        ),
        q, k, v, do,
    )
    want = _grads(
        lambda q, k, v: _ref_model_layout(q, k, v, causal=causal, lengths=lens),
        q, k, v, do,
    )
    for g, w, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=1e-4, atol=1e-5,
            err_msg=f"d{name} mismatch vs jax.grad(ref)",
        )
    # and against the explicit analytic VJP oracle (kernel layout)
    dq2, dk2, dv2 = attention_vjp_ref(
        _t(q), _t(k), _t(v), _t(do), causal=causal, lengths=lens
    )
    for g, w, name in zip(got, (_t(dq2), _t(dk2), _t(dv2)), "qkv"):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=1e-4, atol=1e-5,
            err_msg=f"d{name} mismatch vs attention_vjp_ref",
        )


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float64])
def test_flash_fwd_bwd_parity_dtypes(dtype):
    """bf16 (TPU compute dtype) and f64 (x64 hosts) on one GQA ragged case."""
    B, S, HQ, HKV, D = 2, 33, 4, 2, 8
    with _dtype_ctx(dtype):
        q, k, v = _qkv(B, S, HQ, HKV, D, dtype)
        lens = _lengths(B, S, True)
        tol = _tol(dtype)
        got = flash_attention(q, k, v, causal=True, lengths=lens, block_q=8, block_k=8)
        want = _ref_model_layout(q, k, v, causal=True, lengths=lens)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol,
        )
        do = jax.random.normal(jax.random.fold_in(KEY, 7), (B, S, HQ, D))
        got_g = _grads(
            lambda q, k, v: flash_attention(
                q, k, v, causal=True, lengths=lens, block_q=8, block_k=8
            ),
            q, k, v, do,
        )
        want_g = _grads(
            lambda q, k, v: _ref_model_layout(q, k, v, causal=True, lengths=lens),
            q, k, v, do,
        )
        for g, w, name in zip(got_g, want_g, "qkv"):
            np.testing.assert_allclose(
                np.asarray(g, np.float32), np.asarray(w, np.float32),
                rtol=tol, atol=tol, err_msg=f"d{name} mismatch under {dtype}",
            )


# ------------------------------------------------------- engine contracts


def test_engine_flash_traces_fused_equals_unfused():
    """δ-adaptive escalation on a flash LM is program-structure identical
    fused vs unfused: per-request (m_used, hops, converged) match exactly."""
    from repro.configs import ARCHS, reduced
    from repro.launch.explain import make_traffic
    from repro.models.registry import model_for
    from repro.serve import ExplainEngine

    cfg = dataclasses.replace(reduced(ARCHS["llama3-8b"]), compute_dtype="float32")
    params = model_for(cfg).init(jax.random.PRNGKey(0))
    reqs = make_traffic(cfg, 4, 5, 14, np.random.default_rng(0))
    traces = {}
    for fused in (False, True):
        eng = ExplainEngine(
            cfg, params, m=4, n_int=2, adaptive=True, tol=1e-2, m_max=16,
            fused=fused, attn="flash", seq_buckets=(8, 16),
        )
        res = eng.explain(reqs)
        traces[fused] = [(r["m_used"], r["hops"], r["converged"]) for r in res]
    assert traces[True] == traces[False]


def test_vit_engine_serves_patch_features():
    """Feature-space requests: per-patch scores, finite δ, and replaying the
    same traffic hits the warmed executable cache (zero recompiles)."""
    from repro.configs.vit import reduced_vit
    from repro.models import vit
    from repro.serve import ExplainEngine, ExplainRequest

    cfg = reduced_vit()
    params = vit.init(cfg, jax.random.PRNGKey(0))
    imgs = jax.random.uniform(
        jax.random.PRNGKey(1), (3, cfg.image_size, cfg.image_size, cfg.channels)
    )
    feats = np.asarray(vit.patchify(cfg, imgs), np.float32)
    reqs = [
        ExplainRequest(
            tokens=np.arange(cfg.num_patches, dtype=np.int32),
            target=int(i % cfg.num_classes),
            features=f,
        )
        for i, f in enumerate(feats)
    ]
    eng = ExplainEngine(
        cfg, params, m=4, n_int=2, fused=True, attn="flash",
        seq_buckets=(cfg.num_patches,),
    )
    res = eng.explain(reqs)
    assert len(res) == len(reqs)
    assert all(len(r["token_scores"]) == cfg.num_patches for r in res)
    assert all(np.isfinite(r["delta"]) for r in res)
    misses = eng.stats.misses
    eng.explain(reqs)
    assert eng.stats.misses == misses


def test_mixed_feature_token_traffic_rejected():
    from repro.serve import ExplainRequest
    from repro.serve.batching import plan_buckets

    reqs = [
        ExplainRequest(
            tokens=np.arange(8, dtype=np.int32), target=0,
            features=np.ones((8, 4), np.float32),
        ),
        ExplainRequest(tokens=np.arange(8, dtype=np.int32), target=0),
    ]
    with pytest.raises(ValueError, match="mixed"):
        plan_buckets(reqs)
