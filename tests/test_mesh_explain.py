"""Mesh-sharded ExplainEngine parity (DESIGN.md §9).

The contract under test, on a forced 4-device CPU mesh:
  (a) sharded attributions match the single-device engine within tolerance
      for every attribution method × schedule family, fixed-m AND adaptive;
  (b) the adaptive escalation TRACE (per-request m_used / hops) is identical
      to single-device — δ reductions are device-local, so the mesh never
      changes a serving decision;
  (c) replayed traffic performs zero recompiles against the mesh-keyed
      executable cache, and mesh-divisible padding means the replication
      fallback (EngineStats.mesh_fallbacks) is never taken;
  (d) single-device and sharded executables coexist in one shared AOT cache
      (keys carry the mesh axis sizes).

This module needs ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
set before backend init; in the plain single-device tier-1 process every
test here skips (conftest must never force virtual devices — see its
docstring), and CI runs this file in its own mesh-parity process.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core import schedule
from repro.core.api import Explainer
from repro.core.methods import METHODS
from repro.models.registry import Model
from repro.serve import ExplainEngine, ExplainRequest
from repro.serve.batching import BucketBatch, pad_rows

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4",
)

KEY = jax.random.PRNGKey(0)
MIXED_LENS = (9, 12, 17)


@pytest.fixture(scope="module")
def lm():
    cfg = reduced(ARCHS["llama3-8b"])
    model = Model(cfg)
    return cfg, model, model.init(KEY)


@pytest.fixture(scope="module")
def mesh():
    from repro.launch.mesh import make_explain_mesh

    return make_explain_mesh(4, 1)


def _requests(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [
        ExplainRequest(
            tokens=rng.integers(1, cfg.vocab_size, s).astype(np.int32),
            target=int(rng.integers(0, cfg.vocab_size)),
        )
        for s in lens
    ]


def _pair(cfg, params, mesh, **kw):
    kw.setdefault("schedule", "paper")
    kw.setdefault("m", 8)
    kw.setdefault("n_int", 4)
    return (
        ExplainEngine(cfg, params, **kw),
        ExplainEngine(cfg, params, mesh=mesh, **kw),
    )


# ---------------------------------------------------- (a) fixed-m parity


@pytest.mark.parametrize("method", sorted(METHODS))
def test_fixed_m_parity_per_method(lm, mesh, method):
    cfg, _, params = lm
    single, sharded = _pair(cfg, params, mesh, method=method, n_samples=2)
    reqs = _requests(cfg, MIXED_LENS, seed=1)
    out_s, out_m = single.explain(reqs), sharded.explain(reqs)
    for a, b in zip(out_s, out_m):
        np.testing.assert_allclose(a["token_scores"], b["token_scores"], atol=2e-4)
        np.testing.assert_allclose(a["delta"], b["delta"], atol=2e-4)
    # (c) zero steady-state recompiles against the mesh-keyed cache
    misses = sharded.stats.misses
    out_m2 = sharded.explain(_requests(cfg, MIXED_LENS, seed=2))
    assert sharded.stats.misses == misses, f"{method} recompiled under mesh"
    assert sharded.stats.mesh_fallbacks == 0
    assert all(np.isfinite(o["token_scores"]).all() for o in out_m2)


@pytest.mark.parametrize("sched", sorted(schedule.SCHEDULES))
def test_fixed_m_parity_per_schedule(lm, mesh, sched):
    cfg, _, params = lm
    single, sharded = _pair(cfg, params, mesh, schedule=sched)
    reqs = _requests(cfg, (9, 17), seed=3)
    for a, b in zip(single.explain(reqs), sharded.explain(reqs)):
        np.testing.assert_allclose(a["token_scores"], b["token_scores"], atol=2e-4)
    assert sharded.stats.mesh_fallbacks == 0


# ------------------------------------- (b) adaptive trace bit-identity


@pytest.mark.parametrize(
    "method", sorted(n for n in METHODS if not METHODS[n].forward_only)
)
def test_adaptive_trace_identical_to_single_device(lm, mesh, method):
    cfg, _, params = lm
    single, sharded = _pair(
        cfg, params, mesh, method=method, m=4, adaptive=True, tol=1e-2,
        m_max=16, n_samples=2,
    )
    reqs = _requests(cfg, (9, 17, 12, 24), seed=4)
    out_s, out_m = single.explain(reqs), sharded.explain(reqs)
    for a, b in zip(out_s, out_m):
        # the serving DECISIONS must match exactly: same exit rung, same
        # hop count, same convergence verdict per request
        assert (a["m_used"], a["hops"], a["converged"]) == (
            b["m_used"], b["hops"], b["converged"],
        ), f"{method} escalation trace diverged under mesh"
        np.testing.assert_allclose(a["token_scores"], b["token_scores"], atol=2e-4)
    # replayed adaptive traffic touches only warmed (mesh-keyed) executables
    misses = sharded.stats.misses
    out_m2 = sharded.explain(reqs)
    assert sharded.stats.misses == misses, f"{method} adaptive replay recompiled"
    assert sharded.stats.mesh_fallbacks == 0
    for a, b in zip(out_m, out_m2):
        np.testing.assert_array_equal(a["token_scores"], b["token_scores"])


# --------------------------- (c) mesh-divisible padding, fallback counter


def test_buckets_padded_to_dp_multiple(lm, mesh):
    cfg, _, params = lm
    eng = ExplainEngine(cfg, params, m=4, n_int=2, mesh=mesh)
    assert eng.dp == 4
    eng.explain(_requests(cfg, (9,), seed=5))  # 1 request -> B must pad to 4
    assert set(eng.stats.buckets) == {(4, 16)}
    assert eng.stats.mesh_fallbacks == 0


def test_pad_rows_mesh_multiple():
    rows, B = pad_rows([0], (1, 2, 4, 8), multiple=4)
    assert (rows, B) == ([0, 0, 0, 0], 4)
    rows, B = pad_rows([0, 1, 2, 3, 4], (1, 2, 4, 8), multiple=4)
    assert B == 8 and rows[:5] == [0, 1, 2, 3, 4]
    # no ladder: plain round-up to the multiple
    assert pad_rows([0, 1, 2], None, multiple=4)[1] == 4


def test_indivisible_bucket_counts_fallback(lm, mesh):
    """A hand-built B=3 bucket (bypassing plan-time padding) must serve
    correctly but replicated — counted, warned, never silent."""
    cfg, _, params = lm
    eng = ExplainEngine(cfg, params, m=4, n_int=2, mesh=mesh)
    reqs = _requests(cfg, (5, 5, 5), seed=6)
    tokens = np.stack([np.pad(r.tokens, (0, 3)) for r in reqs]).astype(np.int32)
    bb = BucketBatch(
        bucket=(3, 8),
        indices=(0, 1, 2),
        tokens=tokens,
        lens=np.full((3,), 5, np.int32),
        targets=np.asarray([r.target for r in reqs], np.int32),
        mask=(tokens != 0).astype(np.float32),
    )
    with pytest.warns(UserWarning, match="does not divide dp"):
        res = eng._run_bucket(bb)
    assert eng.stats.mesh_fallbacks == 1
    assert np.isfinite(np.asarray(res.attributions)).all()


# ------------------------------ (d) one cache, mesh-keyed, entries coexist


def test_adaptive_cache_coexists_across_meshes(lm, mesh):
    """Explainer.attribute_adaptive: one shared AOT cache dict serves a
    single-device and a mesh-sharded explainer without collisions — the
    cache key carries the mesh axis sizes."""
    cfg, model, params = lm
    f = model.target_logprob_fn(params)
    reqs = _requests(cfg, (8, 8, 8, 8), seed=7)
    tokens = jnp.asarray(np.stack([r.tokens for r in reqs]))
    e = model.embed_inputs(params, {"tokens": tokens})
    from repro.core.baselines import pad_embedding

    bl = pad_embedding(params["embed"]["embedding"], e, pad_id=0)
    tgt = jnp.asarray([r.target for r in reqs])
    cache = {}
    kw = dict(schedule="paper", m=4, n_int=4)
    res1, info1 = Explainer(f, **kw).attribute_adaptive(e, bl, tgt, m_max=8, cache=cache)
    n1 = len(cache)
    assert n1 == info1["compiles"] > 0
    res2, info2 = Explainer(f, mesh=mesh, **kw).attribute_adaptive(
        e, bl, tgt, m_max=8, cache=cache
    )
    assert len(cache) == n1 + info2["compiles"] > n1, "mesh entries must not collide"
    # B=4 divides dp=4 and hops pad survivors to dp multiples: everything shards
    assert info2["mesh_fallbacks"] == 0
    np.testing.assert_allclose(
        np.asarray(res1.attributions), np.asarray(res2.attributions), atol=2e-4
    )
    np.testing.assert_array_equal(info1["m_used"], info2["m_used"])
    # replay on the warmed shared cache: zero compiles for both explainers
    _, i1 = Explainer(f, **kw).attribute_adaptive(e, bl, tgt, m_max=8, cache=cache)
    _, i2 = Explainer(f, mesh=mesh, **kw).attribute_adaptive(e, bl, tgt, m_max=8, cache=cache)
    assert i1["compiles"] == i2["compiles"] == 0


def test_sharded_executables_actually_shard(lm, mesh):
    """The compiled entries under a mesh carry resolved NamedShardings and
    their outputs land distributed over the data axis."""
    cfg, _, params = lm
    eng = ExplainEngine(cfg, params, m=4, n_int=2, mesh=mesh)
    out = eng.explain(_requests(cfg, MIXED_LENS, seed=8))
    assert out and all(np.isfinite(o["token_scores"]).all() for o in out)
    assert all(sh is not None for _, sh in eng._cache.values())
    from repro.sharding import dp_size, explain_arg_shardings, mesh_cache_key

    assert dp_size(mesh) == 4
    assert mesh_cache_key(mesh) == (("data", 4), ("model", 1))
    args = (np.zeros((8, 16, 4), np.float32), np.zeros((8, 16), np.float32))
    sh = explain_arg_shardings(mesh, args)
    assert sh[0].spec == jax.sharding.PartitionSpec("data", None, None)
    assert sh[1].spec == jax.sharding.PartitionSpec("data", None)
    assert explain_arg_shardings(mesh, (np.zeros((3, 2), np.float32),)) is None
