"""Sharding rule tests on a 1-device mesh (spec construction is mesh-size
aware; divisibility fallbacks are exercised with a fake multi-axis mesh via
spec inspection rather than real devices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCHS, reduced
from repro.models import lm
from repro.models.common import ParamDef
from repro.sharding import (
    DEFAULT_RULES,
    FSDP_RULES,
    cache_specs,
    logical_to_spec,
    param_specs,
    spec_for_batch_tree,
)


class FakeMesh:
    """Duck-typed mesh for spec construction (no devices needed)."""

    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)
        self.devices = np.empty(tuple(sizes.values()), dtype=object)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_tp_axes_assigned():
    spec = logical_to_spec(("embed", "mlp"), (4096, 14336), MESH, DEFAULT_RULES)
    assert spec == P(None, "model")


def test_fsdp_shards_embed():
    spec = logical_to_spec(("embed", "mlp"), (4096, 14336), MESH, FSDP_RULES)
    assert spec == P("data", "model")


def test_indivisible_dim_stays_replicated():
    # 6 heads % 16 != 0 -> replicated
    spec = logical_to_spec(("embed", "heads", "head_dim"), (384, 6, 64), MESH, DEFAULT_RULES)
    assert spec == P(None, None, None)


def test_no_double_use_of_mesh_axis():
    # experts and mlp both prefer 'model'; only the first gets it
    spec = logical_to_spec(("experts", "embed", "mlp"), (128, 2048, 768), MESH, DEFAULT_RULES)
    assert spec == P("model", None, None)


def test_batch_spans_pod_and_data():
    spec = logical_to_spec(("batch", None), (256, 10), MESH3, DEFAULT_RULES)
    assert spec == P(("pod", "data"), None)


def test_batch_too_small_falls_back():
    spec = logical_to_spec(("batch",), (1,), MESH3, DEFAULT_RULES)
    assert spec == P(None)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_cover_all_archs(arch):
    """Every parameter of every FULL config gets a valid spec (divisibility-
    checked against the production mesh sizes)."""
    cfg = ARCHS[arch]
    defs = lm.param_defs(cfg)
    specs = param_specs(defs, MESH, FSDP_RULES)
    flat_defs = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_defs) == len(flat_specs)
    sizes = {"data": 16, "model": 16}
    for d, s in zip(flat_defs, flat_specs):
        for dim, ax in zip(d.shape, tuple(s) + (None,) * (len(d.shape) - len(s))):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            prod = int(np.prod([sizes[a] for a in axes]))
            assert dim % prod == 0, (arch, d.shape, s)


def test_big_params_are_sharded():
    """The widest tensors must not stay replicated (HBM fit at 27B+)."""
    cfg = ARCHS["gemma3-27b"]
    defs = lm.param_defs(cfg)
    specs = param_specs(defs, MESH, FSDP_RULES)
    # embedding table: vocab on model, embed on data (fully sharded)
    assert specs["embed"]["embedding"] == P("model", "data")


def test_cache_specs_kv_layout():
    cfg = ARCHS["llama3-8b"]
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, 128, 1024, kv_slots=16))
    specs = cache_specs(cache, MESH, DEFAULT_RULES)
    k_spec = specs["layers"][0]["k"]
    assert k_spec == P(None, "data", None, "model", None)
    assert specs["len"] == P()


def test_cache_specs_seq_sharded_long_context():
    cfg = ARCHS["jamba-v0.1-52b"]
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, 1, 524_288))
    specs = cache_specs(cache, MESH, DEFAULT_RULES, seq_sharded=True)
    # find an attention layer cache (jamba: one attn layer per period)
    k_specs = [
        lc["k"] for lc in specs["layers"] if isinstance(lc, dict) and "k" in lc
    ]
    assert any(s[2] == "data" for s in k_specs), k_specs  # seq axis on data


def test_cache_specs_ssm_state():
    cfg = ARCHS["mamba2-780m"]
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, 128, 32768))
    specs = cache_specs(cache, MESH, DEFAULT_RULES)
    st = specs["layers"][0]["state"]
    assert st[1] == "data" and st[2] == "model"  # batch on data, heads on model


def test_spec_for_batch_tree():
    batch = {
        "tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
        "labels": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
    }
    specs = spec_for_batch_tree(batch, MESH, DEFAULT_RULES)
    assert specs["tokens"] == P("data", None)


def test_spec_for_batch_tree_seq_sharded():
    batch = {"token": jax.ShapeDtypeStruct((1, 524_288), jnp.int32)}
    specs = spec_for_batch_tree(batch, MESH, DEFAULT_RULES, seq_sharded=True)
    assert specs["token"] == P(None, "data")


def test_explain_specs_fold_batch_axis():
    """ExplainEngine inputs: every leading (request-batch) dim on the data
    axes — that's what shards the folded (batch × step) stage-2 axis."""
    from repro.sharding import explain_specs

    embeds, baseline, aux, mask = explain_specs(MESH, DEFAULT_RULES)
    assert embeds == P("data", None, None) and baseline == embeds
    assert aux["target"] == P("data") and aux["pos"] == P("data")
    assert mask == P("data", None)
    e3, _, _, _ = explain_specs(MESH3, DEFAULT_RULES)
    assert e3[0] == ("pod", "data")  # megabatch spans both data axes


def test_explain_shardings_divisibility_fallback():
    """Indivisible bucket batches replicate (None) instead of erroring; a
    1-device mesh has nothing to shard over."""
    from repro.sharding import explain_shardings

    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    mesh1 = Mesh(dev, ("data", "model"))
    assert explain_shardings(mesh1, batch=8) is None
