"""Fused stage-2 hot path (DESIGN.md §10): parity, donation, autotune.

What the bandwidth overhaul must NOT change, stated as tests:

  (a) fused-vs-oracle parity — ``ig.attribute(fused=True)`` matches the
      materializing path for every method × schedule family, under f32 AND
      bf16, with ragged masks (fused differs only in program structure; at
      bf16 the weight-seeded backward legitimately reorders rounding, so
      the tolerance is dtype-scaled);
  (b) the fused adaptive ladder stays BIT-identical to one fused fixed run
      over the materialized refined schedule — through the DONATED hop
      executables of ``attribute_adaptive`` (the §7 resume contract holds
      unchanged when the state buffer is donated);
  (c) the custom-VJP Pallas op ``kernels.interp_accum`` equals the
      ``paths.interp_add`` oracle forward and backward, for both carry
      ranks (riemann broadcast / IDGI per-step), with padding-forcing odd
      shapes;
  (d) an autotuned engine replays warmed traffic with ZERO steady-state
      recompiles (the tuned chunk is part of the executable key, so the
      closed-shape-set argument survives per-bucket configs) and records
      per-bucket bytes-accessed budgets;
  (e) ``interpret=None`` kernel-op defaults resolve from the backend.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ig, methods, schedule
from repro.core.api import Explainer
from repro.core.paths import interp_add
from repro.core.schedule import Schedule

KEY = jax.random.PRNGKey(0)
# the fused hot path differentiates the model — gradient class only
ALL_METHODS = sorted(
    n for n in methods.METHODS if not methods.METHODS[n].forward_only
)
ALL_SCHEDULES = sorted(schedule.SCHEDULES)


def _f(xs, t):
    # nonlinear but cheap: quadrature error is real (exercises δ), grads are
    # position-dependent (exercises direction-aware accumulators)
    return jnp.sum(jnp.tanh(xs) + 0.25 * xs**2, axis=tuple(range(1, xs.ndim)))


def _inputs(dtype, B=3, F=5):
    x = jax.random.normal(KEY, (B, F)).astype(dtype)
    baseline = jnp.zeros_like(x)
    # ragged mask: rows with 3, 5 (all), 1 real positions
    mask = jnp.array(
        [[1, 1, 1, 0, 0], [1, 1, 1, 1, 1], [1, 0, 0, 0, 0]], jnp.float32
    )
    return x, baseline, mask


# ------------------------------------------------ (a) fused-vs-oracle parity


@pytest.mark.parametrize("method", ALL_METHODS)
@pytest.mark.parametrize("sched_name", ALL_SCHEDULES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_matches_oracle(method, sched_name, dtype):
    x, baseline, mask = _inputs(dtype)
    kw = dict(method=method, schedule=sched_name, m=8, n_int=2, chunk=4,
              n_samples=2, sigma=0.15)
    ref = Explainer(_f, **kw).attribute(x, baseline, None, mask=mask)
    got = Explainer(_f, fused=True, **kw).attribute(x, baseline, None, mask=mask)
    # bf16 forwards round the weight-seeded cotangents at a different scale
    # than the unit-seeded unfused backward — ≲1% relative is expected there,
    # while f32 differs only by reduction order
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(got.attributions, np.float32),
        np.asarray(ref.attributions, np.float32),
        **tol,
    )
    np.testing.assert_allclose(
        np.asarray(got.delta, np.float32), np.asarray(ref.delta, np.float32),
        rtol=tol["rtol"], atol=tol["atol"],
    )
    # masked positions: exact zeros on BOTH paths
    got_np = np.asarray(got.attributions, np.float32)
    assert np.all(got_np[0, 3:] == 0.0) and np.all(got_np[2, 1:] == 0.0)


# ------------------- (b) bit-identical fused resume through donated hops


@pytest.mark.parametrize("method", ALL_METHODS)
def test_fused_adaptive_resume_bit_identical(method):
    """tol=0 forces every row up the whole ladder through the DONATED hop
    executables; the result must equal one fused fixed run over the final
    refined schedule bit-for-bit (§7 × §10)."""
    x, baseline, mask = _inputs(jnp.float32)
    ex = Explainer(_f, method=method, schedule="paper", m=4, n_int=2,
                   fused=True, n_samples=2, sigma=0.15)
    x2, b2, t2, m2, n = ex.expand_inputs(x, baseline, None, mask)
    res, state, sched = ex.start(x2, b2, t2, mask=m2)
    fam = schedule.family("paper")
    refined = Schedule(
        jnp.broadcast_to(sched.alphas, (x2.shape[0],) + sched.alphas.shape[-1:]),
        jnp.broadcast_to(sched.weights, (x2.shape[0],) + sched.weights.shape[-1:]),
    )
    for _ in range(2):  # ladder 4 -> 8 -> 16
        refined = fam.refine(refined)
    fixed = ig.attribute(
        _f, x2, b2, refined, t2, method=ex.spec, mask=m2,
        chunk=ex.adaptive_chunk, fused=True,
    )
    fixed = ex.reduce_result(fixed, n)
    adaptive, info = ex.attribute_adaptive(
        x, baseline, None, tol=0.0, m_max=16, mask=mask
    )
    assert list(info["m_used"]) == [16] * x2.shape[0]
    np.testing.assert_array_equal(
        np.asarray(adaptive.attributions), np.asarray(fixed.attributions)
    )
    np.testing.assert_array_equal(
        np.asarray(adaptive.delta), np.asarray(fixed.delta)
    )


# --------------------------- (c) interp_accum kernel vs oracle, fwd and bwd


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("carry_rank", [2, 3])
def test_interp_accum_kernel_parity(dtype, carry_rank):
    from repro.kernels.interp_accum.ops import interp_accum

    B, K, F = 3, 5, 7  # odd K/F force block padding
    x = jax.random.normal(KEY, (B, F)).astype(dtype)
    baseline = (0.1 * jax.random.normal(jax.random.PRNGKey(1), (B, F))).astype(dtype)
    alphas = jax.random.uniform(jax.random.PRNGKey(2), (B, K))
    mask = jnp.array([[1, 1, 1, 1, 0, 0, 0], [1] * 7, [1, 1, 0, 0, 0, 0, 0]],
                     jnp.float32)
    shape = (B, F) if carry_rank == 2 else (B, K, F)
    carry = jax.random.normal(jax.random.PRNGKey(3), shape)
    got = interp_accum(x, baseline, alphas, carry, mask=mask, block_k=4, block_f=4)
    want = interp_add(x, baseline, alphas, carry, mask=mask)
    assert got.dtype == want.dtype == dtype
    # one output-dtype ulp OF THE OPERANDS: XLA may fold the intermediate
    # downcast in one program and not the other, and the carry add can
    # cancel — so bf16 gets an absolute band at ulp(max|operand|) ≈ 2^-8·2
    rtol, atol = (1e-6, 1e-6) if dtype == jnp.float32 else (1e-2, 2e-2)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=rtol, atol=atol,
    )
    # the flat pure-jnp ref honors the same dtype contract as the oracle
    # (interp at input precision, carry add in f32) — bitwise, bf16 included
    from repro.kernels.interp_accum.ref import interp_add_ref

    np.testing.assert_array_equal(
        np.asarray(interp_add_ref(x, baseline, alphas, carry)),
        np.asarray(interp_add(x, baseline, alphas, carry)),
    )

    # at carry == 0 the ORACLE reproduces the unfused interpolants BITWISE
    # (the §10 dtype contract: same quadrature nodes fused and unfused); the
    # kernel agrees to one-ulp (FMA contraction may differ per backend)
    from repro.core.paths import interpolate

    z = jnp.zeros(shape, jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(interp_add(x, baseline, alphas, z, mask=mask)),
        np.asarray(interpolate(x, baseline, alphas, mask=mask)),
    )
    np.testing.assert_allclose(
        np.asarray(interp_accum(x, baseline, alphas, z, mask=mask,
                                block_k=4, block_f=4), np.float32),
        np.asarray(interpolate(x, baseline, alphas, mask=mask), np.float32),
        rtol=1e-6, atol=0,
    )

    # backward: the fused accumulation (weights ride the seed)
    w = jax.random.uniform(jax.random.PRNGKey(4), (B, K))

    def loss(fn):
        def go(u):
            xi = fn(x, baseline, alphas, u, mask=mask)
            vals = jnp.sum(xi.astype(jnp.float32) ** 2, axis=-1)  # (B, K)
            return jnp.sum(vals * w)
        return go

    u0 = carry.astype(jnp.float32)
    gk = jax.grad(loss(lambda *a, **k: interp_accum(*a, block_k=4, block_f=4, **k)))(u0)
    go_ = jax.grad(loss(interp_add))(u0)
    assert gk.dtype == jnp.float32
    # the backward inherits the forward's dtype-ulp band (xi feeds the grad)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(go_), rtol=rtol, atol=atol)


# ----------------------------- (d) autotuned engine: zero recompiles, stats


@pytest.fixture(scope="module")
def lm_f32():
    from repro.configs import ARCHS, reduced
    from repro.models.registry import Model

    cfg = dataclasses.replace(reduced(ARCHS["llama3-8b"]), compute_dtype="float32")
    model = Model(cfg)
    return cfg, model, model.init(KEY)


def _requests(cfg, lens, seed=0):
    from repro.serve import ExplainRequest

    rng = np.random.default_rng(seed)
    return [
        ExplainRequest(
            tokens=rng.integers(1, cfg.vocab_size, s).astype(np.int32),
            target=int(rng.integers(0, cfg.vocab_size)),
        )
        for s in lens
    ]


def test_autotuned_engine_zero_steady_state_recompiles(lm_f32, tmp_path):
    from repro.serve import ExplainEngine, autotune_engine
    from repro.serve.autotune import bucket_key, cache_path

    cfg, _, params = lm_f32
    reqs = _requests(cfg, (5, 7, 12))
    eng = ExplainEngine(cfg, params, m=4, n_int=2, fused=True)
    report = autotune_engine(eng, reqs, rounds=1, results_dir=str(tmp_path))
    assert report["buckets"], "autotune must tune every traffic bucket"
    # tuning leaves the engine's own cache/stats untouched
    assert eng.stats.misses == 0 and not eng.stats.buckets

    tuned = ExplainEngine(
        cfg, params, m=4, n_int=2, fused=True,
        autotune=True, autotune_dir=str(tmp_path),
    )
    key = bucket_key((1, 8), "riemann", "paper", 4, 2, True)
    if key in report["buckets"]:
        assert tuned._cfg_for((1, 8)).chunk == report["buckets"][key]["winner"]["chunk"]
    out = tuned.explain(reqs)
    warmed = tuned.stats.misses
    out2 = tuned.explain(reqs)
    assert tuned.stats.misses == warmed, "autotuned replay must be pure hits"
    for a, b in zip(out, out2):
        np.testing.assert_array_equal(a["token_scores"], b["token_scores"])
    # compile-time roofline budgets are first-class serving stats
    assert all(bs.bytes_accessed > 0 for bs in tuned.stats.buckets.values())
    assert cache_path(str(tmp_path)) == report["path"]


def test_fused_engine_matches_unfused_traces(lm_f32):
    """Adaptive escalation decisions must be identical fused vs unfused at
    f32 (the BENCH_hotpath gate, pinned here as a fast regression test) —
    and the fused engine's hop executables donate their IGState."""
    from repro.serve import ExplainEngine

    cfg, _, params = lm_f32
    reqs = _requests(cfg, (5, 9, 12), seed=1)
    traces = {}
    for fused in (False, True):
        eng = ExplainEngine(
            cfg, params, m=4, n_int=2, adaptive=True, tol=1e-2, m_max=16,
            fused=fused,
        )
        out = eng.explain(reqs)
        traces[fused] = [(o["m_used"], o["hops"], o["converged"]) for o in out]
    assert traces[False] == traces[True]


# ------------------------- (d') autotune cache load is corruption-proof


def test_autotune_load_corrupted_json_warns_and_empties(tmp_path):
    import warnings

    from repro.serve.autotune import AutotuneCache, cache_path

    path = cache_path(str(tmp_path))
    with open(path, "w") as fh:
        fh.write('{"device": "cpu", "entr')  # truncated mid-write
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cache = AutotuneCache.load(str(tmp_path))
    assert cache.entries == {}
    assert any("unreadable" in str(x.message) for x in w)
    # malformed-but-valid JSON (a list payload) is just as unreadable
    with open(path, "w") as fh:
        fh.write("[1, 2, 3]")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cache = AutotuneCache.load(str(tmp_path))
    assert cache.entries == {}
    assert any("unreadable" in str(x.message) for x in w)


def test_autotune_load_device_mismatch_ignores_entries(tmp_path):
    import json
    import warnings

    from repro.serve.autotune import AutotuneCache, cache_path, device_kind

    path = cache_path(str(tmp_path))
    with open(path, "w") as fh:
        json.dump(
            {"device": "tpu-v9000", "entries": {"k": {"chunk": 2}}}, fh
        )
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cache = AutotuneCache.load(str(tmp_path))
    assert cache.device == device_kind() and cache.entries == {}, (
        "another device's tuned chunks must never be adopted silently"
    )
    assert any("tuned for device" in str(x.message) for x in w)


def test_autotune_entries_fingerprint_tracks_entries():
    from repro.serve.autotune import AutotuneCache, HotpathConfig

    a = AutotuneCache(device="cpu")
    fp0 = a.entries_fingerprint()
    a.put("k", HotpathConfig(chunk=2), {"wall_s": 0.1})
    assert a.entries_fingerprint() != fp0, (
        "a tuned chunk changes attribution bytes — the result-cache key "
        "must move with it"
    )
    b = AutotuneCache(device="cpu", entries=dict(a.entries))
    assert b.entries_fingerprint() == a.entries_fingerprint()


# ------------------------------------------- (e) backend-resolved interpret


def test_default_interpret_resolves_from_backend():
    from repro.kernels.common import default_interpret

    assert default_interpret(True) is True
    assert default_interpret(False) is False
    assert default_interpret(None) == (jax.default_backend() == "cpu")
