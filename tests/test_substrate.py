"""Substrate tests: optimizer, data pipeline, train step, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.data import DataConfig, SyntheticLM, make_pipeline
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule, global_norm
from repro.train import TrainConfig, make_train_state, make_train_step
from repro.train.step import compress_grads

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- optimizer


def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100, weight_decay=0.0, clip_norm=0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(150):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = adamw_update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.15


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lr0 = float(cosine_schedule(cfg, jnp.asarray(0)))
    lr_mid = float(cosine_schedule(cfg, jnp.asarray(10)))
    lr_end = float(cosine_schedule(cfg, jnp.asarray(100)))
    assert lr0 < 0.05  # warmup starts near zero
    np.testing.assert_allclose(lr_mid, 1.0, rtol=1e-5)
    np.testing.assert_allclose(lr_end, 0.1, rtol=1e-4)


def test_clip_by_global_norm():
    from repro.optim import clip_by_global_norm

    tree = {"a": jnp.full((10,), 10.0)}
    clipped, gn = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    assert float(gn) > 30


def test_weight_decay_skips_1d():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=10, weight_decay=1.0, clip_norm=0)
    params = {"norm_scale": jnp.ones((4,)), "w": jnp.ones((4, 4))}
    zero_grads = jax.tree.map(jnp.zeros_like, params)
    state = adamw_init(params)
    new, _, _ = adamw_update(cfg, zero_grads, state, params)
    np.testing.assert_allclose(np.asarray(new["norm_scale"]), 1.0)  # no decay
    assert float(new["w"].max()) < 1.0  # decayed


# ------------------------------------------------------------ grad compress


def test_compress_grads_error_feedback():
    """Quantize–dequantize with EF: accumulated updates converge to the truth."""
    g = jax.random.normal(KEY, (64,)) * 0.01
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(32):
        deq, err = compress_grads({"g": g}, {"g": err})
        deq, err = deq["g"], err["g"]
        total = total + deq
    np.testing.assert_allclose(np.asarray(total / 32), np.asarray(g), atol=2e-4)


def test_compress_grads_int8_range():
    g = {"g": jnp.asarray([1e-3, -2e-3, 5e-4])}
    deq, err = compress_grads(g, jax.tree.map(jnp.zeros_like, g))
    assert float(jnp.abs(deq["g"] - g["g"]).max()) < 2e-3 / 127 + 1e-9


# ------------------------------------------------------------------- data


def test_pipeline_deterministic_replay():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4, seed=7)
    ds = SyntheticLM(cfg)
    b1 = ds.batch_at(5)
    b2 = ds.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch_at(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_pipeline_host_sharding_partitions_batch():
    full = SyntheticLM(DataConfig(vocab_size=64, seq_len=8, global_batch=8, seed=1))
    h0 = SyntheticLM(DataConfig(vocab_size=64, seq_len=8, global_batch=8, seed=1, host_index=0, host_count=2))
    h1 = SyntheticLM(DataConfig(vocab_size=64, seq_len=8, global_batch=8, seed=1, host_index=1, host_count=2))
    assert h0.local_batch == 4 and h1.local_batch == 4
    # host batches are deterministic and distinct
    assert not np.array_equal(h0.batch_at(0)["tokens"], h1.batch_at(0)["tokens"])


def test_pipeline_prefetch_resume():
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=2, seed=3)
    it = make_pipeline(cfg, start_step=10, prefetch=2)
    first = next(iter(it))
    np.testing.assert_array_equal(first["tokens"], SyntheticLM(cfg).batch_at(10)["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=2, seed=3)
    b = SyntheticLM(cfg).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ------------------------------------------------------------ train step


def test_train_step_learns():
    cfg = reduced(ARCHS["llama3-8b"])
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60), microbatches=1
    )
    state = make_train_state(cfg, tcfg, KEY)
    step = jax.jit(make_train_step(cfg, tcfg))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8))
    losses = []
    for i in range(30):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_microbatching_matches_full_batch():
    """grad accumulation is loss-equivalent to one big batch (same tokens)."""
    cfg = reduced(ARCHS["yi-9b"])
    t1 = TrainConfig(microbatches=1, remat=False)
    t4 = TrainConfig(microbatches=4, remat=False)
    s1 = make_train_state(cfg, t1, KEY)
    s4 = make_train_state(cfg, t4, KEY)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8))
    b = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    n1, m1 = make_train_step(cfg, t1)(s1, b)
    n4, m4 = make_train_step(cfg, t4)(s4, b)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-4)
    for a, c in zip(jax.tree.leaves(n1.params), jax.tree.leaves(n4.params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(c, np.float32), rtol=2e-3, atol=2e-5
        )


def test_grad_compression_trains():
    cfg = reduced(ARCHS["llama3-8b"])
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60),
        grad_compression=True,
    )
    state = make_train_state(cfg, tcfg, KEY)
    assert state.err is not None
    step = jax.jit(make_train_step(cfg, tcfg))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8))
    losses = []
    for i in range(20):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
