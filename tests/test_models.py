"""Per-arch smoke tests: reduced config, one forward + one train step on CPU,
output shapes + no NaNs. Attention algorithm equivalences. Serving parity.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import attention as attn
from repro.models.registry import Model

KEY = jax.random.PRNGKey(0)
ARCH_IDS = sorted(ARCHS)


def _make_batch(cfg, B=2, S=32, key=KEY):
    k1, k2 = jax.random.split(key)
    batch = {
        "tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
    }
    if cfg.frontend == "vision":
        batch["frontend"] = jnp.ones((B, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    if cfg.frontend == "audio":
        batch["frontend"] = jnp.ones((B, cfg.encoder_seq, cfg.frontend_dim), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad_no_nans(arch):
    cfg = reduced(ARCHS[arch])
    model = Model(cfg)
    params = model.init(KEY)
    batch = _make_batch(cfg)
    loss = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    grads = jax.grad(lambda p: model.loss(p, batch))(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_hidden_shapes(arch):
    cfg = reduced(ARCHS[arch])
    model = Model(cfg)
    params = model.init(KEY)
    batch = _make_batch(cfg, B=2, S=16)
    h, aux = model.forward_hidden(params, batch)
    S_expect = 16 + (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    assert h.shape == (2, S_expect, cfg.d_model)
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced decode logits == full-sequence forward logits.

    Run in float32: this pins cache SEMANTICS (prefill->decode handoff);
    bf16 rounds the two computation orders differently (SSM state carries
    ~0.2 logit noise) without any algorithmic divergence.
    """
    import dataclasses

    cfg = dataclasses.replace(reduced(ARCHS[arch]), compute_dtype="float32")
    model = Model(cfg)
    params = model.init(KEY)
    B, S = 2, 16
    batch = _make_batch(cfg, B=B, S=S)
    batch.pop("labels")

    h, _ = model.forward_hidden(params, {**batch, "labels": None} if False else batch)
    full_logits = np.asarray(model.logits(params, h).astype(jnp.float32))

    text_off = cfg.frontend_tokens if cfg.frontend == "vision" else 0
    # cache must cover prepended frontend tokens + the decoded continuation
    lg, cache = model.prefill(params, batch, max_len=text_off + S + 8)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0].astype(jnp.float32)),
        full_logits[:, -1],
        rtol=5e-2,
        atol=5e-2,
    )
    # decode 4 tokens teacher-forced against an extended forward pass
    extra = jax.random.randint(jax.random.fold_in(KEY, 7), (B, 4), 0, cfg.vocab_size)
    toks = jnp.concatenate([batch["tokens"], extra], axis=1)
    h2, _ = model.forward_hidden(params, {**batch, "tokens": toks})
    want = np.asarray(model.logits(params, h2).astype(jnp.float32))
    for i in range(4):
        lg, cache = model.decode_step(params, cache, extra[:, i : i + 1])
        got = np.asarray(lg[:, 0].astype(jnp.float32))
        np.testing.assert_allclose(
            got, want[:, text_off + S + i], rtol=5e-2, atol=8e-2
        ), f"{arch} step {i}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_embedding_space_ig_hook(arch):
    """target_logprob_fn is differentiable wrt embeddings for every arch."""
    cfg = reduced(ARCHS[arch])
    model = Model(cfg)
    params = model.init(KEY)
    batch = _make_batch(cfg, B=2, S=8)
    e = model.embed_inputs(params, batch)
    f = model.target_logprob_fn(params)
    t = jnp.zeros((2,), jnp.int32)
    val = f(e, t)
    assert val.shape == (2,)
    g = jax.grad(lambda ee: f(ee, t).sum())(e)
    assert g.shape == e.shape
    assert bool(jnp.all(jnp.isfinite(g)))


def test_attention_blocked_equals_full():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 16))
    k = jax.random.normal(ks[1], (2, 128, 2, 16))
    v = jax.random.normal(ks[2], (2, 128, 2, 16))
    full = attn.full_attention(q, k, v, causal=True)
    blocked = attn.blocked_attention(q, k, v, causal=True, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(full), np.asarray(blocked), rtol=2e-3, atol=2e-4)


def test_attention_local_equals_masked_full():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 16))
    k = jax.random.normal(ks[1], (1, 64, 2, 16))
    v = jax.random.normal(ks[2], (1, 64, 2, 16))
    w = 16
    local = attn.local_attention(q, k, v, window=w)
    masked = attn.full_attention(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(local), np.asarray(masked), rtol=2e-3, atol=2e-4)


def test_decode_attention_equals_full_tail():
    ks = jax.random.split(KEY, 4)
    S = 32
    q = jax.random.normal(ks[0], (1, 1, 4, 16))
    kc = jax.random.normal(ks[1], (1, S, 2, 16))
    vc = jax.random.normal(ks[2], (1, S, 2, 16))
    L = 20  # valid cache length
    got = attn.decode_attention(q, kc, vc, jnp.asarray(L))
    want = attn.full_attention(
        q, kc[:, :L], vc[:, :L], causal=True, q_offset=L - 1
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-4)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= k*E/E the drop rate stays small on random data."""
    from repro.models.moe import moe, moe_def
    from repro.models.common import init_params

    cfg = reduced(ARCHS["qwen3-moe-30b-a3b"])
    p = init_params(KEY, moe_def(cfg))
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (2, 64, cfg.d_model)).astype(jnp.bfloat16)
    y, aux = moe(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))
    assert float(aux) >= 0.0


def test_ssm_chunked_matches_small_chunk():
    """SSD chunked scan result is chunk-size invariant."""
    import dataclasses
    from repro.models import ssm

    cfg = reduced(ARCHS["mamba2-780m"])
    p_defs = ssm.ssm_def(cfg)
    from repro.models.common import init_params

    p = init_params(KEY, p_defs)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (2, 32, cfg.d_model)).astype(jnp.float32)
    y1 = ssm.ssm_forward(p, x, cfg)
    cfg2 = dataclasses.replace(cfg, ssm_chunk=8)
    y2 = ssm.ssm_forward(p, x, cfg2)
    np.testing.assert_allclose(
        np.asarray(y1, np.float32), np.asarray(y2, np.float32), rtol=2e-2, atol=2e-3
    )


def test_param_count_analytic_matches_materialized():
    """ArchConfig.param_count (roofline input) == actual leaf count."""
    for arch in ("llama3-8b", "qwen3-moe-30b-a3b", "mamba2-780m", "whisper-tiny"):
        cfg = reduced(ARCHS[arch])
        model = Model(cfg)
        params = model.init(KEY)
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        assert abs(actual - cfg.param_count()) / actual < 0.02, arch
