"""Registry-family invariants that need no hypothesis install: Σw == 1 for
every family × every small m (incl. the uniform(m=1, trapezoid) regression),
and the nested-refinement contract adaptive serving rests on (DESIGN.md §7).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import schedule

_PROBE = schedule.Probe(
    bounds=jnp.asarray([0.0, 0.25, 0.5, 0.75, 1.0]),
    vals=jnp.asarray([0.0, 0.1, 0.7, 0.95, 1.0]),
)


@pytest.mark.parametrize("name", sorted(schedule.SCHEDULES))
@pytest.mark.parametrize("m", [1, 2, 3, 4, 5, 6, 7, 8, 12, 16])
def test_every_family_weights_sum_to_one_small_m(name, m):
    """Σw == 1 for every registry family × every small m (the completeness
    axiom at the schedule level — a partial quadrature can never close the
    completeness gap)."""
    fam = schedule.family(name)
    n = _PROBE.vals.shape[-1] - 1
    if name in ("paper", "gauss") and m < n:
        pytest.skip(f"{name} allocation needs >= 1 step per interval")
    probe = None if fam.probe == "none" else _PROBE
    s = fam.build(probe, m, power=0.5, min_steps=1, rule="midpoint")
    a, w = np.asarray(s.alphas), np.asarray(s.weights)
    assert a.shape[-1] == m and w.shape[-1] == m
    np.testing.assert_allclose(w.sum(-1), 1.0, rtol=1e-4)
    assert np.all(a >= 0.0) and np.all(a <= 1.0 + 1e-6)


@pytest.mark.parametrize("rule", ["midpoint", "left", "right", "trapezoid"])
@pytest.mark.parametrize("m", [1, 2, 7])
def test_uniform_rules_sum_to_one(rule, m):
    # m=1 trapezoid regression: both "endpoint halvings" used to land on the
    # single node, producing Σw == 0.25.
    s = schedule.uniform(m, rule)
    np.testing.assert_allclose(np.asarray(s.weights).sum(), 1.0, rtol=1e-5)
    a = np.asarray(s.alphas)
    assert a.shape == (m,) and a.min() >= 0.0 and a.max() <= 1.0


# ----------------------------------------------------- nested refinement


@pytest.mark.parametrize("name", sorted(schedule.SCHEDULES))
def test_refine_preserves_quadrature_invariants(name):
    fam = schedule.family(name)
    probe = None if fam.probe == "none" else _PROBE
    s = fam.build(probe, 8, power=0.5, min_steps=1, rule="midpoint")
    for _ in range(3):
        s2 = fam.refine(s)
        a, w = np.asarray(s2.alphas), np.asarray(s2.weights)
        m = np.shape(s.alphas)[-1]
        assert a.shape[-1] == 2 * m, "refine must double the node count"
        # old nodes are preserved verbatim, old weights halve EXACTLY —
        # the property that makes resumed accumulation bit-identical
        np.testing.assert_array_equal(a[..., :m], np.asarray(s.alphas))
        np.testing.assert_array_equal(w[..., :m], np.asarray(s.weights) * 0.5)
        np.testing.assert_allclose(w.sum(-1), 1.0, rtol=1e-4)
        assert np.all(a >= 0.0) and np.all(a <= 1.0 + 1e-6)
        s = s2


def test_refine_batched_schedules():
    vals = jnp.asarray([[0.0, 0.5, 1.0], [0.0, 0.9, 1.0]])
    s = schedule.paper(vals, 8)
    r = schedule.refine_nested(s)
    assert r.alphas.shape == (2, 16)
    np.testing.assert_allclose(np.asarray(r.weights.sum(-1)), 1.0, rtol=1e-4)


def test_refine_ladder_converges_on_smooth_integrand():
    """Refining must actually refine: ∫exp error down the ladder ends far
    below the base rung's error."""
    s = schedule.uniform(8)
    s = schedule.Schedule(s.alphas[None], s.weights[None])
    true = float(np.e - 1.0)
    est = lambda s: float(jnp.sum(s.weights * jnp.exp(s.alphas), -1)[0])
    err0 = abs(est(s) - true)
    for _ in range(4):
        s = schedule.refine_nested(s)
    assert abs(est(s) - true) < err0 / 20.0
