"""Attribution-method zoo: MethodSpec registry semantics + per-method math.

The anchors:
  (a) IDGI through the engine matches a HAND-WRITTEN per-step reference loop
      (independent implementation: explicit python loop, one jax.grad per
      step, no scan/chunk/registry machinery) on the paper CNN;
  (b) total IDGI attribution == total IG attribution for the same schedule
      (both are the same directional-derivative quadrature), so IDGI inherits
      IG's δ and with it the δ-adaptive serving machinery;
  (c) the path-ensemble methods equal a hand-rolled mean over the same
      deterministic samples;
  (d) registries (methods + baselines) fail loudly with valid names listed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import CONFIG as CNN_CONFIG
from repro.core import baselines, ig, methods, schedule, smooth
from repro.core.api import Explainer
from repro.models import cnn

KEY = jax.random.PRNGKey(0)


def quad_f(xs, t):
    return jnp.sum(xs**2, axis=-1)


# --------------------------------------------------------------- (a) IDGI ref


def idgi_hand_reference(f, x, baseline, sched, target):
    """Straight-line IDGI, written the way the formula reads: for each node
    α_k (python loop, no scan/chunks), g_k = ∇f(x(α_k)), the node's tangent
    f-difference d_k = ⟨g_k, x − x′⟩ w_k is split over features ∝ g_k²."""
    B = x.shape[0]
    alphas = np.asarray(jnp.broadcast_to(sched.alphas, (B, sched.alphas.shape[-1])))
    weights = np.asarray(jnp.broadcast_to(sched.weights, alphas.shape))
    diff = np.asarray(x - baseline, np.float32).reshape(B, -1)
    attr = np.zeros((B, diff.shape[1]), np.float32)
    grad_f = jax.grad(lambda xs, t: f(xs, t).sum())
    for k in range(alphas.shape[1]):
        a = jnp.asarray(alphas[:, k]).reshape((B,) + (1,) * (x.ndim - 1))
        xi = baseline + a.astype(x.dtype) * (x - baseline)
        g = np.asarray(grad_f(xi, target), np.float32).reshape(B, -1)
        s = (g * g).sum(-1)  # ⟨g, g⟩
        p = (g * diff).sum(-1)  # ⟨g, x − x′⟩
        for b in range(B):
            if s[b] > 0.0:
                attr[b] += (weights[b, k] * p[b] / s[b]) * (g[b] * g[b])
    return attr.reshape(x.shape)


def test_idgi_matches_hand_reference_on_paper_cnn():
    params = cnn.init(CNN_CONFIG, KEY)
    f = lambda xs, t: cnn.prob_fn(CNN_CONFIG, params, xs, t)
    s = CNN_CONFIG.image_size
    x = jax.random.uniform(jax.random.fold_in(KEY, 1), (2, s, s, CNN_CONFIG.channels))
    bl = jnp.zeros_like(x)
    t = jnp.asarray([1, 2], jnp.int32)
    ex = Explainer(f, method="idgi", schedule="paper", m=8, n_int=4)
    sched = ex.build_schedule(x, bl, t)
    res = ex.attribute(x, bl, t)
    want = idgi_hand_reference(f, x, bl, sched, t)
    np.testing.assert_allclose(
        np.asarray(res.attributions), want, rtol=1e-4, atol=1e-6
    )


def test_idgi_matches_hand_reference_chunked():
    """Chunked scan == the per-step loop (chunking is invisible to IDGI)."""
    x = jax.random.normal(KEY, (3, 8)) + 1.0
    bl = jnp.zeros_like(x)
    t = jnp.zeros((3,), jnp.int32)

    def f(xs, t):
        return jnp.tanh((xs**2).sum(-1) / 10.0)

    sched = schedule.uniform(16)
    res = ig.attribute(f, x, bl, sched, t, method="idgi", chunk=4)
    want = idgi_hand_reference(f, x, bl, sched, t)
    np.testing.assert_allclose(np.asarray(res.attributions), want, rtol=1e-5, atol=1e-7)


# ------------------------------------------- (b) IDGI totals == IG totals


def test_idgi_total_equals_ig_total():
    """Σ_j φ_idgi == Σ_j φ_ig for any schedule (both equal the quadrature
    Σ_k w_k ⟨g_k, x − x′⟩) — hence identical δ, hence identical δ-adaptive
    behavior. The per-feature DISTRIBUTION differs (that's the point)."""
    x = jax.random.normal(KEY, (4, 12)) + 1.0
    bl = 0.1 * jnp.ones_like(x)
    t = jnp.zeros((4,), jnp.int32)

    def f(xs, t):
        return jnp.tanh((xs**3).sum(-1) / 30.0)

    for name in ("uniform", "paper"):
        ex_ig = Explainer(f, method="ig", schedule=name, m=16, n_int=4)
        ex_id = Explainer(f, method="idgi", schedule=name, m=16, n_int=4)
        r_ig = ex_ig.attribute(x, bl, t)
        r_id = ex_id.attribute(x, bl, t)
        np.testing.assert_allclose(
            np.asarray(r_id.attributions.sum((-1,))),
            np.asarray(r_ig.attributions.sum((-1,))),
            rtol=1e-5,
            atol=1e-6,
        )
        np.testing.assert_allclose(
            np.asarray(r_id.delta), np.asarray(r_ig.delta), rtol=1e-4, atol=1e-6
        )
        assert not np.allclose(
            np.asarray(r_id.attributions), np.asarray(r_ig.attributions)
        ), "IDGI must redistribute attribution, not reproduce IG"


# ------------------------------------------------ (c) ensemble equivalences


def test_noise_tunnel_equals_manual_sample_mean():
    x = jax.random.normal(KEY, (2, 6)) + 1.0
    bl = jnp.zeros_like(x)
    t = jnp.zeros((2,), jnp.int32)
    ex = Explainer(
        quad_f, method="noise_tunnel", schedule="uniform", m=8,
        n_samples=3, sigma=0.2, sample_seed=7,
    )
    res = ex.attribute(x, bl, t)
    # hand-rolled: same deterministic samples (smooth.noise_samples with the
    # explainer's key), one vanilla IG per row, mean per example
    xs = smooth.noise_samples(x, jax.random.PRNGKey(7), 3, 0.2)
    per_row = ig.attribute(
        quad_f, xs, jnp.repeat(bl, 3, axis=0), schedule.uniform(8),
        jnp.repeat(t, 3, axis=0),
    )
    want = np.asarray(per_row.attributions).reshape(2, 3, -1).mean(1)
    np.testing.assert_allclose(
        np.asarray(res.attributions), want.reshape(res.attributions.shape),
        rtol=1e-5, atol=1e-6,
    )


def test_expected_grad_equals_manual_baseline_mean():
    x = jax.random.normal(KEY, (2, 6)) + 1.0
    bl = jnp.zeros_like(x)
    t = jnp.zeros((2,), jnp.int32)
    ex = Explainer(
        quad_f, method="expected_grad", schedule="uniform", m=8,
        n_samples=3, sigma=0.3, sample_seed=11,
    )
    res = ex.attribute(x, bl, t)
    x2, b2 = methods.baseline_expand(x, bl, jax.random.PRNGKey(11), 3, 0.3)
    per_row = ig.attribute(
        quad_f, x2, b2, schedule.uniform(8), jnp.repeat(t, 3, axis=0)
    )
    want = np.asarray(per_row.attributions).reshape(2, 3, -1).mean(1)
    np.testing.assert_allclose(
        np.asarray(res.attributions), want.reshape(res.attributions.shape),
        rtol=1e-5, atol=1e-6,
    )


def test_ensemble_is_deterministic():
    x = jax.random.normal(KEY, (2, 6))
    bl = jnp.zeros_like(x)
    t = jnp.zeros((2,), jnp.int32)
    ex = Explainer(quad_f, method="noise_tunnel", schedule="uniform", m=8)
    r1, r2 = ex.attribute(x, bl, t), ex.attribute(x, bl, t)
    np.testing.assert_array_equal(
        np.asarray(r1.attributions), np.asarray(r2.attributions)
    )


# ---------------------------------------------------------- (d) registries


def test_methods_registry_errors():
    with pytest.raises(ValueError, match="expected_grad"):
        methods.get("nope")
    for name, spec in methods.METHODS.items():
        assert methods.get(name) is spec
        if spec.forward_only:
            # perturbation class: each method is its own executable class
            # (no shared gradient accumulator), never grad-linear, and
            # carries a positive default mask budget
            assert spec.accum == name
            assert not spec.grad_linear
            assert spec.n_masks > 0
        else:
            assert spec.accum in ("riemann", "idgi")
        # row_spec strips expansion (the serving engine's compiled unit)
        assert spec.row_spec().expand is None
        assert spec.row_spec().accum == spec.accum


def test_baselines_registry_covers_all_and_errors(rng, key):
    # every defined baseline is reachable by name (gaussian/pad_embedding
    # were historically missing from the registry)
    assert set(baselines.BASELINES) == {"black", "white", "gaussian", "pad_embedding"}
    x = jnp.asarray(rng.normal(size=(2, 4)).astype(np.float32))
    assert baselines.get("black")(x).sum() == 0.0
    assert float(baselines.get("white")(x).mean()) == 1.0
    g = baselines.get("gaussian")(x, key, sigma=0.5)
    assert g.shape == x.shape and bool(jnp.isfinite(g).all())
    table = jnp.asarray(rng.normal(size=(7, 4)).astype(np.float32))
    pe = baselines.get("pad_embedding")(table, x, pad_id=3)
    np.testing.assert_array_equal(np.asarray(pe[0]), np.asarray(table[3]))
    with pytest.raises(ValueError, match="valid baselines.*black"):
        baselines.get("transparent")


@pytest.mark.parametrize(
    "bad", ["", "blk", "Black", "zeros", "pad", "gauss", "white "]
)
def test_baselines_unknown_name_lists_valid(bad):
    """The error path names the offender AND enumerates every valid
    registry entry — the message users actually debug from."""
    with pytest.raises(ValueError) as ei:
        baselines.get(bad)
    msg = str(ei.value)
    assert repr(bad) in msg
    for name in baselines.BASELINES:
        assert name in msg
