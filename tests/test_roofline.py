"""Roofline machinery: HLO collective parsing, costing mode, report math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES_BY_NAME
from repro.models.common import COSTING, costing_mode, scan_or_unroll
from repro.roofline import (
    HW_V5E,
    model_flops,
    parse_collective_bytes,
    roofline_report,
)

HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%p0), replica_groups={}
  %ag = f32[256,256]{1,0} all-gather(%ar), dimensions={0}
  %cp = f32[128,256]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  %add = f32[128,256]{1,0} add(%ar, %cp)
  ROOT %rs = f32[16,256]{1,0} reduce-scatter(%add), dimensions={0}
}
"""


def test_parse_collective_bytes_kinds():
    out = parse_collective_bytes(HLO_SAMPLE)
    b = 128 * 256 * 4
    assert out["all-reduce"] == b
    assert out["all-gather"] == b  # operand (the all-reduce result), not output
    assert out["collective-permute"] == b
    assert out["reduce-scatter"] == b
    assert out["total"] == 4 * b


def test_parse_ignores_non_collectives():
    out = parse_collective_bytes("%x = f32[4]{0} add(%a, %b)")
    assert out["total"] == 0


def test_parse_async_start_counted_once():
    hlo = """
  %p0 = f32[64]{0} parameter(0)
  %s = f32[64]{0} all-reduce-start(%p0)
  %d = f32[64]{0} all-reduce-done(%s)
"""
    out = parse_collective_bytes(hlo)
    assert out["all-reduce"] == 64 * 4


# ------------------------------------------------------------ costing mode


def test_costing_mode_unrolls_scan_flops():
    def body(c, _):
        return c @ c, None

    def g(x):
        y, _ = scan_or_unroll(body, x, None, length=8)
        return y

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    # fresh lambdas: jit caches lowering per function object, and the COSTING
    # flag is read at trace time
    from repro.roofline import cost_analysis_dict

    flops_scan = cost_analysis_dict(jax.jit(lambda v: g(v)).lower(x).compile())["flops"]
    with costing_mode():
        flops_unroll = cost_analysis_dict(
            jax.jit(lambda v: g(v)).lower(x).compile()
        )["flops"]
    assert flops_unroll > 6 * flops_scan  # 8 trips vs body-once


def test_scan_or_unroll_equivalence():
    def body(c, x):
        return c + x, c * 2

    xs = jnp.arange(5.0)
    c1, y1 = jax.lax.scan(body, jnp.asarray(0.0), xs)
    with costing_mode():
        c2, y2 = scan_or_unroll(body, jnp.asarray(0.0), xs)
    np.testing.assert_allclose(float(c1), float(c2))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2))


def test_costing_mode_restores_flag():
    assert not COSTING
    with costing_mode():
        from repro.models import common

        assert common.COSTING
    from repro.models import common

    assert not common.COSTING


# ------------------------------------------------------------ report math


def test_model_flops_train_vs_decode():
    cfg = ARCHS["llama3-8b"]
    tr = model_flops(cfg, SHAPES_BY_NAME["train_4k"])
    de = model_flops(cfg, SHAPES_BY_NAME["decode_32k"])
    n = cfg.param_count()
    np.testing.assert_allclose(tr, 6 * n * 256 * 4096, rtol=1e-6)
    np.testing.assert_allclose(de, 2 * n * 128, rtol=1e-6)


def test_model_flops_moe_uses_active_params():
    cfg = ARCHS["qwen3-moe-30b-a3b"]
    assert cfg.active_param_count() < cfg.param_count() / 5
    f = model_flops(cfg, SHAPES_BY_NAME["train_4k"])
    np.testing.assert_allclose(f, 6 * cfg.active_param_count() * 256 * 4096, rtol=1e-6)


def test_roofline_report_terms():
    rep = roofline_report(
        arch="a",
        shape="train_4k",
        mesh_name="m",
        chips=256,
        cost={"flops": 197e12, "bytes accessed": 819e9},
        coll_bytes_per_chip=50e9,
        mflops=197e12 * 256 * 0.5,
    )
    np.testing.assert_allclose(rep.compute_s, 1.0)
    np.testing.assert_allclose(rep.memory_s, 1.0)
    np.testing.assert_allclose(rep.collective_s, 1.0)
    np.testing.assert_allclose(rep.useful_flops_ratio, 0.5)
    np.testing.assert_allclose(rep.roofline_fraction, 0.5)
    assert rep.dominant in ("compute", "memory", "collective")


def test_param_counts_match_published_sizes():
    """Sanity: analytic param counts land near the advertised model sizes."""
    expect = {
        "llama3-8b": (7.0e9, 9.0e9),
        "gemma3-27b": (25e9, 30e9),
        "qwen3-moe-30b-a3b": (28e9, 32e9),
        "qwen3-moe-235b-a22b": (220e9, 250e9),
        "mamba2-780m": (0.7e9, 0.9e9),
        "jamba-v0.1-52b": (49e9, 56e9),
        "internlm2-20b": (17e9, 22e9),
        "yi-9b": (8e9, 10e9),
        "internvl2-26b": (18e9, 28e9),  # backbone only (frontend stubbed)
        "whisper-tiny": (2e7, 7e7),  # untied embeddings + per-layer cross-attn
    }
    for arch, (lo, hi) in expect.items():
        n = ARCHS[arch].param_count()
        assert lo <= n <= hi, (arch, n / 1e9)
