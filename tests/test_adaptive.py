"""Adaptive iso-convergence: resumable accumulation, nested refinement,
ladder escalation (DESIGN.md §7).

The guarantees under test:
  (a) escalation never discards or corrupts work — running the ladder to a
      rung is BIT-IDENTICAL to one fixed-m run over the materialized nested
      schedule at that rung (same chunking), for a causal LM through the
      serving engine and for a CNN through the core API;
  (b) per-example m_used / hops / convergence flags match a hand-computed
      trace of fixed-m runs over the refined schedules;
  (c) escalation only ever touches the warmed closed set of executables —
      replaying identical traffic performs zero new compilations;
  (d) the escalation batching helpers keep (B, S) on the ladder.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ig, schedule
from repro.core.api import Explainer
from repro.core.schedule import Schedule
from repro.configs import ARCHS, reduced
from repro.configs.paper_cnn import CONFIG as CNN_CONFIG
from repro.models import cnn
from repro.models.registry import Model
from repro.serve import ExplainEngine, ExplainRequest
from repro.serve.batching import pad_rows

KEY = jax.random.PRNGKey(0)


def quad_f(xs, t):
    return jnp.sum(xs**2, axis=-1)


def _materialize_ladder(ex: Explainer, x, bl, t, hops: int) -> Schedule:
    """The nested schedule a full-ladder run lands on: base build + refines."""
    fam = schedule.family(ex.schedule)
    sched = ex.build_schedule(x, bl, t)
    a = jnp.broadcast_to(sched.alphas, (x.shape[0], sched.alphas.shape[-1]))
    w = jnp.broadcast_to(sched.weights, a.shape)
    sched = Schedule(a, w)
    for _ in range(hops):
        sched = fam.refine(sched)
    return sched


# ------------------------------------------------- (a) bit-identity, core


@pytest.mark.parametrize("schedule_name", ["uniform", "paper"])
def test_full_ladder_bit_identical_to_fixed_run(schedule_name):
    """tol=0 never converges -> every example rides the whole ladder; the
    result must equal one fixed run over the final nested schedule, bit for
    bit (old weights halve by exact power-of-two scaling and chunk
    boundaries align at every rung)."""

    def f(xs, t):  # curved enough that delta > 0 at every rung
        return jnp.tanh((xs**2).sum(-1) / 10.0)

    x = jax.random.normal(KEY, (3, 8)) + 1.0
    bl = jnp.zeros_like(x)
    t = jnp.zeros((3,), jnp.int32)
    ex = Explainer(f, schedule=schedule_name, m=4, n_int=2)
    res, info = ex.attribute_adaptive(x, bl, t, tol=0.0, m_max=16)
    assert list(info["m_used"]) == [16, 16, 16] and list(info["hops"]) == [2, 2, 2]
    assert not info["converged"].any()

    final = _materialize_ladder(ex, x, bl, t, hops=2)
    fixed = ig.attribute(f, x, bl, final, t, chunk=ex.adaptive_chunk)
    np.testing.assert_array_equal(
        np.asarray(res.attributions), np.asarray(fixed.attributions)
    )
    # δ reuses the rung-0 endpoint forwards, which this eager reference
    # recomputes — identical math, but eager-vs-compiled can differ by 1 ulp
    np.testing.assert_allclose(
        np.asarray(res.delta), np.asarray(fixed.delta), atol=1e-6
    )


def test_full_ladder_bit_identical_cnn():
    """Same guarantee on the paper CNN (conv stack, randomly initialized)."""
    params = cnn.init(CNN_CONFIG, KEY)
    f = lambda xs, t: cnn.prob_fn(CNN_CONFIG, params, xs, t)
    s = CNN_CONFIG.image_size
    x = jax.random.uniform(jax.random.fold_in(KEY, 1), (2, s, s, CNN_CONFIG.channels))
    bl = jnp.zeros_like(x)
    t = jnp.zeros((2,), jnp.int32)
    ex = Explainer(f, schedule="paper", m=4, n_int=2)
    res, info = ex.attribute_adaptive(x, bl, t, tol=0.0, m_max=8)
    assert list(info["m_used"]) == [8, 8]

    final = _materialize_ladder(ex, x, bl, t, hops=1)
    fixed = ig.attribute(f, x, bl, final, t, chunk=ex.adaptive_chunk)
    np.testing.assert_array_equal(
        np.asarray(res.attributions), np.asarray(fixed.attributions)
    )


# ------------------------------------------- (b) hand-computed trace, core


def test_m_used_and_hops_match_hand_trace():
    """Replay the ladder by hand with fixed-m runs over the refined
    schedules; the adaptive loop's per-example exit rungs must agree."""

    def f(xs, t):
        return jnp.tanh((xs**2).sum(-1) / 8.0)

    # spread of magnitudes -> examples converge at different rungs
    x = jax.random.normal(KEY, (4, 6)) * jnp.asarray([[0.4], [0.9], [1.4], [2.2]])
    bl = jnp.zeros_like(x)
    t = jnp.zeros((4,), jnp.int32)
    tol, m_max = 2e-3, 32
    ex = Explainer(f, schedule="paper", m=4, n_int=2)
    res, info = ex.attribute_adaptive(x, bl, t, tol=tol, m_max=m_max)

    ladder = schedule.m_ladder(4, m_max)
    fixed = {
        m: ig.attribute(
            f, x, bl, _materialize_ladder(ex, x, bl, t, hops=j), t,
            chunk=ex.adaptive_chunk,
        )
        for j, m in enumerate(ladder)
    }
    thr = tol * np.abs(np.asarray(res.f_x) - np.asarray(res.f_baseline))
    for b in range(4):
        exit_rung, exit_hops = ladder[-1], len(ladder) - 1
        for j, m in enumerate(ladder):
            if float(fixed[m].delta[b]) <= thr[b]:
                exit_rung, exit_hops = m, j
                break
        assert info["m_used"][b] == exit_rung, (b, info["m_used"], exit_rung)
        assert info["hops"][b] == exit_hops
        assert info["converged"][b] == (float(fixed[exit_rung].delta[b]) <= thr[b])
        # the example's final numbers are the rung-of-exit numbers
        np.testing.assert_array_equal(
            np.asarray(res.attributions)[b], np.asarray(fixed[exit_rung].attributions)[b]
        )
    assert info["total_steps"] == int(np.sum(info["m_used"]))
    # steady state: a second call against the same cache compiles nothing
    cache = {}
    ex.attribute_adaptive(x, bl, t, tol=tol, m_max=m_max, cache=cache)
    _, info2 = ex.attribute_adaptive(x, bl, t, tol=tol, m_max=m_max, cache=cache)
    assert info2["compiles"] == 0


# --------------------------------------------------- engine (causal LM)


@pytest.fixture(scope="module")
def lm():
    cfg = reduced(ARCHS["llama3-8b"])
    model = Model(cfg)
    params = model.init(KEY)
    return cfg, model, params


def _requests(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [
        ExplainRequest(
            tokens=rng.integers(1, cfg.vocab_size, s).astype(np.int32),
            target=int(rng.integers(0, cfg.vocab_size)),
        )
        for s in lens
    ]


def test_engine_full_ladder_bit_identical_lm(lm):
    """Serving-engine escalation (causal LM): full-ladder output equals a
    fixed run over the materialized nested schedule on the same bucket.

    The reduced LM runs in bfloat16, where eager-vs-compiled fusion
    differences are far above 1 ulp — so the fixed-run reference must ride
    the same compiled machinery. A fixed run over schedule S IS a single hop
    from a zero accumulator (state_scale·0 == 0), which reuses the engine's
    own hop code path at n_new = m_final.
    """
    cfg, model, params = lm
    reqs = _requests(cfg, (11, 9, 12, 10))  # one (4, 16) bucket
    eng = ExplainEngine(
        cfg, params, schedule="paper", m=4, n_int=4, adaptive=True, tol=0.0, m_max=16
    )
    out = eng.explain(reqs, return_raw=True)
    assert all(o["m_used"] == 16 and o["hops"] == 2 for o in out)

    from repro.serve.batching import plan_buckets

    bb = plan_buckets(
        reqs, seq_buckets=eng.seq_buckets, batch_buckets=eng.batch_buckets, pad_id=0
    )[0]
    args = eng._bucket_inputs(bb)
    embeds, baseline, aux, mask = args
    chunk = eng._explainer.adaptive_chunk
    start, _ = eng._executable(
        ("start", bb.bucket, "riemann", "paper", 4, 4, chunk, ()),
        eng.stats.bucket(bb.bucket),
        eng._start_fn,
        args,
    )
    res0, state0, sched = start(*args)
    fam = schedule.family("paper")
    for _ in range(2):
        sched = fam.refine(sched)
    zero_state = ig.IGState(
        jnp.zeros_like(state0.acc), state0.f_x, state0.f_baseline
    )
    fixed_args = (embeds, baseline, aux, mask, sched, zero_state)
    fixed_fn, _ = eng._executable(
        ("hop", bb.bucket, "riemann", 16, chunk, ()),
        eng.stats.hop_bucket(bb.bucket),
        eng._hop_fn,
        fixed_args,
    )
    fixed, _ = fixed_fn(*fixed_args)
    per_token = np.asarray(fixed.attributions.sum(-1))
    for row, o in enumerate(out):
        np.testing.assert_array_equal(o["raw_token_scores"], per_token[row])
        np.testing.assert_array_equal(
            np.float32(o["delta"]), np.float32(fixed.delta[row])
        )


def test_engine_adaptive_stats_and_results(lm):
    cfg, _, params = lm
    reqs = _requests(cfg, (9, 17, 24, 12), seed=3)
    eng = ExplainEngine(
        cfg, params, schedule="paper", m=8, n_int=4, adaptive=True, tol=1e-2, m_max=32
    )
    out = eng.explain(reqs)
    a = eng.stats.adaptive
    assert a.requests == len(reqs)
    assert a.total_steps == sum(o["m_used"] for o in out)
    assert a.converged == sum(o["converged"] for o in out)
    assert a.m_used == {
        m: sum(1 for o in out if o["m_used"] == m) for m in {o["m_used"] for o in out}
    }
    assert a.early_exits == sum(
        1 for o in out if o["converged"] and o["m_used"] < eng.m_ladder[-1]
    )
    for o in out:
        assert o["m_used"] in eng.m_ladder
        assert o["hops"] == eng.m_ladder.index(o["m_used"])
        assert o["converged"] == (o["delta"] <= o["threshold"])
        # engine never spends the full ladder on an already-converged request
        if o["m_used"] > eng.m_ladder[0]:
            assert o["hops"] >= 1


def test_engine_adaptive_zero_recompiles_on_replay(lm):
    """Identical traffic replays the identical escalation path -> every
    start and hop executable is a cache hit (the §7 zero-recompile gate)."""
    cfg, _, params = lm
    reqs = _requests(cfg, (9, 17, 24, 12, 9, 30), seed=5)
    eng = ExplainEngine(
        cfg, params, schedule="paper", m=8, n_int=4, adaptive=True, tol=5e-3, m_max=32
    )
    eng.explain(reqs)
    misses = eng.stats.misses
    assert misses == eng.stats.compiles  # plan buckets + hop buckets
    eng.explain(reqs)
    assert eng.stats.misses == misses, "replayed traffic must never recompile"


def test_engine_adaptive_matches_fixed_when_tol_loose(lm):
    """A huge tolerance converges everything at rung 0 -> identical numbers
    to the non-adaptive engine at m = base rung."""
    cfg, _, params = lm
    reqs = _requests(cfg, (9, 17), seed=7)
    ad = ExplainEngine(
        cfg, params, schedule="paper", m=8, n_int=4, adaptive=True, tol=1e6
    )
    fx = ExplainEngine(cfg, params, schedule="paper", m=8, n_int=4)
    out_a = ad.explain(reqs)
    out_f = fx.explain(reqs)
    for oa, of in zip(out_a, out_f):
        assert oa["m_used"] == 8 and oa["hops"] == 0 and oa["converged"]
        np.testing.assert_allclose(oa["token_scores"], of["token_scores"], atol=1e-6)
        np.testing.assert_allclose(oa["delta"], of["delta"], atol=1e-6)


# ------------------------------------------------------- (d) ladder helpers


def test_pad_rows_and_m_ladder():
    assert pad_rows([3, 5], (1, 2, 4)) == ([3, 5], 2)
    assert pad_rows([3, 5, 6], (1, 2, 4)) == ([3, 5, 6, 6], 4)
    assert pad_rows([1], None) == ([1], 1)
    assert schedule.m_ladder(8, 64) == (8, 16, 32, 64)
    assert schedule.m_ladder(8, 8) == (8,)
    assert schedule.m_ladder(8, 63) == (8, 16, 32)
    with pytest.raises(AssertionError):
        schedule.m_ladder(8, 4)
