"""Checkpoint fault-tolerance guarantees: atomicity, integrity, retention."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)

KEY = jax.random.PRNGKey(0)


def _tree():
    return {
        "a": jax.random.normal(KEY, (16, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.ones((3,), jnp.bfloat16)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    out = restore_checkpoint(str(tmp_path), 3, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_step_picks_newest_valid(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    save_checkpoint(str(tmp_path), 5, t)
    assert latest_step(str(tmp_path)) == 5


def test_corrupted_shard_falls_back(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    save_checkpoint(str(tmp_path), 2, t)
    # corrupt the newest checkpoint's shard
    shard = os.path.join(str(tmp_path), "step_00000002", "shard_00000.npz")
    with open(shard, "r+b") as f:
        f.seek(10)
        f.write(b"\xde\xad\xbe\xef")
    assert latest_step(str(tmp_path)) == 1  # fell back
    out = restore_checkpoint(str(tmp_path), 1, jax.tree.map(jnp.zeros_like, t))
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(t["a"]))


def test_tmp_dirs_ignored(tmp_path):
    """A crash mid-write leaves a .tmp dir; restore must ignore it."""
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp-abc"))
    assert latest_step(str(tmp_path)) == 1


def test_manager_retention_and_async(tmp_path):
    t = _tree()
    cm = CheckpointManager(str(tmp_path), keep_n=2, save_async=True)
    for s in (1, 2, 3, 4):
        cm.save(s, t)
    cm.wait()
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(str(tmp_path)) if d.startswith("step_")
    )
    assert steps == [3, 4]


def test_manager_restore_latest_empty(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    step, tree = cm.restore_latest({"x": jnp.zeros(3)})
    assert step is None


def test_structure_mismatch_rejected(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    with pytest.raises(AssertionError):
        restore_checkpoint(str(tmp_path), 1, {"only_one_leaf": jnp.zeros(3)})
