"""Content-addressed attribution cache + warm-start persistence (ISSUE 10).

The contracts, stated as tests:

  (a) key sensitivity — flipping ANY keyed knob (method, schedule family,
      m, sample seed, baseline id, model params, attention impl, mesh,
      fused) changes ``request_cache_key``; the identical engine + request
      reproduces the identical key; different request bytes never collide;
  (b) replay — a hit is ``np.array_equal`` to the fresh computation, and a
      caller mutating a hit can never corrupt the stored bytes;
  (c) eviction — the LRU byte budget holds after every put, oversize
      entries are refused, counters track hits/misses/evictions;
  (d) warm-start — save/restore round-trips the executable set with ZERO
      compiles on replay; a corrupted shard, a truncated manifest, or an
      engine-context mismatch falls back COLD (warn, never raise, never
      wrong results);
  (e) scheduler admission — a cached explain request completes AT submit
      with no queue slot; only degraded results are never cached.
"""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.serve import ExplainEngine, ExplainRequest, ResultCache
from repro.serve.result_cache import _entry_bytes
from repro.serve.warm_state import load_warm_state, save_warm_state

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def lm():
    from repro.configs import ARCHS, reduced
    from repro.models.registry import Model

    cfg = dataclasses.replace(
        reduced(ARCHS["llama3-8b"]), compute_dtype="float32"
    )
    model = Model(cfg)
    return cfg, model, model.init(KEY)


def _req(cfg, n=7, seed=0, target=3):
    rng = np.random.default_rng(seed)
    return ExplainRequest(
        tokens=rng.integers(1, cfg.vocab_size, n).astype(np.int32),
        target=target,
    )


def _engine(cfg, params, **kw):
    kw.setdefault("m", 4)
    kw.setdefault("n_int", 2)
    kw.setdefault("seq_buckets", (8, 16))
    return ExplainEngine(cfg, params, **kw)


# ------------------------------------------------------- (a) key sensitivity


def test_key_is_deterministic_and_request_sensitive(lm):
    cfg, _, params = lm
    req = _req(cfg)
    k1 = _engine(cfg, params).request_cache_key(req)
    k2 = _engine(cfg, params).request_cache_key(req)
    assert k1 == k2, "same engine identity + request must reproduce the key"
    assert _engine(cfg, params).request_cache_key(_req(cfg, seed=1)) != k1
    assert _engine(cfg, params).request_cache_key(_req(cfg, target=5)) != k1
    assert _engine(cfg, params).request_cache_key(_req(cfg, n=9)) != k1


def test_key_sensitivity_matrix(lm):
    """Every knob the docs/caching.md contract lists must move the key."""
    cfg, model, params = lm
    req = _req(cfg)
    base = _engine(cfg, params).request_cache_key(req)
    variants = {
        "method": dict(method="idgi"),
        "schedule": dict(schedule="uniform"),
        "m": dict(m=8),
        "sample_seed": dict(method="noise_tunnel", sample_seed=1),
        "baseline_pad_id": dict(pad_id=1),
        "attn": dict(attn="flash"),
        "fused": dict(fused=True),
        "adaptive": dict(adaptive=True, tol=1e-2),
    }
    keys = {"base": base}
    for name, kw in variants.items():
        keys[name] = _engine(cfg, params, **kw).request_cache_key(req)
    # a different sample seed only matters to ensemble methods — compare it
    # against the same method at the default seed, not against base
    keys["sample_seed_ref"] = _engine(
        cfg, params, method="noise_tunnel"
    ).request_cache_key(req)
    assert keys["sample_seed"] != keys["sample_seed_ref"]
    del keys["sample_seed"], keys["sample_seed_ref"]
    vals = list(keys.values())
    assert len(set(vals)) == len(vals), (
        f"key collision across knobs: {keys}"
    )


def test_key_covers_model_fingerprint_and_mesh(lm):
    cfg, model, params = lm
    req = _req(cfg)
    base = _engine(cfg, params).request_cache_key(req)
    other_params = model.init(jax.random.PRNGKey(1))
    assert _engine(cfg, other_params).request_cache_key(req) != base, (
        "different weights must never share attribution entries"
    )
    eng = _engine(cfg, params)
    eng._mesh_key = ("data", 2, "model", 1)  # what a dp=2 mesh records
    assert eng.request_cache_key(req) != base


def test_key_ignores_batch_composition(lm):
    """Padding invariance: the key is per-request — co-batched traffic and
    the bucket a request lands in do NOT change it (so a request cached
    from a full batch hits when it arrives alone)."""
    cfg, _, params = lm
    eng = _engine(cfg, params, result_cache=1 << 20)
    reqs = [_req(cfg, n=7), _req(cfg, n=12, seed=2), _req(cfg, n=7, seed=3)]
    batched = eng.explain(reqs)
    solo = eng.explain([reqs[0]])[0]
    assert eng.stats.result_hits >= 1, "solo replay must hit the batched entry"
    np.testing.assert_array_equal(
        solo["token_scores"], batched[0]["token_scores"]
    )


# ------------------------------------------------------------ (b) replay


def test_hit_is_bit_identical_and_tamper_proof(lm):
    cfg, _, params = lm
    eng = _engine(cfg, params, result_cache=1 << 20)
    ref = _engine(cfg, params)
    reqs = [_req(cfg), _req(cfg, n=12, seed=2)]
    first = eng.explain(reqs)
    fresh = ref.explain(reqs)
    hit = eng.explain(reqs)
    assert eng.stats.result_hits == len(reqs)
    for a, b, c in zip(first, hit, fresh):
        np.testing.assert_array_equal(a["token_scores"], b["token_scores"])
        np.testing.assert_array_equal(b["token_scores"], c["token_scores"])
        assert a["delta"] == b["delta"] == c["delta"]
    # caller mutation of a returned hit never reaches the stored bytes
    hit[0]["token_scores"][:] = -1.0
    again = eng.explain([reqs[0]])[0]
    np.testing.assert_array_equal(again["token_scores"], first[0]["token_scores"])


def test_raw_rows_served_from_cache(lm):
    """Entries are stored WITH the raw bucket row, so a hit can serve both
    ``return_raw`` variants regardless of which variant populated it."""
    cfg, _, params = lm
    eng = _engine(cfg, params, result_cache=1 << 20)
    req = _req(cfg)
    plain = eng.explain([req])[0]
    assert "raw_token_scores" not in plain
    raw = eng.explain([req], return_raw=True)[0]
    assert eng.stats.result_hits == 1
    assert raw["raw_token_scores"].shape == (8,)  # padded bucket row


# ------------------------------------------------------------ (c) eviction


def test_lru_eviction_respects_byte_budget():
    entry = {"token_scores": np.ones(64, np.float32)}
    size = _entry_bytes(entry)
    rc = ResultCache(max_bytes=3 * size)
    for i in range(5):
        rc.put(f"k{i}", entry)
        assert rc.bytes <= rc.max_bytes, "budget must hold after EVERY put"
    assert len(rc) == 3 and rc.evictions == 2
    assert rc.get("k0") is None and rc.get("k1") is None  # oldest evicted
    assert rc.get("k4") is not None
    # recency: touching k2 makes k3 the next victim
    rc.get("k2")
    rc.put("k5", entry)
    assert "k3" not in rc and "k2" in rc


def test_oversize_entry_refused():
    rc = ResultCache(max_bytes=128)
    rc.put("big", {"token_scores": np.ones(1024, np.float32)})
    assert len(rc) == 0 and rc.evictions == 1 and rc.bytes == 0


def test_repeat_put_replaces_not_duplicates():
    rc = ResultCache(max_bytes=1 << 20)
    e = {"token_scores": np.ones(8, np.float32)}
    rc.put("k", e)
    b1 = rc.bytes
    rc.put("k", e)
    assert len(rc) == 1 and rc.bytes == b1


# ------------------------------------------------------- (d) warm start


@pytest.fixture(scope="module")
def warmed(lm):
    """One served engine + its saved warm state (module-scoped: compiles)."""
    cfg, _, params = lm
    import tempfile

    eng = _engine(cfg, params, result_cache=1 << 20)
    reqs = [_req(cfg), _req(cfg, n=12, seed=2)]
    out = eng.explain(reqs)
    td = tempfile.mkdtemp()
    save_warm_state(eng, td)
    return cfg, params, eng, reqs, out, td


def test_warm_restore_zero_compiles_and_bit_identical(lm, warmed):
    cfg, params, _, reqs, out, td = warmed
    eng2 = _engine(cfg, params, result_cache=1 << 20)
    rep = load_warm_state(eng2, td)
    assert rep.restored and rep.executables > 0
    replay = eng2.explain(reqs)
    assert eng2.stats.compiles == 0, "restored engine must never compile"
    for a, b in zip(out, replay):
        np.testing.assert_array_equal(a["token_scores"], b["token_scores"])
        assert a["delta"] == b["delta"]


def test_warm_restore_corrupted_shard_falls_back_cold(lm, warmed, tmp_path):
    import os
    import shutil

    cfg, params, _, reqs, _, td = warmed
    broken = str(tmp_path / "warm")
    shutil.copytree(td, broken)
    with open(os.path.join(broken, "executables.pkl"), "r+b") as fh:
        fh.seek(0)
        fh.write(b"\x00" * 16)
    eng2 = _engine(cfg, params)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        rep = load_warm_state(eng2, broken)
    assert not rep.restored and "corrupted" in rep.reason
    assert any("cold" in str(x.message) for x in w)
    # correctness is unaffected: the cold engine still serves (and compiles)
    out = eng2.explain([reqs[0]])
    assert eng2.stats.compiles > 0 and np.isfinite(out[0]["delta"])


def test_warm_restore_context_mismatch_falls_back_cold(lm, warmed):
    cfg, params, _, _, _, td = warmed
    eng2 = _engine(cfg, params, m=8)  # different m -> different context
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        rep = load_warm_state(eng2, td)
    assert not rep.restored and "context" in rep.reason
    assert eng2._cache == {}


def test_warm_restore_save_cycle_preserves_executables(lm, warmed, tmp_path):
    """restore -> save must carry the restored executables forward: they have
    no export info (their builder fns never ran) and cannot be re-serialized,
    so the cycle reuses the original blobs instead of shrinking the state."""
    import json
    import os

    cfg, params, _, reqs, out, td = warmed
    eng2 = _engine(cfg, params, result_cache=1 << 20)
    assert load_warm_state(eng2, td).restored
    resaved = str(tmp_path / "warm2")
    save_warm_state(eng2, resaved)
    with open(os.path.join(resaved, "manifest.json")) as fh:
        n = json.load(fh)["n_executables"]
    assert n == len(eng2._cache) > 0, "restore->save shrank the warm state"
    eng3 = _engine(cfg, params, result_cache=1 << 20)
    rep = load_warm_state(eng3, resaved)
    assert rep.restored and rep.executables == n
    replay = eng3.explain(reqs)
    assert eng3.stats.compiles == 0
    for a, b in zip(out, replay):
        np.testing.assert_array_equal(a["token_scores"], b["token_scores"])


def test_warm_restore_missing_dir_is_quiet_cold(lm, tmp_path):
    cfg, _, params = lm
    eng = _engine(cfg, params)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        rep = load_warm_state(eng, str(tmp_path / "nope"))
    assert not rep.restored and rep.reason == "no warm state"
    assert not w, "a first boot has no warm state — that is not a warning"


# -------------------------------------------------- (e) scheduler admission


def test_scheduler_cached_explain_completes_at_admission(lm):
    from repro.runtime.fault import FaultConfig
    from repro.serve import MixedScheduler

    cfg, _, params = lm
    eng = _engine(cfg, params, result_cache=1 << 20)
    sched = MixedScheduler(
        eng, max_len=16, decode_chunk=2,
        fault_cfg=FaultConfig(max_retries=1, backoff_base_s=0.0),
    )
    req = _req(cfg)
    t1 = sched.submit(req)
    sched.run_until_idle()
    assert t1.status == "done"
    t2 = sched.submit(req)
    assert t2.status == "done", "a cached request completes AT admission"
    assert sched.queue_depth == 0, "hits never occupy a queue slot"
    np.testing.assert_array_equal(
        t1.result["token_scores"], t2.result["token_scores"]
    )
    assert "raw_token_scores" not in t2.result


def test_degraded_results_never_cached(lm):
    from repro.runtime.fault import FaultConfig
    from repro.serve import MixedScheduler

    cfg, _, params = lm
    eng = _engine(cfg, params, result_cache=1 << 20)
    sched = MixedScheduler(
        eng, max_len=16, decode_chunk=2,
        fault_cfg=FaultConfig(max_retries=1, backoff_base_s=0.0),
    )

    def poison(kind, payload):
        if kind.startswith("exp"):
            raise RuntimeError("injected")

    sched.fault_hook = poison
    req = _req(cfg, seed=9)
    t1 = sched.submit(req)
    sched.run_until_idle()
    assert t1.status == "degraded"
    sched.fault_hook = None
    t2 = sched.submit(req)
    sched.run_until_idle()
    assert t2.status == "done" and not t2.result["degraded"], (
        "the fault-path zero vector must not be replayed from the cache"
    )
