"""Shared fixtures. NOTE: never set xla_force_host_platform_device_count
here — smoke tests and benches must see the 1 real CPU device; only
``repro.launch.dryrun`` (its own process) requests 512 placeholders.
"""
import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
