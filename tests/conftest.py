"""Shared fixtures. NOTE: never set xla_force_host_platform_device_count
here — smoke tests and benches must see the 1 real CPU device; only
``repro.launch.dryrun`` (its own process) requests 512 placeholders.
"""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture()
def rng():
    """Per-test-seeded numpy Generator: every RNG-dependent test draws from
    its own fixed stream, so failures reproduce regardless of which other
    tests ran (no shared global numpy state)."""
    return np.random.default_rng(0)
