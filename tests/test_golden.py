"""Golden regression: per-method paper-CNN attributions vs checked-in
fixtures (tests/golden/cnn_<method>.npz, produced by tools/make_golden.py).

Engine / schedule / serving refactors are free to reorganize HOW the numbers
are computed — these tests pin WHAT comes out. Tolerance bands absorb
benign fusion/reduction-order drift (rtol 1e-3 against values ~1e-3..1e-1,
plus a small atol floor for near-zero pixels); anything beyond that is a
behavior change and must regenerate the fixtures deliberately.
"""
import os

import numpy as np
import pytest

from repro.core.methods import METHODS

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

# generation-config mirror of tools/make_golden.py (kept in the tool)
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
from make_golden import (  # noqa: E402
    golden_explainer,
    golden_inputs,
    golden_perturb_result,
)

RTOL = 1e-3
ATOL = 1e-5


@pytest.fixture(scope="module")
def pipeline():
    return golden_inputs()


@pytest.mark.parametrize("method", sorted(METHODS))
def test_golden_attributions(method, pipeline):
    path = os.path.join(GOLDEN_DIR, f"cnn_{method}.npz")
    assert os.path.exists(path), (
        f"missing golden fixture {path} — run PYTHONPATH=src python "
        "tools/make_golden.py and commit the result"
    )
    want = np.load(path)
    f, x, bl, t = pipeline
    if METHODS[method].forward_only:
        # perturbation class: cell-grid scores from the SAME seeded CNN and
        # batch (tolerance bands identical — the class boundary changes how
        # the numbers are computed, not how tightly they are pinned)
        res = golden_perturb_result(f, x, bl, t, method)
    else:
        res = golden_explainer(f, method).attribute(x, bl, t)
    got = np.asarray(res.attributions, np.float32)
    assert got.shape == want["attributions"].shape
    atol = ATOL + RTOL * float(np.abs(want["attributions"]).max())
    np.testing.assert_allclose(
        got, want["attributions"], rtol=RTOL, atol=atol,
        err_msg=f"{method} attributions drifted beyond the golden band",
    )
    np.testing.assert_allclose(
        np.asarray(res.f_x, np.float32), want["f_x"], rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(res.f_baseline, np.float32), want["f_baseline"], rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(res.delta, np.float32), want["delta"], rtol=1e-2, atol=1e-4
    )
