"""Serving engine + explanation service integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models.registry import Model
from repro.serve import ExplainRequest, ExplainService, ServeEngine

KEY = jax.random.PRNGKey(0)


def _engine(arch="llama3-8b", max_len=48):
    cfg = reduced(ARCHS[arch])
    model = Model(cfg)
    params = model.init(KEY)
    return cfg, params, ServeEngine(cfg, params, max_len=max_len)


def test_generate_shapes_and_range():
    cfg, params, eng = _engine()
    batch = {"tokens": jax.random.randint(KEY, (3, 16), 0, cfg.vocab_size)}
    out = eng.generate(batch, 8)
    assert out.shape == (3, 8)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))


def test_generate_matches_stepwise_forward():
    """Greedy engine output == argmax of the full forward each step."""
    cfg, params, eng = _engine(max_len=32)
    model = Model(cfg)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    out = np.asarray(eng.generate({"tokens": toks}, 4))
    cur = toks
    for i in range(4):
        h, _ = model.forward_hidden(params, {"tokens": cur})
        nxt = np.asarray(jnp.argmax(model.logits(params, h[:, -1]), axis=-1))
        np.testing.assert_array_equal(out[:, i], nxt, err_msg=f"token {i}")
        cur = jnp.concatenate([cur, jnp.asarray(nxt)[:, None]], axis=1)


def test_generate_num_tokens_zero_is_empty():
    """Regression: num_tokens=0 must return (B, 0), not smuggle out the
    free prefill token."""
    cfg, params, eng = _engine()
    batch = {"tokens": jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)}
    out = eng.generate(batch, 0)
    assert out.shape == (2, 0)
    assert out.dtype == jnp.int32


def test_generate_sampling_honors_key_and_temperature():
    """Regression: the serve step ignored its greedy flag, so sampled
    serving silently decoded greedily. Sampling must differ from greedy at
    high temperature yet stay reproducible under the same key."""
    cfg, params, eng = _engine(max_len=24)
    batch = {"tokens": jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)}
    greedy = np.asarray(eng.generate(batch, 8))
    k = jax.random.PRNGKey(7)
    s1 = np.asarray(eng.generate(batch, 8, key=k, temperature=8.0))
    s2 = np.asarray(eng.generate(batch, 8, key=k, temperature=8.0))
    np.testing.assert_array_equal(s1, s2)  # same key -> same draw
    assert not np.array_equal(s1, greedy)  # hot sampling is not argmax
    s3 = np.asarray(eng.generate(batch, 8, key=jax.random.PRNGKey(8),
                                 temperature=8.0))
    assert not np.array_equal(s1, s3)  # different key -> different draw
    assert bool(jnp.all((jnp.asarray(s1) >= 0)))
    assert s1.shape == (2, 8)


def test_explain_service_paper_vs_uniform():
    cfg = reduced(ARCHS["llama3-8b"])
    model = Model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(0)
    reqs = [
        ExplainRequest(tokens=rng.integers(0, cfg.vocab_size, 12).astype(np.int32), target=5)
        for _ in range(3)
    ]
    out_p = ExplainService(cfg, params, schedule="paper", m=16, n_int=4).explain(reqs)
    out_u = ExplainService(cfg, params, schedule="uniform", m=16).explain(reqs)
    for o in out_p + out_u:
        assert o["token_scores"].shape == (12,)
        assert np.isfinite(o["token_scores"]).all()
        assert np.isfinite(o["delta"])
    # completeness sanity: sum of scores approximates f_x - f_baseline
    o = out_p[0]
    np.testing.assert_allclose(
        o["token_scores"].sum(), o["f_x"] - o["f_baseline"], atol=max(4 * o["delta"], 0.2)
    )


@pytest.mark.parametrize("arch", ["mamba2-780m", "qwen3-moe-30b-a3b"])
def test_explain_service_other_families(arch):
    """IG applies to SSM (attention-free) and MoE families unchanged."""
    cfg = reduced(ARCHS[arch])
    model = Model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(1)
    reqs = [ExplainRequest(tokens=rng.integers(0, cfg.vocab_size, 8).astype(np.int32), target=3)]
    out = ExplainService(cfg, params, schedule="paper", m=8, n_int=4).explain(reqs)
    assert np.isfinite(out[0]["token_scores"]).all()
