"""Property tests (hypothesis) for the schedule layer — the paper's core.

Invariants:
  * every schedule's weights sum to 1 (it discretizes ∫_0^1);
  * alphas lie in [0, 1] and are sorted;
  * `paper` integer allocation: sums to m, >= min_steps everywhere;
  * `paper`/`warp`/`gauss` integrate smooth functions at least as well as a
    crude bound; exactness on constants (completeness of the Riemann sum);
  * largest-remainder rounding is fair (each interval within 1 of quota).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import schedule

MAX_EXAMPLES = 50


def _boundary_vals(draw_vals):
    return jnp.asarray(draw_vals, jnp.float32)


@st.composite
def boundary_values(draw, min_n=2, max_n=12):
    n = draw(st.integers(min_n, max_n))
    vals = draw(
        st.lists(
            st.floats(-10, 10, allow_nan=False, width=32), min_size=n + 1, max_size=n + 1
        )
    )
    return np.asarray(vals, np.float32)


@st.composite
def m_and_boundaries(draw):
    vals = draw(boundary_values())
    n = len(vals) - 1
    m = draw(st.integers(n, 256))
    return m, vals


# ----------------------------------------------------------------- uniform


@pytest.mark.parametrize("rule", ["midpoint", "left", "right", "trapezoid"])
@pytest.mark.parametrize("m", [1, 2, 7, 64])
def test_uniform_weights_sum_to_one(rule, m):
    # m=1 trapezoid regression: both "endpoint halvings" used to land on the
    # single node, producing Σw == 0.25.
    s = schedule.uniform(m, rule)
    np.testing.assert_allclose(s.weights.sum(), 1.0, rtol=1e-5)
    assert s.alphas.shape == (m,)
    assert float(s.alphas.min()) >= 0.0 and float(s.alphas.max()) <= 1.0


# ------------------------------------------------------------- allocation


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(m_and_boundaries())
def test_paper_allocation_sums_to_m(mb):
    m, vals = mb
    imp = schedule.normalized_deltas(jnp.asarray(vals))
    alloc = schedule.allocate_steps(imp, m, min_steps=1)
    assert int(alloc.sum()) == m
    assert int(alloc.min()) >= 1


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(m_and_boundaries())
def test_largest_remainder_fairness(mb):
    """Each interval's integer allocation is within 1 of its exact quota."""
    m, vals = mb
    imp = np.asarray(schedule.normalized_deltas(jnp.asarray(vals)))
    n = len(imp)
    alloc = np.asarray(schedule.allocate_steps(jnp.asarray(imp), m, min_steps=1))
    quota = imp * (m - n) + 1  # min_steps=1 baseline + proportional budget
    assert np.all(alloc >= np.floor(quota) - 1e-6)
    assert np.all(alloc <= np.ceil(quota) + 1e-6)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(m_and_boundaries())
def test_paper_schedule_invariants(mb):
    m, vals = mb
    s = schedule.paper(jnp.asarray(vals), m)
    a, w = np.asarray(s.alphas), np.asarray(s.weights)
    assert a.shape == (m,) and w.shape == (m,)
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-4)
    assert np.all(a >= 0) and np.all(a <= 1)
    assert np.all(np.diff(a) >= -1e-6), "paper schedule must be sorted"
    assert np.all(w > 0)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(m_and_boundaries())
def test_warp_schedule_invariants(mb):
    m, vals = mb
    s = schedule.warp(jnp.asarray(vals), m)
    a, w = np.asarray(s.alphas), np.asarray(s.weights)
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-4)
    assert np.all(a >= 0) and np.all(a <= 1 + 1e-6)
    assert np.all(np.diff(a) >= -1e-6)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(m_and_boundaries())
def test_gauss_schedule_invariants(mb):
    m, vals = mb
    n = len(vals) - 1
    if m < n:
        m = n
    s = schedule.gauss(jnp.asarray(vals), m)
    a, w = np.asarray(s.alphas), np.asarray(s.weights)
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-4)
    assert np.all(a >= 0) and np.all(a <= 1)


# --------------------------------------------------- quadrature exactness


@pytest.mark.parametrize("method", ["uniform", "paper", "warp", "gauss"])
def test_exact_on_constants(method):
    """∫ c dα == c — the completeness axiom at the schedule level."""
    vals = jnp.asarray([0.0, 0.3, 0.9, 1.0, 1.0])  # 4 intervals
    m = 32
    if method == "uniform":
        s = schedule.uniform(m)
    else:
        s = getattr(schedule, method)(vals, m)
    integral = float(jnp.sum(s.weights * 5.0))
    np.testing.assert_allclose(integral, 5.0, rtol=1e-5)


@pytest.mark.parametrize("method", ["paper", "warp", "gauss"])
def test_integrates_smooth_function(method):
    """Non-uniform schedules integrate exp(-x) to reasonable accuracy."""
    vals = jnp.asarray([0.0, 0.6, 0.85, 0.95, 1.0])
    m = 64
    s = getattr(schedule, method)(vals, m)
    est = float(jnp.sum(s.weights * jnp.exp(-s.alphas)))
    true = 1.0 - np.exp(-1.0)
    assert abs(est - true) < 2e-3, (method, est, true)


def test_gauss_beats_midpoint_on_smooth():
    vals = jnp.asarray([0.0, 0.5, 1.0])
    m = 16
    f = lambda a: jnp.sin(3 * a)
    true = (1 - np.cos(3.0)) / 3.0
    for lo, hi in [("uniform", "gauss")]:
        s_lo = schedule.uniform(m)
        s_hi = schedule.gauss(vals, m)
        err_lo = abs(float(jnp.sum(s_lo.weights * f(s_lo.alphas))) - true)
        err_hi = abs(float(jnp.sum(s_hi.weights * f(s_hi.alphas))) - true)
        assert err_hi < err_lo


def test_sqrt_power_softens_allocation():
    """Paper §III: sqrt attenuates the bias vs linear weighting."""
    vals = jnp.asarray([0.0, 0.9, 0.95, 1.0, 1.0])  # one dominant interval
    m = 64
    lin = schedule.normalized_deltas(vals, power=1.0)
    sq = schedule.normalized_deltas(vals, power=0.5)
    a_lin = schedule.allocate_steps(lin, m, min_steps=0)
    a_sq = schedule.allocate_steps(sq, m, min_steps=0)
    assert int(a_sq.min()) >= int(a_lin.min())
    assert int(a_sq.max()) <= int(a_lin.max())


def test_flat_region_fallback_uniform():
    """All-flat probe values -> uniform importance, no NaNs."""
    vals = jnp.zeros((5,))
    imp = np.asarray(schedule.normalized_deltas(vals))
    np.testing.assert_allclose(imp, 0.25, rtol=1e-6)


def test_batched_schedules():
    vals = jnp.asarray([[0.0, 0.5, 1.0], [0.0, 0.9, 1.0]])
    s = schedule.paper(vals, 16)
    assert s.alphas.shape == (2, 16)
    np.testing.assert_allclose(np.asarray(s.weights.sum(-1)), 1.0, rtol=1e-4)


def test_from_boundaries_padding():
    """Zero-width (padding) intervals receive zero steps."""
    bounds = jnp.asarray([[0.0, 0.5, 1.0, 1.0]])  # last interval zero-width
    vals = jnp.asarray([[0.0, 0.7, 1.0, 1.0]])
    s = schedule.from_boundaries(bounds, vals, 16)
    a = np.asarray(s.alphas[0])
    assert np.all(a <= 1.0)
    np.testing.assert_allclose(np.asarray(s.weights.sum(-1)), 1.0, rtol=1e-4)
