"""Forward-only perturbation class: core unit contracts + serving paths.

The conformance grid (tests/test_conformance.py -k fwd) proves the class
properties — masked zeros, padding invariance, bit-exact replay. This file
covers the machinery AROUND those properties:

  (a) core/perturb plumbing: chunked scan == single-shot, f_x probe reuse,
      the image<->cell view pair is exactly invertible, and the loud error
      paths (wrong class in either direction, unknown mask method);
  (b) engine serving: forward-only requests ride the bucketed executable
      cache with zero steady-state recompiles, pad positions score exactly
      zero, and the adaptive ladder refuses the class at construction;
  (c) scheduler: forward-only explain traffic defaults to the preemptible
      BATCH class and completes with finite scores.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core import ig, perturb, schedule
from repro.models.registry import Model
from repro.runtime.fault import FaultConfig
from repro.serve import ExplainEngine, ExplainRequest, MixedScheduler

KEY = jax.random.PRNGKey(0)

FWD_METHODS = ("occlusion", "rise", "lime")


def _f(xs, t):
    # position-weighted nonlinearity over (N, S, E) — cheap but not linear
    w = 1.0 + jnp.arange(xs.shape[1], dtype=jnp.float32)[None, :, None]
    return jnp.tanh((w * xs).sum((-2, -1)) / 8.0) + 0.01 * (xs**2).sum((-2, -1))


def _inputs(B, S, E=3, seed=0):
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (B, S, E))
    t = jnp.zeros((B,), jnp.int32)
    return x, jnp.zeros_like(x), t


# ------------------------------------------------------- (a) core plumbing


@pytest.mark.parametrize("method", FWD_METHODS)
def test_chunked_scan_matches_single_shot(method):
    """chunk is a memory knob, not a numerics knob: any divisor of P gives
    the same scores to float tolerance (f32 reduction-order drift only —
    lime's band is wider because the drift passes through the normal-eq
    solve, which amplifies it by the system's conditioning)."""
    x, bl, t = _inputs(2, 10)
    full = perturb.PerturbExplainer(_f, method=method, n_masks=8, seed=3)
    res = full.attribute(x, bl, t)
    rtol = 1e-3 if method == "lime" else 1e-5
    for chunk in (2, 4):
        chunked = perturb.PerturbExplainer(
            _f, method=method, n_masks=8, seed=3, chunk=chunk
        ).attribute(x, bl, t)
        np.testing.assert_allclose(
            np.asarray(chunked.attributions), np.asarray(res.attributions),
            rtol=rtol, atol=1e-6,
        )


@pytest.mark.parametrize("method", FWD_METHODS)
def test_f_x_probe_reuse(method):
    """Passing a known f(x) endpoint skips the x-probe and changes nothing:
    the serving path hands the decode-donated probe straight in."""
    x, bl, t = _inputs(2, 8)
    pe = perturb.PerturbExplainer(_f, method=method, n_masks=8, seed=1)
    pm = pe.masks_for(2, 8)
    base = perturb.attribute_from_masks(_f, x, bl, t, pm, method=method)
    reused = perturb.attribute_from_masks(
        _f, x, bl, t, pm, method=method, f_x=_f(x, t)
    )
    np.testing.assert_allclose(
        np.asarray(reused.attributions), np.asarray(base.attributions),
        rtol=1e-6, atol=1e-7,
    )
    np.testing.assert_allclose(
        np.asarray(reused.f_x), np.asarray(base.f_x), rtol=1e-6, atol=0
    )


def test_image_cell_views_are_inverse():
    x = jax.random.uniform(KEY, (2, 8, 8, 3))
    cells = perturb.image_to_cells(x, 4)
    assert cells.shape == (2, 4, 4 * 4 * 3)
    back = perturb.cells_to_image(cells, (8, 8, 3), 4)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
    # cell_fn(f) over the cell view == f over the image, exactly
    f_img = lambda xs, t: xs.sum((1, 2, 3))
    fc = perturb.cell_fn(f_img, (8, 8, 3), 4)
    t = jnp.zeros((2,), jnp.int32)
    np.testing.assert_array_equal(np.asarray(fc(cells, t)), np.asarray(f_img(x, t)))
    # score broadcast: every pixel of a cell carries its cell's score
    scores = jnp.arange(2 * 4, dtype=jnp.float32).reshape(2, 4)
    px = perturb.cell_scores_to_pixels(scores, (8, 8, 3), 4)
    assert px.shape == x.shape
    assert float(px[1, 0, 0, 0]) == float(scores[1, 0])
    assert float(px[1, 5, 5, 2]) == float(scores[1, 3])


def test_occlusion_masks_cover_every_position():
    for S, P in ((7, 4), (16, 16), (5, 8)):
        z = np.asarray(perturb.occlusion_masks(S, P))
        assert z.shape == (P, S)
        # width-⌈S/P⌉ windows tile the sequence, repeating cyclically so the
        # mask batch is always exactly P (shape pure in (S, P)): every
        # position is occluded (z == 0) by ≥ 1 window, with cycle-uniform
        # multiplicity (max − min ≤ 1 full repeats), and no window is wider
        # than ⌈S/P⌉
        per_pos = (z == 0.0).sum(0)
        assert (per_pos >= 1).all()
        window = -(-S // P)
        n_win = -(-S // window)
        assert per_pos.max() - per_pos.min() <= (1 if P % n_win else 0)
        assert ((z == 0.0).sum(1) <= window).all()


def test_class_boundaries_fail_loudly():
    x, bl, t = _inputs(1, 6)
    pm = perturb.PerturbExplainer(_f, method="rise", n_masks=4).masks_for(1, 6)
    with pytest.raises(ValueError, match="gradient-based"):
        perturb.attribute_from_masks(_f, x, bl, t, pm, method="ig")
    with pytest.raises(ValueError, match="forward-only"):
        ig.attribute(_f, x, bl, schedule.uniform(4), t, method="rise")
    with pytest.raises(ValueError, match="unknown perturbation method"):
        perturb.draw_masks("saliency", jax.random.PRNGKey(0)[None], 6, 4)


# ------------------------------------------------------ (b) engine serving


@pytest.fixture(scope="module")
def lm():
    cfg = reduced(ARCHS["llama3-8b"])
    model = Model(cfg)
    return cfg, model.init(KEY)


def _requests(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [
        ExplainRequest(
            tokens=rng.integers(1, cfg.vocab_size, s).astype(np.int32),
            target=int(rng.integers(0, cfg.vocab_size)),
        )
        for s in lens
    ]


@pytest.mark.parametrize("method", FWD_METHODS)
def test_engine_forward_only_zero_recompiles(lm, method):
    cfg, params = lm
    eng = ExplainEngine(
        cfg, params, method=method, n_masks=8, seq_buckets=(8, 16)
    )
    assert eng.n_masks == 8
    reqs = _requests(cfg, (5, 9, 12))
    first = eng.explain(reqs, return_raw=True)
    misses = eng.stats.misses
    assert misses > 0
    # fresh same-shape traffic: pure cache hits, bit-identical replay of
    # the SAME requests (mask keys are pure in request index)
    replay = eng.explain(reqs, return_raw=True)
    assert eng.stats.misses == misses
    for a, b, r in zip(first, replay, reqs):
        assert a["token_scores"].shape == (len(r.tokens),)
        np.testing.assert_array_equal(a["token_scores"], b["token_scores"])
        assert np.isfinite(a["token_scores"]).all()
        # raw bucket rows: exact zeros past the real length
        assert (a["raw_token_scores"][len(r.tokens):] == 0.0).all()


def test_engine_refuses_adaptive_forward_only(lm):
    cfg, params = lm
    with pytest.raises(ValueError, match="forward-only"):
        ExplainEngine(cfg, params, method="occlusion", adaptive=True)


# ----------------------------------------------------------- (c) scheduler


def test_scheduler_forward_only_batch_class(lm):
    cfg, params = lm
    eng = ExplainEngine(
        cfg, params, method="rise", n_masks=8, seq_buckets=(8, 16)
    )
    sched = MixedScheduler(
        eng, max_len=16, decode_chunk=2,
        fault_cfg=FaultConfig(max_retries=1, backoff_base_s=0.0),
    )
    tickets = [
        sched.submit(ExplainRequest(tokens=r.tokens, target=r.target))
        for r in _requests(cfg, (5, 9))
    ]
    # no SLO given: the perturbation class defaults to preemptible BATCH
    assert all(t.slo.name == "batch" for t in tickets)
    sched.run_until_idle()
    for t in tickets:
        assert t.status == "done"
        assert not t.result["degraded"]
        assert np.isfinite(t.result["token_scores"]).all()
