"""MixedScheduler: unified generate+explain serving (ISSUE 8).

Covers the serving-path contracts the mixed gate
(benchmarks/mixed_serving.py) enforces at benchmark scale:

  * donated-endpoint bit-identity with the standalone engine, including
    identical adaptive ``m_used``/``hops``/``converged`` traces;
  * admission control: backpressure, tenant rate limits, poisoned-size
    degradation at submit time;
  * fault injection degrades ONLY the affected requests and the loop keeps
    serving; decode failures keep the emitted prefix; hop failures fall
    back to the last completed rung;
  * δ-aware preemption: queued escalation hops never delay decode;
  * streamed attributions arrive position-ordered and one-per-token.

Everything runs at float32 compute — the donation contract's bit-exact
regime (docs/serving.md).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models.registry import Model
from repro.runtime.fault import FaultConfig
from repro.serve import (
    INTERACTIVE,
    ExplainEngine,
    ExplainRequest,
    GenerateRequest,
    MixedScheduler,
    TenantPolicy,
)

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(0)


def _prompt(n):
    return RNG.integers(1, 512, n).astype(np.int32)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(
        reduced(ARCHS["llama3-8b"]), compute_dtype="float32"
    )
    model = Model(cfg)
    params = model.init(KEY)
    engine = ExplainEngine(
        cfg, params, m=4, n_int=2, seq_buckets=(8, 16),
        adaptive=True, tol=1e-3, m_max=8,
    )
    return cfg, params, engine


def _sched(engine, **kw):
    kw.setdefault("max_len", 16)
    kw.setdefault("decode_chunk", 2)
    kw.setdefault("fault_cfg", FaultConfig(max_retries=1, backoff_base_s=0.0))
    return MixedScheduler(engine, **kw)


def test_donated_endpoint_bit_identical(setup):
    """Decode-path probe == standalone ExplainEngine probe, bit for bit,
    with identical adaptive escalation traces."""
    _, _, engine = setup
    sched = _sched(engine)
    prompts = [_prompt(6), _prompt(7)]
    tickets = [
        sched.submit(GenerateRequest(tokens=p, num_tokens=2, explain=True))
        for p in prompts
    ]
    sched.run_until_idle()
    assert all(t.status == "done" for t in tickets)
    ref = engine.explain([
        ExplainRequest(tokens=p, target=int(t.tokens[0]))
        for p, t in zip(prompts, tickets)
    ])
    for t, r in zip(tickets, ref):
        got = next(a for a in t.attributions if a["pos"] == 0)
        np.testing.assert_array_equal(got["token_scores"], r["token_scores"])
        assert got["delta"] == r["delta"]
        assert got["f_x"] == r["f_x"]
        assert got["f_baseline"] == r["f_baseline"]
        # the scheduled ladder escalates identically to the inline one
        assert (got["m_used"], got["hops"], got["converged"]) == (
            r["m_used"], r["hops"], r["converged"],
        )
        assert not got["degraded"]


def test_streamed_attributions_position_ordered(setup):
    _, _, engine = setup
    sched = _sched(engine)
    t = sched.submit(GenerateRequest(
        tokens=_prompt(6), num_tokens=3, explain=True, explain_stream=True,
    ))
    sched.run_until_idle()
    assert t.status == "done"
    assert t.tokens.shape == (3,)
    assert [a["pos"] for a in t.attributions] == [0, 1, 2]
    for a in t.attributions:
        assert a["token"] == int(t.tokens[a["pos"]])
        # position k attributes prompt + k emitted prefix tokens
        assert a["token_scores"].shape == (6 + a["pos"],)
        assert np.isfinite(a["token_scores"]).all()


def test_fault_degrades_only_affected_bucket(setup):
    """A poisoned explain bucket degrades its own requests to the zero-score
    fallback; co-scheduled requests in other buckets are untouched and the
    loop keeps serving afterwards."""
    _, _, engine = setup
    sched = _sched(engine)
    healthy = [sched.submit(ExplainRequest(tokens=_prompt(6), target=3))
               for _ in range(2)]
    poisoned = sched.submit(ExplainRequest(tokens=_prompt(12), target=3))

    def hook(kind, payload):
        if kind in ("exp_start", "hop", "exp_fixed"):
            bucket = payload.bb.bucket if hasattr(payload, "bb") else payload.bucket
            if bucket[1] == 16:
                raise RuntimeError("injected poison")

    degraded0 = engine.stats.degraded
    sched.fault_hook = hook
    sched.run_until_idle()
    sched.fault_hook = None
    assert poisoned.status == "degraded" and poisoned.degraded
    assert poisoned.result["degraded"]
    np.testing.assert_array_equal(
        poisoned.result["token_scores"], np.zeros(12, np.float32)
    )
    assert engine.stats.degraded > degraded0
    for t in healthy:
        assert t.status == "done" and not t.degraded
        assert np.isfinite(t.result["token_scores"]).all()
    # the engine survived: the same scheduler serves the next request
    again = sched.submit(ExplainRequest(tokens=_prompt(12), target=3))
    sched.run_until_idle()
    assert again.status == "done"


def test_decode_failure_keeps_emitted_prefix(setup):
    _, _, engine = setup
    sched = _sched(engine)
    t = sched.submit(GenerateRequest(tokens=_prompt(6), num_tokens=4))

    def hook(kind, payload):
        if kind == "decode":
            raise RuntimeError("injected decode fault")

    sched.fault_hook = hook
    sched.run_until_idle()
    sched.fault_hook = None
    assert t.status == "degraded"
    # the prefill token was emitted before the decode stream died
    assert t.tokens.shape == (1,)


def test_hop_failure_falls_back_to_completed_rung(setup):
    """An escalation-hop fault degrades the still-active rows to their
    rung-0 attributions — complete, finite, just less converged."""
    _, _, engine = setup
    sched = _sched(engine)
    t = sched.submit(ExplainRequest(tokens=_prompt(6), target=3))

    def hook(kind, payload):
        if kind == "hop":
            raise RuntimeError("injected hop fault")

    sched.fault_hook = hook
    sched.run_until_idle()
    sched.fault_hook = None
    assert t.status == "degraded"
    r = t.result
    assert r["degraded"] and not r["converged"]
    assert r["m_used"] == engine.m and r["hops"] == 0
    assert np.isfinite(r["token_scores"]).all()
    assert np.abs(r["token_scores"]).sum() > 0  # rung 0 stood, not zeroed


def test_hops_are_preempted_by_decode(setup):
    """With escalation hops queued, a newly admitted interactive generate
    dispatches ahead of them and the deferral is counted."""
    _, _, engine = setup
    sched = _sched(engine)
    preempted0 = engine.stats.preempted
    sched.submit(ExplainRequest(tokens=_prompt(6), target=3))
    while not any(k == "hop" for _, _, k, _ in sched._heap):
        assert sched.step(), "ladder converged before any hop was queued"
    t = sched.submit(GenerateRequest(
        tokens=_prompt(7), num_tokens=2, slo=INTERACTIVE,
    ))
    sched.run_until_idle()
    assert t.status == "done"
    assert engine.stats.preempted > preempted0


def test_backpressure_rejects_above_max_queue(setup):
    _, _, engine = setup
    sched = _sched(engine, max_queue=1)
    t1 = sched.submit(GenerateRequest(tokens=_prompt(6), num_tokens=1))
    t2 = sched.submit(GenerateRequest(tokens=_prompt(6), num_tokens=1))
    assert t1.status == "queued"
    assert t2.status == "rejected_backpressure"
    assert sched.rejected_backpressure == 1
    sched.run_until_idle()
    assert t1.status == "done"


def test_tenant_rate_limit(setup):
    _, _, engine = setup
    sched = _sched(engine, tenants={"default": TenantPolicy(rate=0.0, burst=1)})
    t1 = sched.submit(ExplainRequest(tokens=_prompt(6), target=1))
    t2 = sched.submit(ExplainRequest(tokens=_prompt(6), target=1))
    assert t1.status == "queued"
    assert t2.status == "rejected_rate"
    assert sched.rejected_rate == 1


def test_poisoned_size_degrades_at_admission(setup):
    """A prompt no bucket or the KV cache can hold must degrade at submit
    time instead of reaching (and killing) the dispatch loop."""
    _, _, engine = setup
    sched = _sched(engine)
    too_long = sched.submit(ExplainRequest(tokens=_prompt(64), target=1))
    assert too_long.status == "degraded"
    overflow = sched.submit(GenerateRequest(tokens=_prompt(12), num_tokens=8))
    assert overflow.status == "degraded"  # 12 + 8 > max_len=16
    assert overflow.tokens.shape == (0,)
    sched.run_until_idle()  # nothing queued explodes


def test_num_tokens_zero_completes_empty(setup):
    _, _, engine = setup
    sched = _sched(engine)
    t = sched.submit(GenerateRequest(tokens=_prompt(6), num_tokens=0))
    assert t.status == "done"
    assert t.tokens.shape == (0,)


def test_zero_steady_state_recompiles(setup):
    """Replaying an identical mixed workload reuses every executable —
    decode and explain are one combined compile set."""
    _, _, engine = setup
    sched = _sched(engine)

    def workload():
        ts = [
            sched.submit(GenerateRequest(tokens=_prompt(6), num_tokens=2,
                                         explain=True)),
            sched.submit(ExplainRequest(tokens=_prompt(7), target=5)),
        ]
        sched.run_until_idle()
        return ts

    workload()
    misses0 = engine.stats.misses
    ts = workload()
    assert engine.stats.misses == misses0
    assert all(t.status == "done" for t in ts)
