"""Property-based conformance suite: every method × every schedule family.

The MethodSpec contract (DESIGN.md §8) is a set of PROPERTIES, not examples —
this suite states each one as a checker and drives it two ways:

  * a deterministic grid over the full METHODS × SCHEDULES cross product
    (always runs, pinning the whole zoo in the tier-1 matrix);
  * hypothesis ``@given`` wrappers over randomized shapes/models/schedules
    (run wherever hypothesis is installed — CI installs it via
    requirements-dev.txt; locally the grid half still covers the product).

Properties:
  P1  completeness on a linear model is EXACT for every method × family
      (δ ≈ 0 at machine precision, any m): linearity is the one regime where
      quadrature error vanishes, so any leak here is a method bug;
  P2  Σw == 1 after ``refine_nested`` — exactly, for arbitrary schedules —
      and old nodes keep their α with exactly-halved weights;
  P3  masked padding positions receive EXACTLY zero attribution (not small:
      zero) for every method, and δ is finite;
  P4  adaptive resume is bit-identical to the fixed-m run over the
      materialized refined schedule, for every method's state pytree ×
      family (the IGState contract that δ-adaptive serving rests on).

Forward-only (perturbation) class properties, over {occlusion, rise, lime}
× bucket shapes (``repro.core.perturb``):
  F1  masked/pad positions receive EXACTLY zero attribution, δ finite;
  F2  batch-composition invariance: a row's scores are bit-identical no
      matter what the other rows of its bucket hold (the padding-row
      discipline the serving engine's bucket padding rests on);
  F3  deterministic replay: masks are a pure function of (seed, bucket
      width, request index), so repeated attribution is bit-exact and a
      different seed actually moves the random-mask methods.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ig, methods, perturb, schedule
from repro.core.api import Explainer
from repro.core.schedule import Schedule

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in CI where it IS present
    HAVE_HYPOTHESIS = False

KEY = jax.random.PRNGKey(0)
# P1-P4 are gradient-class contracts (schedules, δ, IGState resume); the
# forward-only perturbation class has its own property set F1-F3 below
ALL_METHODS = sorted(
    n for n in methods.METHODS if not methods.METHODS[n].forward_only
)
FWD_METHODS = sorted(
    n for n in methods.METHODS if methods.METHODS[n].forward_only
)
ALL_SCHEDULES = sorted(schedule.SCHEDULES)
GRID = [(m, s) for m in ALL_METHODS for s in ALL_SCHEDULES]
FWD_BUCKETS = [(2, 8), (3, 12), (4, 16)]  # (B, S) incl. a non-pow2 width
FWD_GRID = [(m, b) for m in FWD_METHODS for b in FWD_BUCKETS]


def _explainer(f, method, sched_name, m=16, n_int=4, **kw):
    kw.setdefault("n_samples", 2)
    kw.setdefault("sigma", 0.15)
    return Explainer(f, method=method, schedule=sched_name, m=m, n_int=n_int, **kw)


# ----------------------------------------------------- P1: linear exactness


def check_linear_exact(method, sched_name, a, x, tol=2e-4):
    """δ == 0 (machine precision) on f(x) = ⟨a, x⟩ for any schedule/m."""

    def f(xs, t):
        return xs @ a

    bl = jnp.zeros_like(x)
    t = jnp.zeros((x.shape[0],), jnp.int32)
    res = _explainer(f, method, sched_name).attribute(x, bl, t)
    scale = float(jnp.abs(res.f_x - res.f_baseline).max()) + 1.0
    np.testing.assert_allclose(np.asarray(res.delta), 0.0, atol=tol * scale)


@pytest.mark.parametrize("method,sched_name", GRID)
def test_linear_exact_grid(method, sched_name):
    a = jax.random.normal(KEY, (8,))
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (3, 8))
    check_linear_exact(method, sched_name, a, x)


# --------------------------------------------- P2: refine_nested invariants


def check_refine_invariants(alphas, weights):
    """Σw == 1 exactly after refinement; old nodes keep α, weights halve."""
    sched = Schedule(jnp.asarray(alphas, jnp.float32), jnp.asarray(weights, jnp.float32))
    ref = schedule.refine_nested(sched)
    m = sched.alphas.shape[-1]
    assert ref.alphas.shape[-1] == 2 * m
    np.testing.assert_array_equal(
        np.asarray(ref.alphas)[..., :m], np.asarray(sched.alphas)
    )
    np.testing.assert_array_equal(
        np.asarray(ref.weights)[..., :m], 0.5 * np.asarray(sched.weights)
    )
    np.testing.assert_allclose(
        np.asarray(ref.weights.sum(-1)),
        np.asarray(sched.weights.sum(-1)),
        rtol=1e-6,
    )
    a2 = np.asarray(ref.alphas)
    assert np.all((a2 >= 0.0) & (a2 <= 1.0))


@pytest.mark.parametrize("sched_name", ALL_SCHEDULES)
@pytest.mark.parametrize("m", [4, 8, 16])
def test_refine_invariants_grid(sched_name, m):
    def f(xs, t):
        return jnp.tanh((xs**2).sum(-1) / 10.0)

    x = jax.random.normal(KEY, (2, 6)) + 1.0
    ex = Explainer(f, schedule=sched_name, m=m, n_int=2)
    s = ex.build_schedule(x, jnp.zeros_like(x), jnp.zeros((2,), jnp.int32))
    check_refine_invariants(s.alphas, s.weights)


# ------------------------------------------------- P3: exact masked zeros


def check_masked_zero(method, sched_name):
    def f(xs, t):
        return jnp.tanh((xs**2).sum(-1) / 10.0)

    x = jax.random.normal(KEY, (3, 8)) + 1.0
    bl = jnp.zeros_like(x)
    t = jnp.zeros((3,), jnp.int32)
    mask = jnp.asarray(np.tril(np.ones((3, 8), np.float32), k=4))  # ragged
    res = _explainer(f, method, sched_name).attribute(x, bl, t, mask)
    attr = np.asarray(res.attributions)
    assert np.all(attr[np.asarray(mask) == 0.0] == 0.0), "padding must attribute 0"
    assert np.isfinite(np.asarray(res.delta)).all()


@pytest.mark.parametrize("method,sched_name", GRID)
def test_masked_zero_grid(method, sched_name):
    check_masked_zero(method, sched_name)


# --------------------------------------- P4: adaptive resume bit-identity


def check_adaptive_bit_identical(method, sched_name, m0=4, hops=2):
    """Two halves of the §7/§8 resumability contract, per method × family:

    (i)  state-resume bit-identity: accumulating hop-by-hop through the
         method's IGState (state_scale=0.5 per nested doubling) EQUALS one
         fixed run over the final refined schedule — array_equal, not
         allclose (exact pow-2 weight halving + aligned chunk boundaries);
    (ii) ``attribute_adaptive`` at tol=0 rides the full ladder and lands on
         that same fixed result (through its AOT-compiled rungs, where
         eager-vs-compiled fusion may legitimately differ by ulps — so this
         half is allclose at float32 tightness, not bit equality).
    """

    def f(xs, t):
        return jnp.tanh((xs**2).sum(-1) / 10.0)

    x = jax.random.normal(KEY, (3, 8)) + 1.0
    bl = jnp.zeros_like(x)
    t = jnp.zeros((3,), jnp.int32)
    ex = _explainer(f, method, sched_name, m=m0, n_int=2)
    chunk = ex.adaptive_chunk
    fam = schedule.family(sched_name)
    spec = ex.spec

    # the ladder and the fixed run ride the same deterministic expansion
    x2, b2, t2, _, n = ex.expand_inputs(x, bl, t, None)
    sched_ = ex.build_schedule(x2, b2, t2)
    a = jnp.broadcast_to(sched_.alphas, (x2.shape[0], sched_.alphas.shape[-1]))
    sched_ = Schedule(a, jnp.broadcast_to(sched_.weights, a.shape))

    # (i) eager hop-by-hop resume vs eager fixed run: bit-identical
    res_l, state = ig.attribute(
        f, x2, b2, sched_, t2, method=spec, chunk=chunk, return_state=True
    )
    full = sched_
    for h in range(hops):
        refined = fam.refine(full)
        n_old = full.alphas.shape[-1]
        new_nodes = Schedule(
            refined.alphas[:, n_old:], refined.weights[:, n_old:]
        )
        res_l, state = ig.attribute(
            f, x2, b2, new_nodes, t2, method=spec, chunk=chunk,
            state=state, state_scale=0.5, return_state=True,
        )
        full = refined
    fixed = ig.attribute(f, x2, b2, full, t2, method=spec, chunk=chunk)
    np.testing.assert_array_equal(
        np.asarray(res_l.attributions), np.asarray(fixed.attributions)
    )

    # (ii) the compiled adaptive ladder lands on the same result
    res, info = ex.attribute_adaptive(x, bl, t, tol=0.0, m_max=m0 * 2**hops)
    assert set(info["m_used"]) == {m0 * 2**hops}
    fixed_red = ex.reduce_result(fixed, n)
    np.testing.assert_allclose(
        np.asarray(res.attributions),
        np.asarray(fixed_red.attributions),
        rtol=1e-5,
        atol=1e-6,
    )


@pytest.mark.parametrize("method,sched_name", GRID)
def test_adaptive_bit_identical_grid(method, sched_name):
    check_adaptive_bit_identical(method, sched_name)


# ------------------------- F1-F3: forward-only (perturbation) class


def _fwd_f(xs, t):
    # nonlinear, position-dependent: perturbing different positions moves
    # the output by genuinely different amounts
    scale = 1.0 + jnp.arange(xs.shape[1], dtype=jnp.float32)[None, :, None]
    return jnp.sum(jnp.tanh(xs * scale) + 0.1 * xs**2, axis=(1, 2))


def _fwd_inputs(B, S, seed=0):
    x = jax.random.normal(jax.random.fold_in(KEY, 100 + seed), (B, S, 2)) + 1.0
    return x, jnp.zeros_like(x), jnp.zeros((B,), jnp.int32)


def check_fwd_masked_zero(method, B, S, seed=0):
    x, bl, t = _fwd_inputs(B, S, seed)
    lens = [max(1, S - 1 - i) for i in range(B)]  # ragged real widths
    mask = jnp.asarray(
        np.arange(S)[None, :] < np.asarray(lens)[:, None], jnp.float32
    )
    pe = perturb.PerturbExplainer(_fwd_f, method=method, n_masks=8, seed=seed)
    res = pe.attribute(x, bl, t, mask=mask)
    attr = np.asarray(res.attributions)
    assert attr.shape == (B, S)
    assert np.all(attr[np.asarray(mask) == 0.0] == 0.0), "padding must score 0"
    assert np.any(attr[np.asarray(mask) == 1.0] != 0.0), "real positions must move"
    assert np.isfinite(np.asarray(res.delta)).all()


@pytest.mark.parametrize("method,bucket", FWD_GRID)
def test_fwd_masked_zero_grid(method, bucket):
    check_fwd_masked_zero(method, *bucket)


@pytest.mark.parametrize("method,bucket", FWD_GRID)
def test_fwd_batch_composition_invariance(method, bucket):
    """F2: a row's masks are keyed by ITS index alone, and the forward
    batch is row-parallel — swapping the other rows of the bucket leaves a
    row's scores bit-identical (array_equal, not allclose). This is the
    exact property that makes the engine's pad-row duplication sound."""
    B, S = bucket
    x, bl, t = _fwd_inputs(B, S)
    pe = perturb.PerturbExplainer(_fwd_f, method=method, n_masks=8)
    a = np.asarray(pe.attribute(x, bl, t).attributions)
    # replace every row except row 0 with unrelated data
    x2 = x.at[1:].set(jax.random.normal(jax.random.fold_in(KEY, 999), (B - 1, S, 2)))
    b = np.asarray(pe.attribute(x2, bl, t).attributions)
    np.testing.assert_array_equal(a[0], b[0])


@pytest.mark.parametrize("method,bucket", FWD_GRID)
def test_fwd_deterministic_replay(method, bucket):
    B, S = bucket
    x, bl, t = _fwd_inputs(B, S)
    pe = perturb.PerturbExplainer(_fwd_f, method=method, n_masks=8, seed=3)
    r1 = np.asarray(pe.attribute(x, bl, t).attributions)
    r2 = np.asarray(pe.attribute(x, bl, t).attributions)
    np.testing.assert_array_equal(r1, r2)
    # the mask draw is pure in (seed, S, index): a different seed moves the
    # random-mask methods; occlusion windows are deterministic by design
    pm1 = pe.masks_for(B, S)
    pm9 = perturb.PerturbExplainer(
        _fwd_f, method=method, n_masks=8, seed=9
    ).masks_for(B, S)
    if method == "occlusion":
        np.testing.assert_array_equal(np.asarray(pm1.z), np.asarray(pm9.z))
    else:
        assert not np.array_equal(np.asarray(pm1.z), np.asarray(pm9.z))
    # ...and pure in the request index: rows draw DIFFERENT masks
    if method != "occlusion":
        assert not np.array_equal(np.asarray(pm1.z[0]), np.asarray(pm1.z[1]))


# ---------------------------------------------------- hypothesis wrappers

if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        method=st.sampled_from(ALL_METHODS),
        sched_name=st.sampled_from(ALL_SCHEDULES),
        dim=st.integers(2, 16),
        batch=st.integers(1, 4),
        seed=st.integers(0, 2**16),
    )
    def test_linear_exact_hypothesis(method, sched_name, dim, batch, seed):
        k = jax.random.PRNGKey(seed)
        a = jax.random.normal(k, (dim,))
        x = jax.random.normal(jax.random.fold_in(k, 1), (batch, dim))
        check_linear_exact(method, sched_name, a, x)

    @settings(max_examples=40, deadline=None)
    @given(
        m=st.integers(1, 32),
        batch=st.integers(0, 3),
        seed=st.integers(0, 2**16),
        sort=st.booleans(),
    )
    def test_refine_invariants_hypothesis(m, batch, seed, sort):
        rng = np.random.default_rng(seed)
        shape = (batch, m) if batch else (m,)
        alphas = rng.uniform(0.0, 1.0, size=shape).astype(np.float32)
        if sort:
            alphas = np.sort(alphas, axis=-1)
        w = rng.uniform(0.1, 1.0, size=shape).astype(np.float32)
        weights = w / w.sum(-1, keepdims=True)
        check_refine_invariants(alphas, weights)

    @settings(max_examples=10, deadline=None)
    @given(
        method=st.sampled_from(ALL_METHODS),
        sched_name=st.sampled_from(ALL_SCHEDULES),
    )
    def test_adaptive_bit_identical_hypothesis(method, sched_name):
        check_adaptive_bit_identical(method, sched_name, m0=2, hops=1)

    @settings(max_examples=15, deadline=None)
    @given(
        method=st.sampled_from(FWD_METHODS),
        B=st.integers(1, 4),
        S=st.integers(2, 20),
        seed=st.integers(0, 2**16),
    )
    def test_fwd_masked_zero_hypothesis(method, B, S, seed):
        check_fwd_masked_zero(method, B, S, seed)
