"""Fault-tolerance logic against simulated failures: retry/restore/replay,
straggler detection, elastic remesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.runtime import (
    ElasticMesh,
    FaultConfig,
    RetryPolicy,
    StragglerMonitor,
    run_with_recovery,
)


class FlakyStep:
    """Fails deterministically at given step indices, once each."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)
        self.calls = 0

    def __call__(self, state, batch):
        self.calls += 1
        step = int(state["step"])
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise RuntimeError(f"injected failure at step {step}")
        return {"step": state["step"] + 1, "w": state["w"] + batch}, {"loss": float(step)}


class IndexableBatches:
    def __init__(self, n):
        self.n = n

    def batch_at(self, i):
        return jnp.asarray(float(i))

    def __getitem__(self, i):
        return self.batch_at(i)


def test_retry_policy_retries_then_succeeds():
    cfg = FaultConfig(max_retries=3, backoff_base_s=0.0)
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("boom")
        return "ok"

    assert RetryPolicy(cfg)(flaky) == "ok"
    assert calls["n"] == 3


def test_retry_policy_exhausts():
    cfg = FaultConfig(max_retries=2, backoff_base_s=0.0)

    def always():
        raise RuntimeError("dead")

    with pytest.raises(RuntimeError):
        RetryPolicy(cfg)(always)


def test_run_with_recovery_restores_and_replays(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep_n=3)
    state = {"step": jnp.asarray(0), "w": jnp.asarray(0.0)}
    step_fn = FlakyStep(fail_at=(7,))
    batches = IndexableBatches(10)
    final, hist = run_with_recovery(
        step_fn,
        state,
        batches,
        num_steps=10,
        ckpt_manager=cm,
        ckpt_every=2,
        fault_cfg=FaultConfig(max_retries=2, backoff_base_s=0.0),
    )
    assert int(final["step"]) == 10
    # deterministic replay: w == sum over steps each counted once in the
    # final trajectory == sum(range(10)) regardless of the mid-run failure
    assert float(final["w"]) == sum(range(10))


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(FaultConfig(straggler_threshold=2.0, straggler_ewma=0.5))
    for _ in range(5):
        assert not mon.observe(1.0)
    assert mon.observe(5.0)  # straggler
    assert not mon.observe(1.0)  # mean not poisoned
    assert len(mon.flagged) == 1


def test_straggler_warmup_seeds_from_median():
    """Regression: seeding the EWMA from the FIRST observation let a cold
    -compile step (10-100x steady state) poison the mean permanently —
    stragglers never update the mean, so the monitor stayed blind for the
    whole run. The mean must seed from the warmup median instead."""
    mon = StragglerMonitor(FaultConfig(straggler_threshold=2.0, straggler_warmup=3))
    assert not mon.observe(10.0)  # cold compile; warmup never flags
    assert not mon.observe(0.1)
    assert not mon.observe(0.1)
    assert mon.mean == pytest.approx(0.1)  # median, not the 10.0 outlier
    assert not mon.observe(0.1)
    assert mon.observe(0.5)  # a real straggler is visible immediately
    assert len(mon.flagged) == 1


def test_recovery_rollback_clamps_history(tmp_path):
    """Regression: restoring from a checkpoint that PREDATES start_step
    (a manager shared across drivers) computed a negative history cut,
    silently keeping a wrong suffix. The cut must clamp to zero and the
    replayed trajectory must be exactly the post-restore steps."""
    cm = CheckpointManager(str(tmp_path), keep_n=3)
    ck_state = {"step": jnp.asarray(2), "w": jnp.asarray(float(sum(range(2))))}
    cm.save(2, ck_state)
    cm.wait()
    state = {"step": jnp.asarray(5), "w": jnp.asarray(float(sum(range(5))))}
    step_fn = FlakyStep(fail_at=(9,))
    final, hist = run_with_recovery(
        step_fn,
        state,
        IndexableBatches(10),
        num_steps=10,
        ckpt_manager=cm,
        fault_cfg=FaultConfig(max_retries=2, backoff_base_s=0.0),
        start_step=5,
    )
    assert int(final["step"]) == 10
    # history holds ONLY the replayed-from-checkpoint trajectory 2..9; with
    # the negative-slice bug the pre-restore step-5 entry survived the cut
    assert [h["loss"] for h in hist] == [float(s) for s in range(2, 10)]
    assert float(final["w"]) == sum(range(10))


def test_elastic_mesh_shrinks_data_axis():
    em = ElasticMesh(model_size=16, data_size=16, pod_size=2)
    assert em.device_count == 512
    em2 = em.after_loss(400)
    assert em2.model_size == 16  # TP preserved
    assert em2.device_count <= 400
    assert em2.data_size == 12


def test_elastic_mesh_drops_pod_when_starved():
    em = ElasticMesh(model_size=16, data_size=4, pod_size=2)
    em2 = em.after_loss(20)
    assert em2.pod_size == 1
    assert em2.model_size == 16


def test_elastic_mesh_raises_below_tp():
    em = ElasticMesh(model_size=16, data_size=2)
    with pytest.raises(RuntimeError):
        em.after_loss(8)


def test_elastic_batch_rescale_keeps_per_device():
    old = ElasticMesh(model_size=16, data_size=16, pod_size=2)
    new = old.after_loss(400)
    gb = new.rescale_batch(256, old)
    per_old = 256 // (16 * 2)
    assert gb == per_old * new.data_size * new.pod_size


def test_elastic_mesh_builds_jax_mesh():
    em = ElasticMesh(model_size=1, data_size=1)
    mesh = em.make_mesh()
    assert mesh.devices.size == 1
