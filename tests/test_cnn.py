"""paper-cnn smoke: the faithful vision-reproduction model trains + IG runs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import CONFIG
from repro.core.api import Explainer
from repro.models import cnn

KEY = jax.random.PRNGKey(0)


def test_forward_shapes():
    params = cnn.init(CONFIG, KEY)
    imgs = jax.random.uniform(KEY, (2, CONFIG.image_size, CONFIG.image_size, CONFIG.channels))
    logits = cnn.forward(CONFIG, params, imgs)
    assert logits.shape == (2, CONFIG.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_prob_fn_is_probability():
    params = cnn.init(CONFIG, KEY)
    imgs = jax.random.uniform(KEY, (4, 32, 32, 3))
    t = jnp.zeros((4,), jnp.int32)
    p = cnn.prob_fn(CONFIG, params, imgs, t)
    assert p.shape == (4,)
    assert bool(jnp.all((p >= 0) & (p <= 1)))


def test_ig_on_pixels():
    """The paper's exact setting: IG over raw pixels of a classifier."""
    params = cnn.init(CONFIG, KEY)
    f = lambda xs, t: cnn.prob_fn(CONFIG, params, xs, t)
    x = jax.random.uniform(KEY, (2, 32, 32, 3))
    bl = jnp.zeros_like(x)  # black-image baseline
    t = jnp.zeros((2,), jnp.int32)
    ex = Explainer(f, schedule="paper", m=16, n_int=4)
    res = ex.attribute(x, bl, t)
    assert res.attributions.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(res.attributions)))
    # completeness: delta small relative to the prob gap
    assert float(res.delta.max()) < 0.1


def test_cnn_gradient_flow():
    params = cnn.init(CONFIG, KEY)
    imgs = jax.random.uniform(KEY, (2, 32, 32, 3))
    labels = jnp.asarray([1, 2])

    def loss(p):
        lg = cnn.forward(CONFIG, p, imgs)
        return -jnp.mean(jax.nn.log_softmax(lg)[jnp.arange(2), labels])

    g = jax.grad(loss)(params)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))
    assert float(sum(jnp.abs(x).sum() for x in jax.tree.leaves(g))) > 0
