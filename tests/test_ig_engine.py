"""IG engine correctness: completeness, analytic cases, chunking, kernels.

Analytic oracle: for f(x) = <a, x> (linear), IG is exact for ANY schedule:
phi_i = a_i * (x_i - x'_i). For f quadratic the midpoint rule has known
O(1/m^2) error. These pin the engine's math independent of the paper claims.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ig, metrics, probes, schedule, smooth
from repro.core.api import Explainer

KEY = jax.random.PRNGKey(0)


def linear_f(a):
    def f(xs, t):
        return xs @ a

    return f


def quad_f(xs, t):
    return jnp.sum(xs**2, axis=-1)


def test_linear_exact_any_schedule():
    a = jax.random.normal(KEY, (8,))
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (3, 8))
    bl = jnp.zeros_like(x)
    t = jnp.zeros((3,), jnp.int32)
    for m in (1, 4, 16):
        res = ig.attribute(linear_f(a), x, bl, schedule.uniform(m), t)
        np.testing.assert_allclose(
            np.asarray(res.attributions), np.asarray(a * x), rtol=1e-4, atol=1e-5
        )
        np.testing.assert_allclose(np.asarray(res.delta), 0.0, atol=1e-4)


def test_quadratic_exact_under_midpoint():
    """f = Σx²: the IG integrand is LINEAR in α, so midpoint is exact."""
    x = jax.random.normal(KEY, (2, 6)) + 2.0
    bl = jnp.zeros_like(x)
    t = jnp.zeros((2,), jnp.int32)
    for m in (1, 4):
        res = ig.attribute(quad_f, x, bl, schedule.uniform(m), t)
        assert float(res.delta.max()) < 1e-3


def test_cubic_midpoint_convergence():
    """f = Σx³ (quadratic integrand): midpoint delta falls as O(1/m²)."""

    def cubic(xs, t):
        return jnp.sum(xs**3, axis=-1)

    x = jax.random.normal(KEY, (2, 6)) + 2.0
    bl = jnp.zeros_like(x)
    t = jnp.zeros((2,), jnp.int32)
    deltas = []
    for m in (4, 8, 16):
        res = ig.attribute(cubic, x, bl, schedule.uniform(m), t)
        deltas.append(float(res.delta.max()))
    # each doubling of m should cut midpoint error ~4x (allow 3x for slack)
    assert deltas[1] < deltas[0] / 3
    assert deltas[2] < deltas[1] / 3


def test_completeness_delta_matches_metric():
    x = jax.random.normal(KEY, (2, 5))
    bl = jnp.zeros_like(x)
    t = jnp.zeros((2,), jnp.int32)
    res = ig.attribute(quad_f, x, bl, schedule.uniform(32), t)
    d = metrics.convergence_delta(res.attributions, res.f_x, res.f_baseline)
    np.testing.assert_allclose(np.asarray(d), np.asarray(res.delta), rtol=1e-6)


def test_chunking_invariance():
    """chunked scan == single shot, bit-for-bit up to reduction order."""
    x = jax.random.normal(KEY, (2, 10))
    bl = jnp.zeros_like(x)
    t = jnp.zeros((2,), jnp.int32)
    sched = schedule.uniform(16)
    full = ig.attribute(quad_f, x, bl, sched, t, chunk=0)
    chunked = ig.attribute(quad_f, x, bl, sched, t, chunk=4)
    np.testing.assert_allclose(
        np.asarray(full.attributions), np.asarray(chunked.attributions), rtol=1e-5
    )


def test_per_example_schedules():
    """(B, m) schedules: each example follows its own allocation."""
    x = jax.random.normal(KEY, (2, 4))
    bl = jnp.zeros_like(x)
    t = jnp.zeros((2,), jnp.int32)
    vals = probes.boundary_values(quad_f, x, bl, t, n_int=4)
    assert vals.shape == (2, 5)
    sched = schedule.paper(vals, 16)
    assert sched.alphas.shape == (2, 16)
    res = ig.attribute(quad_f, x, bl, sched, t)
    assert float(res.delta.max()) < 0.05


@pytest.mark.parametrize("schedule_name", ["uniform", "paper", "warp", "gauss", "refine"])
def test_explainer_end_to_end(schedule_name):
    def f(xs, t):
        return jnp.tanh((xs**2).sum(-1) / 10.0)

    x = jax.random.normal(KEY, (4, 16))
    bl = jnp.zeros_like(x)
    t = jnp.zeros((4,), jnp.int32)
    ex = Explainer(f, schedule=schedule_name, m=32, n_int=4)
    res = ex.attribute(x, bl, t)
    assert res.attributions.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(res.attributions)))
    assert float(res.delta.max()) < 0.05


def test_explainer_jit_compiles_once():
    def f(xs, t):
        return jnp.sum(xs**2, axis=-1)

    ex = Explainer(f, schedule="paper", m=16, n_int=4)
    jitted = ex.jitted()
    x = jax.random.normal(KEY, (2, 8))
    r1 = jitted(x, jnp.zeros_like(x), jnp.zeros((2,), jnp.int32))
    r2 = jitted(2 * x, jnp.zeros_like(x), jnp.zeros((2,), jnp.int32))
    assert np.isfinite(np.asarray(r2.delta)).all()


def test_paper_beats_uniform_on_saturating_model():
    """The paper's central claim on a saturating model: iso-m, lower delta.

    The transition must be ASYMMETRIC (paper Fig 3 regime): on a symmetric
    sigmoid the midpoint rule wins by error cancellation across the bump.
    """

    def f(xs, t):  # one-sided exponential saturation after a kink at 0.12
        r = jax.nn.relu(xs.mean(-1) - 0.12)
        return 1.0 - jnp.exp(-9.0 * r) + 0.05 * xs.mean(-1)

    x = jnp.ones((4, 16)) + 0.05 * jax.random.normal(KEY, (4, 16))
    bl = jnp.zeros_like(x)
    t = jnp.zeros((4,), jnp.int32)
    m = 16
    d_uniform = float(ig.attribute(f, x, bl, schedule.uniform(m), t).delta.mean())
    vals = probes.boundary_values(f, x, bl, t, n_int=8)
    d_paper = float(ig.attribute(f, x, bl, schedule.paper(vals, m), t).delta.mean())
    assert d_paper < d_uniform, (d_paper, d_uniform)


def test_noise_tunnel_and_multibaseline_compose():
    def f(xs, t):
        return jnp.sum(xs**2, axis=-1)

    x = jax.random.normal(KEY, (2, 6))
    t = jnp.zeros((2,), jnp.int32)
    ex = Explainer(f, schedule="paper", m=16, n_int=4)
    nt = smooth.noise_tunnel(
        lambda xn: ex.attribute(xn, jnp.zeros_like(xn), t), x, KEY, n_samples=2
    )
    assert nt.attributions.shape == x.shape
    mb = smooth.multi_baseline(
        lambda b: ex.attribute(x, b, t), [jnp.zeros_like(x), 0.1 * jnp.ones_like(x)]
    )
    assert mb.attributions.shape == x.shape


def test_insertion_deletion_auc():
    def f(xs, t):
        return xs[:, 0] * 10 + xs[:, 1]  # feature 0 dominates

    x = jnp.ones((1, 4))
    bl = jnp.zeros_like(x)
    t = jnp.zeros((1,), jnp.int32)
    res = ig.attribute(f, x, bl, schedule.uniform(8), t)
    ins, dele = metrics.insertion_deletion_auc(f, x, bl, res.attributions, t, steps=4)
    assert float(ins[0]) > float(dele[0])
