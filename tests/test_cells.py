"""Integration: cell construction lowers+compiles on a real multi-device mesh.

Runs in a SUBPROCESS with xla_force_host_platform_device_count=8 so the main
test process keeps its single CPU device (the dry-run contract).
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeConfig
from repro.launch.cells import build_cell, lower_cell
from repro.models.common import costing_mode
from repro.roofline import cost_analysis_dict, parse_collective_bytes

mesh = jax.make_mesh((2, 4), ("data", "model"))
out = {}
cases = [
    ("llama3-8b", ShapeConfig("t", 64, 8, "train"), {"microbatches": 2}),
    ("qwen3-moe-30b-a3b", ShapeConfig("t", 64, 8, "train"), {"microbatches": 1}),
    ("mamba2-780m", ShapeConfig("d", 256, 8, "decode"), {}),
    ("gemma3-27b", ShapeConfig("p", 256, 8, "prefill"), {}),
    ("whisper-tiny", ShapeConfig("d", 256, 8, "decode"), {}),
]
for arch, shape, kw in cases:
    cfg = reduced(ARCHS[arch])
    with mesh:
        cell = build_cell(cfg, shape, mesh, **kw)
        compiled = lower_cell(cell).compile()
        cost = cost_analysis_dict(compiled)
        with costing_mode():
            kw2 = dict(kw); kw2.pop("microbatches", None)
            cell2 = build_cell(cfg, shape, mesh, **kw2)
            cost2 = cost_analysis_dict(lower_cell(cell2).compile())
    out[f"{arch}:{shape.kind}"] = {
        "flops": cost.get("flops", 0),
        "costing_flops": cost2.get("flops", 0),
        "collectives": parse_collective_bytes(compiled.as_text())["total"],
    }
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def cell_results():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1200,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_cells_compile_on_multi_device_mesh(cell_results):
    assert len(cell_results) == 5
    for k, v in cell_results.items():
        assert v["flops"] > 0, k


def test_costing_mode_counts_more_flops(cell_results):
    """Unrolled costing flops >= scanned flops (scan bodies counted once)."""
    for k, v in cell_results.items():
        assert v["costing_flops"] >= 0.9 * v["flops"], (k, v)


def test_train_cell_has_collectives(cell_results):
    assert cell_results["llama3-8b:train"]["collectives"] > 0
