"""Per-kernel shape/dtype sweeps against the pure-jnp ref oracles.

All kernels run in interpret=True (Pallas kernel body executed in Python on
CPU) — the BlockSpec tiling/grid logic is exactly what a TPU would execute.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ig, schedule
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ig_accum.ops import ig_accum
from repro.kernels.ig_accum.ref import ig_accum_ref
from repro.kernels.interpolate.ops import interpolate as interpolate_k
from repro.kernels.interpolate.ref import interpolate_ref

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------- interpolate


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,K,F", [(1, 1, 8), (2, 7, 300), (3, 8, 512), (2, 16, 1024), (1, 5, 33)]
)
def test_interpolate_matches_ref(B, K, F, dtype):
    x = jax.random.normal(KEY, (B, F)).astype(dtype)
    b = (0.1 * jax.random.normal(jax.random.fold_in(KEY, 1), (B, F))).astype(dtype)
    a = jax.random.uniform(jax.random.fold_in(KEY, 2), (B, K))
    got = interpolate_k(x, b, a)
    want = interpolate_ref(x, b, a)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_interpolate_nd_features():
    """Engine adapter flattens arbitrary feature shapes."""
    x = jax.random.normal(KEY, (2, 3, 5, 7))
    b = jnp.zeros_like(x)
    a = jax.random.uniform(KEY, (4,))
    got = interpolate_k(x, b, a)
    assert got.shape == (2, 4, 3, 5, 7)
    from repro.core.paths import interpolate as engine_ref

    np.testing.assert_allclose(
        np.asarray(got), np.asarray(engine_ref(x, b, a)), rtol=1e-5, atol=1e-6
    )


# ---------------------------------------------------------------- ig_accum


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,K,F", [(1, 1, 8), (2, 7, 300), (4, 8, 512), (2, 9, 1000)])
def test_ig_accum_matches_ref(B, K, F, dtype):
    g = jax.random.normal(KEY, (B, K, F)).astype(dtype)
    w = jax.random.uniform(jax.random.fold_in(KEY, 1), (B, K))
    acc = jax.random.normal(jax.random.fold_in(KEY, 2), (B, F))
    got = ig_accum(acc, g, w)
    want = ig_accum_ref(acc, g, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_kernels_inside_engine():
    """Pallas kernels injected into the IG engine reproduce the jnp path."""

    def f(xs, t):
        return jnp.sum(xs**2, axis=-1)

    x = jax.random.normal(KEY, (2, 64))
    bl = jnp.zeros_like(x)
    t = jnp.zeros((2,), jnp.int32)
    sched = schedule.uniform(8)
    base = ig.attribute(f, x, bl, sched, t)

    def accum_fn(acc, grads, weights):
        return ig_accum(acc, grads, weights)

    fused = ig.attribute(
        f, x, bl, sched, t, interp_fn=interpolate_k, accum_fn=accum_fn
    )
    np.testing.assert_allclose(
        np.asarray(base.attributions), np.asarray(fused.attributions), rtol=1e-4, atol=1e-5
    )


# --------------------------------------------------------- flash attention


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize(
    "B,S,NQ,NKV,D", [(1, 128, 4, 4, 64), (1, 256, 4, 2, 64), (2, 128, 8, 2, 32)]
)
def test_flash_attention_matches_ref(B, S, NQ, NKV, D, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, NQ, S, D))
    k = jax.random.normal(ks[1], (B, NKV, S, D))
    v = jax.random.normal(ks[2], (B, NKV, S, D))
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=64, block_k=64)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-3)


def test_flash_attention_bf16():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, causal=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=5e-2, atol=5e-2
    )


def test_flash_wrapper_model_layout():
    """(B, S, H, D) wrapper output matches blocked_attention used in models."""
    from repro.models.attention import blocked_attention

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 32))
    k = jax.random.normal(ks[1], (2, 256, 2, 32))
    v = jax.random.normal(ks[2], (2, 256, 2, 32))
    got = flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    want = blocked_attention(q, k, v, causal=True, block_q=128, block_k=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-3)
