"""Per-kernel shape/dtype sweeps against the pure-jnp ref oracles.

All kernels run in interpret=True (Pallas kernel body executed in Python on
CPU) — the BlockSpec tiling/grid logic is exactly what a TPU would execute.
"""
import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ig, schedule
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ig_accum.ops import accum_fn_for, ig_accum, ig_accum_idgi
from repro.kernels.ig_accum.ref import ig_accum_idgi_ref, ig_accum_ref
from repro.kernels.interpolate.ops import interpolate as interpolate_k
from repro.kernels.interpolate.ref import interpolate_ref
from repro.kernels.lstsq import ref as lstsq_ref
from repro.kernels.lstsq.ops import prepare_normal_eqs, wls_solve
from repro.kernels.lstsq.ref import wls_solve_ref

KEY = jax.random.PRNGKey(0)

# Parity must hold on UNFRIENDLY shapes — odd, prime, non-pow2 K and F that
# exercise the pad-to-block paths — and under the numerics the deploy targets
# actually use: f32, bf16 (TPU compute dtype), and f64 (x64-enabled hosts).
ODD_SHAPES = [(1, 3, 17), (2, 7, 33), (3, 5, 130), (2, 9, 257)]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.float64]


def _dtype_ctx(dtype):
    """x64 must be enabled around f64 parity cases (and only those)."""
    if dtype == jnp.float64:
        return jax.experimental.enable_x64()
    return contextlib.nullcontext()


def _tol(dtype):
    return {jnp.float32: 1e-5, jnp.float64: 1e-5, jnp.bfloat16: 3e-2}[dtype]


def _ragged_mask(B, F):
    """Ragged real-position mask: row b keeps a different odd prefix."""
    lens = [max(1, (F * (b + 1)) // (B + 1) - b) for b in range(B)]
    m = np.zeros((B, F), np.float32)
    for b, n in enumerate(lens):
        m[b, :n] = 1.0
    return jnp.asarray(m)


# ------------------------------------------------------------- interpolate


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,K,F", [(1, 1, 8), (2, 7, 300), (3, 8, 512), (2, 16, 1024), (1, 5, 33)]
)
def test_interpolate_matches_ref(B, K, F, dtype):
    x = jax.random.normal(KEY, (B, F)).astype(dtype)
    b = (0.1 * jax.random.normal(jax.random.fold_in(KEY, 1), (B, F))).astype(dtype)
    a = jax.random.uniform(jax.random.fold_in(KEY, 2), (B, K))
    got = interpolate_k(x, b, a)
    want = interpolate_ref(x, b, a)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_interpolate_nd_features():
    """Engine adapter flattens arbitrary feature shapes."""
    x = jax.random.normal(KEY, (2, 3, 5, 7))
    b = jnp.zeros_like(x)
    a = jax.random.uniform(KEY, (4,))
    got = interpolate_k(x, b, a)
    assert got.shape == (2, 4, 3, 5, 7)
    from repro.core.paths import interpolate as engine_ref

    np.testing.assert_allclose(
        np.asarray(got), np.asarray(engine_ref(x, b, a)), rtol=1e-5, atol=1e-6
    )


# ---------------------------------------------------------------- ig_accum


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,K,F", [(1, 1, 8), (2, 7, 300), (4, 8, 512), (2, 9, 1000)])
def test_ig_accum_matches_ref(B, K, F, dtype):
    g = jax.random.normal(KEY, (B, K, F)).astype(dtype)
    w = jax.random.uniform(jax.random.fold_in(KEY, 1), (B, K))
    acc = jax.random.normal(jax.random.fold_in(KEY, 2), (B, F))
    got = ig_accum(acc, g, w)
    want = ig_accum_ref(acc, g, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_kernels_inside_engine():
    """Pallas kernels injected into the IG engine reproduce the jnp path."""

    def f(xs, t):
        return jnp.sum(xs**2, axis=-1)

    x = jax.random.normal(KEY, (2, 64))
    bl = jnp.zeros_like(x)
    t = jnp.zeros((2,), jnp.int32)
    sched = schedule.uniform(8)
    base = ig.attribute(f, x, bl, sched, t)

    # the ops wrappers honor the MethodSpec accumulator signature directly
    fused = ig.attribute(
        f, x, bl, sched, t, interp_fn=interpolate_k, accum_fn=ig_accum
    )
    np.testing.assert_allclose(
        np.asarray(base.attributions), np.asarray(fused.attributions), rtol=1e-4, atol=1e-5
    )


# ------------------------------------- odd shapes × masks × {f32, bf16, f64}


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,K,F", ODD_SHAPES)
def test_interpolate_odd_shapes_masked(B, K, F, dtype):
    with _dtype_ctx(dtype):
        x = jax.random.normal(KEY, (B, F)).astype(dtype)
        b = (0.1 * jax.random.normal(jax.random.fold_in(KEY, 1), (B, F))).astype(dtype)
        a = jax.random.uniform(jax.random.fold_in(KEY, 2), (B, K))
        mask = _ragged_mask(B, F)
        got = interpolate_k(x, b, a, mask=mask)
        pinned = jnp.where(mask.astype(bool), x, b)
        want = interpolate_ref(pinned, b, a)
        tol = _tol(dtype)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol,
        )
        # masked positions sit EXACTLY at the baseline for every alpha
        off = np.asarray(mask) == 0.0
        np.testing.assert_array_equal(
            np.asarray(got, np.float32)[:, :, :][np.broadcast_to(off[:, None, :], got.shape)],
            np.broadcast_to(np.asarray(b, np.float32)[:, None, :], got.shape)[
                np.broadcast_to(off[:, None, :], got.shape)
            ],
        )


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,K,F", ODD_SHAPES)
def test_ig_accum_odd_shapes_masked(B, K, F, dtype):
    with _dtype_ctx(dtype):
        g = jax.random.normal(KEY, (B, K, F)).astype(dtype)
        w = jax.random.uniform(jax.random.fold_in(KEY, 1), (B, K))
        acc = jax.random.normal(jax.random.fold_in(KEY, 2), (B, F)).astype(jnp.float32)
        mask = _ragged_mask(B, F)
        got = ig_accum(acc, g, w, mask=mask)
        want = ig_accum_ref(acc, g * mask[:, None, :].astype(g.dtype), w)
        tol = _tol(dtype)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=tol, atol=tol
        )


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,K,F", ODD_SHAPES)
def test_ig_accum_idgi_odd_shapes_masked(B, K, F, dtype):
    """The IDGI weighting pass: two-pass Pallas vs the einsum oracle, on
    pad-exercising shapes, with ragged masks, under each deploy dtype."""
    with _dtype_ctx(dtype):
        g = jax.random.normal(KEY, (B, K, F)).astype(dtype)
        w = jax.random.uniform(jax.random.fold_in(KEY, 1), (B, K))
        acc = jax.random.normal(jax.random.fold_in(KEY, 2), (B, F)).astype(jnp.float32)
        d = jax.random.normal(jax.random.fold_in(KEY, 3), (B, F)).astype(dtype)
        mask = _ragged_mask(B, F)
        mg = mask[:, None, :].astype(g.dtype)
        got = ig_accum_idgi(acc, g, w, diff=d, mask=mask)
        want = ig_accum_idgi_ref(acc, g * mg, w, d)
        tol = _tol(dtype)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=tol, atol=tol
        )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ig_accum_idgi_friendly_shapes(dtype):
    B, K, F = 2, 8, 512  # no padding: the pure-kernel path
    g = jax.random.normal(KEY, (B, K, F)).astype(dtype)
    w = jax.random.uniform(jax.random.fold_in(KEY, 1), (B, K))
    acc = jnp.zeros((B, F), jnp.float32)
    d = jax.random.normal(jax.random.fold_in(KEY, 3), (B, F)).astype(dtype)
    got = ig_accum_idgi(acc, g, w, diff=d)
    want = ig_accum_idgi_ref(acc, g, w, d)
    tol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_ig_accum_idgi_zero_gradient_rows():
    """⟨g, g⟩ == 0 steps contribute exactly zero, never NaN."""
    g = jnp.zeros((1, 4, 16))
    out = ig_accum_idgi(
        jnp.zeros((1, 16)), g, jnp.ones((1, 4)), diff=jnp.ones((1, 16))
    )
    assert bool(jnp.isfinite(out).all()) and float(jnp.abs(out).sum()) == 0.0


def test_idgi_kernel_inside_engine():
    """Pallas IDGI kernels injected into the IG engine == the jnp method."""

    def f(xs, t):
        return jnp.tanh((xs**2).sum(-1) / 10.0)

    x = jax.random.normal(KEY, (2, 64)) + 1.0
    bl = jnp.zeros_like(x)
    t = jnp.zeros((2,), jnp.int32)
    sched = schedule.uniform(8)
    base = ig.attribute(f, x, bl, sched, t, method="idgi")
    fused = ig.attribute(
        f, x, bl, sched, t, method="idgi",
        interp_fn=interpolate_k, accum_fn=accum_fn_for("idgi"),
    )
    np.testing.assert_allclose(
        np.asarray(base.attributions), np.asarray(fused.attributions),
        rtol=1e-4, atol=1e-6,
    )
    with pytest.raises(ValueError, match="riemann"):
        accum_fn_for("simpson")


# --------------------------------------------------------- flash attention


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize(
    "B,S,NQ,NKV,D", [(1, 128, 4, 4, 64), (1, 256, 4, 2, 64), (2, 128, 8, 2, 32)]
)
def test_flash_attention_matches_ref(B, S, NQ, NKV, D, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, NQ, S, D))
    k = jax.random.normal(ks[1], (B, NKV, S, D))
    v = jax.random.normal(ks[2], (B, NKV, S, D))
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=64, block_k=64)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-3)


def test_flash_attention_bf16():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, causal=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=5e-2, atol=5e-2
    )


def test_flash_wrapper_model_layout():
    """(B, S, H, D) wrapper output matches blocked_attention used in models."""
    from repro.models.attention import blocked_attention

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 32))
    k = jax.random.normal(ks[1], (2, 256, 2, 32))
    v = jax.random.normal(ks[2], (2, 256, 2, 32))
    got = flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    want = blocked_attention(q, k, v, causal=True, block_q=128, block_k=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-3)


# ------------------------------------------------- lstsq (LIME WLS solve)


def _wls_system(B, P, N, dtype, *, seed=0, dup_cols=0):
    """A well-posed weighted design and its normal equations (B, N, N)/(B, N).

    ``dup_cols`` > 0 duplicates trailing design columns — an exactly
    rank-deficient XᵀWX that only the ridge makes solvable."""
    k = jax.random.fold_in(KEY, seed)
    X = jax.random.normal(k, (B, P, N))
    if dup_cols:
        X = X.at[..., -dup_cols:].set(X[..., :dup_cols])
    w = jax.random.uniform(jax.random.fold_in(k, 1), (B, P), minval=0.1)
    y = jax.random.normal(jax.random.fold_in(k, 2), (B, P))
    A, rhs = lstsq_ref.normal_eqs(X, w, y)
    return A.astype(dtype), rhs.astype(dtype)


def _lstsq_tol(dtype):
    # the solve amplifies input error by the (ridge-bounded) condition
    # number, so the bands are wider than the elementwise kernels'
    return {jnp.float32: 1e-3, jnp.float64: 1e-8, jnp.bfloat16: 1e-3}[dtype]


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,P,N", [(1, 9, 3), (2, 21, 7), (3, 40, 17), (2, 50, 22)])
def test_wls_solve_matches_ref_and_lstsq(B, P, N, dtype):
    """Pallas Gauss–Jordan vs the jnp oracle vs ``jnp.linalg.lstsq`` on the
    SAME prepared (ridge-regularized) system — odd / non-pow2 N exercises
    the identity-row padding to the sublane multiple."""
    with _dtype_ctx(dtype):
        A, rhs = _wls_system(B, P, N, dtype)
        ridge = 0.1
        got = wls_solve(A, rhs, ridge=ridge, interpret=True)
        want = wls_solve_ref(A, rhs, ridge=ridge)
        tol = _lstsq_tol(dtype)
        np.testing.assert_allclose(
            np.asarray(got, np.float64), np.asarray(want, np.float64),
            rtol=tol, atol=tol,
        )
        Ap, bp = prepare_normal_eqs(A, rhs, ridge=ridge)
        direct = jnp.stack(
            [jnp.linalg.lstsq(Ap[b], bp[b])[0] for b in range(B)]
        )
        np.testing.assert_allclose(
            np.asarray(got, np.float64), np.asarray(direct, np.float64),
            rtol=10 * tol, atol=10 * tol,
        )


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,P,N", [(2, 21, 7), (3, 40, 17)])
def test_wls_solve_ragged_mask(B, P, N, dtype):
    """Masked (ragged-batch) entries are pinned: β EXACTLY zero there, and
    the valid block solves the same system the oracle solves."""
    with _dtype_ctx(dtype):
        A, rhs = _wls_system(B, P, N, dtype, seed=3)
        mask = _ragged_mask(B, N)
        got = wls_solve(A, rhs, mask=mask, ridge=0.1, interpret=True)
        want = wls_solve_ref(A, rhs, mask=mask, ridge=0.1)
        assert np.all(np.asarray(got)[np.asarray(mask) == 0.0] == 0.0)
        tol = _lstsq_tol(dtype)
        np.testing.assert_allclose(
            np.asarray(got, np.float64), np.asarray(want, np.float64),
            rtol=tol, atol=tol,
        )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_wls_solve_rank_deficient_regularized(dtype, B=2, P=24, N=8):
    """Duplicated design columns make XᵀWX exactly singular; the ridge is
    what makes the system solvable, and the no-pivot sweep must still agree
    with the oracle AND actually satisfy the regularized equations."""
    with _dtype_ctx(dtype):
        A, rhs = _wls_system(B, P, N, dtype, seed=7, dup_cols=2)
        ridge = 0.5
        got = wls_solve(A, rhs, ridge=ridge, interpret=True)
        want = wls_solve_ref(A, rhs, ridge=ridge)
        tol = _lstsq_tol(dtype)
        np.testing.assert_allclose(
            np.asarray(got, np.float64), np.asarray(want, np.float64),
            rtol=tol, atol=tol,
        )
        Ap, bp = prepare_normal_eqs(A, rhs, ridge=ridge)
        resid = jnp.einsum("bij,bj->bi", Ap, got) - bp
        assert float(jnp.abs(resid).max()) < 10 * tol * float(jnp.abs(bp).max() + 1.0)


def test_wls_solve_inside_lime():
    """The kernel drops into the LIME solve hook and reproduces the oracle
    end-to-end (the engine's use_kernels injection point)."""
    from repro.core import perturb

    def f(xs, t):
        return jnp.sum(jnp.tanh(xs), axis=(1, 2))

    x = jax.random.normal(KEY, (2, 12, 3)) + 1.0
    bl = jnp.zeros_like(x)
    t = jnp.zeros((2,), jnp.int32)
    base = perturb.PerturbExplainer(f, method="lime", n_masks=16)
    kern = perturb.PerturbExplainer(
        f, method="lime", n_masks=16,
        solve_fn=lambda A, rhs, **kw: wls_solve(A, rhs, interpret=True, **kw),
    )
    a = np.asarray(base.attribute(x, bl, t).attributions)
    b = np.asarray(kern.attribute(x, bl, t).attributions)
    # elimination order differs from LU under the small default ridge
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)
