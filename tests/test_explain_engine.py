"""ExplainEngine: bucketing/masking correctness + compiled-executable cache.

The guarantees the serving refactor rests on:
  (a) mixed-length batches produce attributions identical to per-length
      unbatched calls — the padding mask changes nothing observable;
  (b) traffic at an already-seen bucket shape performs zero new compilations
      (counted by the engine's jit-wrapper compile counter);
  (c) every registry schedule keeps Σw == 1 and the completeness δ under
      masking, with exactly zero attribution at masked positions;
  (d) every attribution method in the MethodSpec registry serves through the
      engine — fixed-m AND adaptive — with zero steady-state recompiles
      (replayed traffic is pure cache hits), and the per-row compiled unit
      matches the core Explainer on the same embeddings.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core import schedule
from repro.core.api import Explainer
from repro.core.baselines import pad_embedding
from repro.core.methods import METHODS
from repro.models.registry import Model
from repro.serve import ExplainEngine, ExplainRequest
from repro.serve.batching import bucket_for, plan_buckets, pow2_ladder

KEY = jax.random.PRNGKey(0)
MIXED_LENS = (9, 17, 24)


@pytest.fixture(scope="module")
def lm():
    cfg = reduced(ARCHS["llama3-8b"])
    model = Model(cfg)
    params = model.init(KEY)
    return cfg, model, params


def _requests(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [
        ExplainRequest(
            tokens=rng.integers(1, cfg.vocab_size, s).astype(np.int32),
            target=int(rng.integers(0, cfg.vocab_size)),
        )
        for s in lens
    ]


def _engine(cfg, params, **kw):
    kw.setdefault("schedule", "paper")
    kw.setdefault("m", 8)
    kw.setdefault("n_int", 4)
    return ExplainEngine(cfg, params, **kw)


# ------------------------------------------------------- (a) mask correctness


def test_mixed_length_matches_unbatched(lm):
    cfg, model, params = lm
    reqs = _requests(cfg, MIXED_LENS)
    mixed = _engine(cfg, params).explain(reqs)
    f = model.target_logprob_fn(params)
    for i, r in enumerate(reqs):
        # per-length unbatched engine call (same compiled path, B=1 bucket)
        single = _engine(cfg, params).explain([r])[0]
        np.testing.assert_allclose(
            mixed[i]["token_scores"], single["token_scores"], atol=1e-5
        )
        # exact-length jitted reference: no padding, no mask, fixed pos=-1
        e = model.embed_inputs(params, {"tokens": jnp.asarray(r.tokens)[None]})
        bl = pad_embedding(params["embed"]["embedding"], e, pad_id=0)
        ex = Explainer(f, schedule="paper", m=8, n_int=4)
        ref = jax.jit(ex.attribute)(e, bl, jnp.asarray([r.target]))
        np.testing.assert_allclose(
            mixed[i]["token_scores"],
            np.asarray(ref.attributions.sum(-1))[0],
            atol=1e-4,
        )
        np.testing.assert_allclose(mixed[i]["delta"], float(ref.delta[0]), atol=1e-4)


def test_masked_positions_exactly_zero(lm):
    cfg, _, params = lm
    reqs = _requests(cfg, MIXED_LENS, seed=1)
    out = _engine(cfg, params).explain(reqs, return_raw=True)
    for r, o in zip(reqs, out):
        raw = o["raw_token_scores"]
        assert raw.shape == (o["bucket"][1],)
        assert np.all(raw[len(r.tokens) :] == 0.0), "padding must attribute 0"
        assert np.isfinite(o["delta"])


# ------------------------------------------------- (b) zero new compilations


def test_seen_bucket_never_recompiles(lm):
    cfg, _, params = lm
    eng = _engine(cfg, params)
    eng.explain(_requests(cfg, MIXED_LENS, seed=2))
    misses_after_warmup = eng.stats.misses
    assert misses_after_warmup == len(eng.stats.buckets) > 0
    # fresh requests, same shapes -> pure cache hits, zero compiles
    eng.explain(_requests(cfg, MIXED_LENS, seed=3))
    assert eng.stats.misses == misses_after_warmup
    assert eng.stats.hits == misses_after_warmup
    assert all(b.compiles == 1 for b in eng.stats.buckets.values())
    assert eng.stats.hit_rate == 0.5


# ------------------------------- (c) registry invariants under masking


def quad_f(xs, t):
    return jnp.sum(xs**2, axis=-1)


@pytest.mark.parametrize("name", sorted(schedule.SCHEDULES))
def test_registry_schedule_masked_invariants(name):
    x = jax.random.normal(KEY, (3, 8)) + 1.0
    bl = jnp.zeros_like(x)
    t = jnp.zeros((3,), jnp.int32)
    mask = jnp.asarray(np.tril(np.ones((3, 8), np.float32), k=4))  # ragged tail
    ex = Explainer(quad_f, schedule=name, m=16, n_int=4)
    sched = ex.build_schedule(x, bl, t, mask)
    np.testing.assert_allclose(np.asarray(sched.weights.sum(-1)), 1.0, rtol=1e-4)
    res = ex.attribute(x, bl, t, mask)
    attr = np.asarray(res.attributions)
    assert np.all(attr[np.asarray(mask) == 0.0] == 0.0)
    assert float(res.delta.max()) < 0.05
    # δ is over real tokens: completeness against f at the masked input
    masked_x = jnp.where(mask.astype(bool), x, bl)
    gap = np.abs(attr.sum(-1) - np.asarray(quad_f(masked_x, t) - quad_f(bl, t)))
    np.testing.assert_allclose(gap, np.asarray(res.delta), atol=1e-5)


# --------------------------------- (d) method zoo through the serving engine


@pytest.mark.parametrize("method", sorted(METHODS))
def test_method_zoo_zero_steady_state_recompiles(lm, method):
    """Acceptance gate: every registered method serves mixed-length traffic
    through the engine, and replaying fresh same-shape traffic touches only
    warmed executables (ensemble methods included — their noise is a pure
    function of the request indices, so the escalation path replays too)."""
    cfg, _, params = lm
    eng = _engine(cfg, params, method=method, n_samples=2)
    out = eng.explain(_requests(cfg, MIXED_LENS, seed=11))
    misses = eng.stats.misses
    assert misses > 0
    out2 = eng.explain(_requests(cfg, MIXED_LENS, seed=12))
    assert eng.stats.misses == misses, f"{method} recompiled at steady state"
    for o in out + out2:
        assert np.isfinite(o["token_scores"]).all()
        assert np.isfinite(o["delta"]) and np.isfinite(o["f_x"])
    for o, r in zip(out, _requests(cfg, MIXED_LENS, seed=11)):
        assert o["token_scores"].shape == (len(r.tokens),)


@pytest.mark.parametrize(
    "method", sorted(n for n in METHODS if not METHODS[n].forward_only)
)
def test_method_zoo_adaptive_zero_recompiles_on_replay(lm, method):
    cfg, _, params = lm
    reqs = _requests(cfg, (9, 17, 12, 24), seed=13)
    eng = _engine(
        cfg, params, method=method, m=4, adaptive=True, tol=1e-2, m_max=16,
        n_samples=2,
    )
    out = eng.explain(reqs)
    misses = eng.stats.misses
    out2 = eng.explain(reqs)
    assert eng.stats.misses == misses, f"{method} adaptive replay recompiled"
    for o, o2 in zip(out, out2):
        assert o["m_used"] in eng.m_ladder and o["hops"] >= 0
        np.testing.assert_array_equal(o["token_scores"], o2["token_scores"])


def test_engine_idgi_matches_core_explainer(lm):
    """The engine's compiled IDGI unit == the core Explainer on the same
    embeddings (the serving stack adds batching/masking, not math)."""
    cfg, model, params = lm
    (req,) = _requests(cfg, (9,), seed=14)
    out = _engine(cfg, params, method="idgi").explain([req])[0]
    f = model.target_logprob_fn(params)
    e = model.embed_inputs(params, {"tokens": jnp.asarray(req.tokens)[None]})
    bl = pad_embedding(params["embed"]["embedding"], e, pad_id=0)
    ex = Explainer(f, method="idgi", schedule="paper", m=8, n_int=4)
    ref = jax.jit(ex.attribute)(e, bl, jnp.asarray([req.target]))
    np.testing.assert_allclose(
        out["token_scores"], np.asarray(ref.attributions.sum(-1))[0], atol=1e-4
    )
    np.testing.assert_allclose(out["delta"], float(ref.delta[0]), atol=1e-4)


def test_ensemble_engine_result_is_sample_mean(lm):
    """n_samples=1 with sigma→0 degrades noise_tunnel to plain IG — the
    reduction plumbing must be exact in that corner."""
    cfg, _, params = lm
    reqs = _requests(cfg, MIXED_LENS, seed=15)
    nt = _engine(cfg, params, method="noise_tunnel", n_samples=1, sigma=1e-9)
    base = _engine(cfg, params, method="ig")
    out_nt = nt.explain(reqs)
    out_ig = base.explain(reqs)
    for a, b in zip(out_nt, out_ig):
        np.testing.assert_allclose(a["token_scores"], b["token_scores"], atol=1e-4)


# ----------------------------------------------------------- bucket planning


def test_bucket_planning():
    reqs = _requests(type("C", (), {"vocab_size": 64}), (3, 9, 9, 17, 100))
    plan = plan_buckets(reqs, seq_buckets=(8, 16, 32, 128), batch_buckets=(1, 2, 4))
    shapes = {bb.bucket for bb in plan}
    assert shapes == {(1, 8), (2, 16), (1, 32), (1, 128)}
    for bb in plan:
        assert bb.tokens.shape == bb.bucket and bb.mask.shape == bb.bucket
        for row in range(bb.bucket[0]):
            n = bb.lens[row]
            assert bb.mask[row, :n].all() and not bb.mask[row, n:].any()
    served = sorted(i for bb in plan for i in bb.indices)
    assert served == list(range(len(reqs)))


def test_bucket_planning_splits_beyond_batch_ladder():
    """More same-bucket rows than the batch ladder's top rung -> split, not crash."""
    reqs = _requests(type("C", (), {"vocab_size": 64}), (7,) * 9)
    plan = plan_buckets(reqs, seq_buckets=(8,), batch_buckets=(1, 2, 4))
    assert [bb.bucket for bb in plan] == [(4, 8), (4, 8), (1, 8)]
    served = sorted(i for bb in plan for i in bb.indices)
    assert served == list(range(9))


def test_ladders():
    assert pow2_ladder(100) == (8, 16, 32, 64, 128)
    assert bucket_for(9, (8, 16, 32)) == 16
    with pytest.raises(ValueError):
        bucket_for(64, (8, 16, 32))
