"""Batched generation serving on the static-cache engine.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-moe-30b-a3b

Prefill a batch of prompts, decode greedily, report prefill/decode
throughput. Works for every assigned arch family (dense/MoE/SSM/hybrid/VLM).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models.registry import Model
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch])
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["frontend"] = jnp.ones((args.batch, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    if cfg.frontend == "audio":
        batch["frontend"] = jnp.ones((args.batch, cfg.encoder_seq, cfg.frontend_dim), jnp.bfloat16)

    extra = cfg.frontend_tokens if cfg.frontend == "vision" else 0
    engine = ServeEngine(cfg, params, max_len=extra + args.prompt_len + args.tokens)

    t0 = time.perf_counter()
    out = engine.generate(batch, args.tokens)
    jax.block_until_ready(out)
    wall = time.perf_counter() - t0
    total_tokens = args.batch * args.tokens
    print(
        f"arch={cfg.name}: generated {out.shape} in {wall:.2f}s "
        f"({total_tokens/wall:.0f} tok/s incl. compile+prefill)"
    )
    print("sample:", np.asarray(out[0])[:16], "...")


if __name__ == "__main__":
    main()
