"""End-to-end explanation SERVING — the paper's deployment scenario.

    PYTHONPATH=src python examples/explain_serving.py [--arch llama3-8b]

Spins up the ExplainService on a reduced LM, submits batched explanation
requests ("why this next token?"), and reports per-request token scores,
convergence, and wall-clock — paper (NUIG) vs uniform at the same budget,
plus the uniform step count needed to MATCH paper's delta (the iso-
convergence speedup, Fig 6a analogue).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models.registry import Model
from repro.serve import ExplainRequest, ExplainService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--seq", type=int, default=24)
    ap.add_argument("--m", type=int, default=32)
    ap.add_argument("--tol", type=float, default=1e-2,
                    help="relative δ tolerance for the adaptive demo")
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch])
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [
        ExplainRequest(
            tokens=rng.integers(0, cfg.vocab_size, args.seq).astype(np.int32),
            target=int(rng.integers(0, cfg.vocab_size)),
        )
        for _ in range(args.requests)
    ]

    results = {}
    for method in ("paper", "uniform"):
        svc = ExplainService(cfg, params, schedule=method, m=args.m, n_int=4)
        svc.explain(reqs[:1])  # warmup / compile
        t0 = time.perf_counter()
        out = svc.explain(reqs)
        wall = time.perf_counter() - t0
        deltas = [o["delta"] for o in out]
        results[method] = (wall, float(np.mean(deltas)))
        print(
            f"method={method:8s} m={args.m} batch={args.requests} "
            f"wall={wall:.3f}s mean_delta={np.mean(deltas):.5f}"
        )

    # iso-convergence: how many uniform steps match paper's delta?
    target_delta = results["paper"][1]
    for mu in (args.m, 2 * args.m, 4 * args.m, 8 * args.m):
        svc = ExplainService(cfg, params, schedule="uniform", m=mu)
        d = float(np.mean([o["delta"] for o in svc.explain(reqs)]))
        print(f"uniform m={mu}: delta={d:.5f}")
        if d <= target_delta:
            print(f"--> iso-convergence step reduction: {mu}/{args.m} = {mu/args.m:.1f}x")
            break

    top = np.argsort(-np.abs(out[0]["token_scores"]))[:5]
    print("top-5 attributed positions (request 0):", top.tolist())

    # tolerance-driven serving (DESIGN.md §7): don't pick m at all — state
    # the δ you need and let each request climb the m-ladder until it holds.
    base_m = max(4, args.m // 4)  # paper allocation needs >= n_int steps
    print(f"\n-- adaptive: tol={args.tol} relative δ, ladder from m={base_m}")
    svc = ExplainService(
        cfg, params, schedule="paper", m=base_m, n_int=4,
        adaptive=True, tol=args.tol, m_max=max(2 * args.m, 2 * base_m),
    )
    svc.explain(reqs)  # warm every ladder executable this traffic touches
    a = svc.engine.stats.adaptive
    steps0, exits0, reqs0 = a.total_steps, a.early_exits, a.requests
    t0 = time.perf_counter()
    out = svc.explain(reqs)
    wall = time.perf_counter() - t0
    for i, o in enumerate(out[:4]):
        print(
            f"request {i}: m_used={o['m_used']:<4d} hops={o['hops']} "
            f"delta={o['delta']:.5f} (threshold {o['threshold']:.5f}) "
            f"converged={o['converged']}"
        )
    steps = a.total_steps - steps0
    print(
        f"adaptive wall={wall:.3f}s mean_m_used={steps / (a.requests - reqs0):.1f} "
        f"early_exits={a.early_exits - exits0}/{a.requests - reqs0} "
        f"steps={steps} vs fixed-m {args.m}x{len(reqs)}={args.m * len(reqs)}"
    )


if __name__ == "__main__":
    main()
