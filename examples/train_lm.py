"""End-to-end training driver on the full substrate stack.

    PYTHONPATH=src python examples/train_lm.py                # ~10M, quick
    PYTHONPATH=src python examples/train_lm.py --params 100m --steps 300

Exercises: synthetic data pipeline -> sharded/microbatched train_step with
remat -> AdamW + cosine -> async checkpointing -> fault-tolerant driver loop
with straggler monitoring. The --params 100m variant is the "train a ~100M
model for a few hundred steps" deliverable (several hours on this CPU
container; the default is a scaled-down smoke of the same path).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS
from repro.configs.base import ArchConfig, LayerSpec
from repro.data import DataConfig, SyntheticLM
from repro.optim import AdamWConfig
from repro.runtime import FaultConfig, StragglerMonitor, run_with_recovery
from repro.train import TrainConfig, make_train_state, make_train_step

SIZES = {
    # llama-family dims scaled down; all divisible for the production mesh
    "10m": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64, d_ff=704, vocab_size=8192),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", default="10m", choices=sorted(SIZES))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm_ckpt")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        ARCHS["llama3-8b"], name=f"llama-{args.params}", **SIZES[args.params]
    )
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps),
        microbatches=args.microbatches,
        remat=True,
    )
    state = make_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state.params))
    print(f"model: {cfg.name}  params={n/1e6:.1f}M  steps={args.steps}")

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch, seed=0))
    ckpt = CheckpointManager(args.ckpt_dir, keep_n=2, save_async=True)
    start, state = 0, state
    restored_step, restored = ckpt.restore_latest(state)
    if restored_step is not None:
        state, start = restored, restored_step
        print(f"resumed from checkpoint step {start}")

    monitor = StragglerMonitor(FaultConfig())

    def wrapped(state, batch):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        s, m = step_fn(state, b)
        return s, {k: float(v) for k, v in m.items()}

    t0 = time.time()
    state, hist = run_with_recovery(
        wrapped, state, data, num_steps=args.steps,
        ckpt_manager=ckpt, ckpt_every=max(args.steps // 4, 10),
        monitor=monitor, start_step=start,
    )
    dt = time.time() - t0
    losses = [h["loss"] for h in hist]
    print(
        f"done: {len(hist)} steps, {dt/max(len(hist),1)*1e3:.0f} ms/step, "
        f"loss {losses[0]:.3f} -> {losses[-1]:.3f}, stragglers={len(monitor.flagged)}"
    )
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
