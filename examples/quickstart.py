"""Quickstart: Non-Uniform IG (the paper) in five lines of user code.

    PYTHONPATH=src python examples/quickstart.py

Trains the small inception-style classifier, explains a prediction with the
paper's NUIG vs baseline uniform IG, prints the ASCII heatmap and the
convergence deltas at the same step budget (paper Fig 5a in miniature).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import cnn_prob_fn, eval_batch, load_or_train_cnn
from repro.core.api import Explainer


def ascii_heatmap(attr: np.ndarray, width: int = 32) -> str:
    """(H, W) -> shaded ASCII."""
    a = np.abs(attr)
    a = a / (a.max() + 1e-12)
    chars = " .:-=+*#%@"
    return "\n".join(
        "".join(chars[min(int(v * (len(chars) - 1)), len(chars) - 1)] for v in row)
        for row in a
    )


def main():
    params = load_or_train_cnn()
    f = cnn_prob_fn(params)  # f(images, targets) -> target-class probability
    x, targets = eval_batch(1)
    baseline = jnp.zeros_like(x)  # black image = missingness (paper §II)

    m = 32  # total interpolation steps — paper uses 10-30x more for uniform
    for method in ("uniform", "paper"):
        explainer = Explainer(f, schedule=method, m=m, n_int=4)
        res = explainer.attribute(x, baseline, targets)
        print(f"\nmethod={method:8s} m={m} convergence delta={float(res.delta[0]):.5f}")

    heat = np.asarray(res.attributions[0]).sum(-1)  # sum over channels
    print("\nNUIG attribution heatmap (target class {}):".format(int(targets[0])))
    print(ascii_heatmap(heat))
    print("\nThe blob the classifier keys on lights up; the paper's schedule")
    print("reaches the same completeness with a fraction of the steps.")


if __name__ == "__main__":
    main()
