"""§Perf iteration driver: lower one cell with knob overrides, print terms.

    PYTHONPATH=src python tools/perf_iterate.py llama3-8b train_4k \
        --microbatches 4 --grad-compression
    PYTHONPATH=src python tools/perf_iterate.py qwen3-moe-235b-a22b decode_32k \
        --serve-dtype bfloat16

Prints the three roofline terms + top dot shapes so each hypothesis ->
change -> measure cycle is one command. Results are NOT cached (always
fresh); compare against results/dryrun_pod16x16.json baselines.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import time

import jax

from repro.configs import ARCHS, SHAPES_BY_NAME
from repro.launch.cells import build_cell, lower_cell
from repro.launch.mesh import make_production_mesh
from repro.models.common import costing_mode
from repro.roofline import (
    HW_V5E,
    cost_analysis_dict,
    model_flops,
    parse_collective_bytes,
    roofline_report,
)
from repro.roofline.hlo_flops import dot_flops_summary, entry_bytes, entry_bytes_by_op


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--serve-dtype", default="float32")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top-dots", type=int, default=8)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    cfg = ARCHS[args.arch]
    shape = SHAPES_BY_NAME[args.shape]
    kw = {}
    if shape.kind == "train":
        kw = dict(
            microbatches=1,  # costing variant
            remat=not args.no_remat,
            grad_compression=args.grad_compression,
        )
    else:
        kw = dict(serve_dtype=args.serve_dtype)

    t0 = time.time()
    with mesh, costing_mode():
        cell = build_cell(cfg, shape, mesh, **kw)
        compiled = lower_cell(cell).compile()
    hlo = compiled.as_text()
    cost = cost_analysis_dict(compiled)
    coll = parse_collective_bytes(hlo)
    kb = entry_bytes(hlo)
    rep = roofline_report(
        arch=args.arch, shape=args.shape, mesh_name="perf", chips=mesh.devices.size,
        cost={"flops": cost.get("flops", 0), "bytes accessed": kb},
        coll_bytes_per_chip=coll["total"], mflops=model_flops(cfg, shape),
    )
    print(f"\n{args.arch}:{args.shape}  (compile {time.time()-t0:.0f}s, knobs {kw})")
    print(
        f"  compute={rep.compute_s:.4f}s memory={rep.memory_s:.4f}s "
        f"collective={rep.collective_s:.4f}s dominant={rep.dominant}"
    )
    print(
        f"  flops/chip={rep.flops_per_chip:.3e} bytes/chip={kb:.3e} "
        f"coll/chip={coll['total']:.3e} useful={rep.useful_flops_ratio:.3f} "
        f"frac={rep.roofline_fraction:.4f}"
    )
    print("  collectives:", {k: f"{v/2**30:.2f}GiB" for k, v in coll.items() if v})
    s = dot_flops_summary(hlo, top=args.top_dots)
    print(f"  top dots ({s['num_dots']} total, {s['total_dot_flops']:.3e} flops):")
    for r in s["top"]:
        print(f"    {r['frac']*100:5.1f}% x{r['count']:<4d} {r['shape'][:100]}")
    print("  top memory ops:")
    for r in entry_bytes_by_op(hlo, top=args.top_dots):
        print(f"    {r['frac']*100:5.1f}% x{r['count']:<5d} {r['bytes']:.2e}B  {r['op'][:95]}")


if __name__ == "__main__":
    main()
