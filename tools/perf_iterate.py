"""§Perf iteration driver: lower one cell with knob overrides, print terms.

    PYTHONPATH=src python tools/perf_iterate.py llama3-8b train_4k \
        --microbatches 4 --grad-compression
    PYTHONPATH=src python tools/perf_iterate.py qwen3-moe-235b-a22b decode_32k \
        --serve-dtype bfloat16

Prints the three roofline terms + top dot shapes so each hypothesis ->
change -> measure cycle is one command. Results are NOT cached (always
fresh); compare against results/dryrun_pod16x16.json baselines.

Adaptive-explain mode (DESIGN.md §7) measures the OTHER hot path — the
δ-feedback serving ladder — and appends one record per run to the BENCH
trajectory so steps-to-tolerance is tracked alongside latency across
perf-iteration cycles:

    PYTHONPATH=src python tools/perf_iterate.py [llama3-8b] --explain-adaptive \
        [--tol 1e-2 --base-m 8 --m-max 64 --note "my change"]

Trajectory file: results/BENCH_trajectory.jsonl (one JSON object per line).
"""
import os
import sys

# The roofline path wants a big fake device grid; the adaptive-explain path
# runs a real (reduced) model and must keep the true host platform.
if "--explain-adaptive" not in sys.argv:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time

import jax

from repro.configs import ARCHS, SHAPES_BY_NAME
from repro.launch.cells import build_cell, lower_cell
from repro.launch.mesh import make_production_mesh
from repro.models.common import costing_mode
from repro.roofline import (
    HW_V5E,
    cost_analysis_dict,
    model_flops,
    parse_collective_bytes,
    roofline_report,
)
from repro.roofline.hlo_flops import dot_flops_summary, entry_bytes, entry_bytes_by_op


TRAJECTORY = os.path.join(os.path.dirname(__file__), "..", "results", "BENCH_trajectory.jsonl")


def explain_adaptive_bench(args) -> dict:
    """One δ-feedback serving measurement: mixed-length traffic through the
    adaptive ExplainEngine; records steps-to-tolerance AND latency."""
    import numpy as np

    from repro.configs import ARCHS, reduced
    from repro.models.registry import Model
    from repro.serve import ExplainEngine, ExplainRequest

    cfg = reduced(ARCHS[args.arch])
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed)
    reqs = [
        ExplainRequest(
            tokens=rng.integers(1, cfg.vocab_size, int(s)).astype(np.int32),
            target=int(rng.integers(0, cfg.vocab_size)),
        )
        for s in rng.integers(9, 33, size=args.requests)
    ]
    eng = ExplainEngine(
        cfg, params, method=args.method, schedule=args.schedule, m=args.base_m, n_int=4,
        adaptive=True, tol=args.tol, m_max=args.m_max,
    )
    eng.explain(reqs)  # warm every ladder executable this traffic touches
    a = eng.stats.adaptive
    warm = (a.total_steps, a.launched_steps, a.probe_forwards, a.converged,
            a.early_exits, a.requests)
    t0 = time.time()
    out = eng.explain(reqs)
    wall = time.time() - t0
    # report the measured round only — mixing in warm-round counters would
    # inflate steps relative to the measured latency
    steps = a.total_steps - warm[0]
    rec = {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "kind": "explain_adaptive",
        "arch": args.arch,
        "method": args.method,
        "schedule": args.schedule,
        "tol": args.tol,
        "ladder": list(eng.m_ladder),
        "requests": a.requests - warm[5],
        "wall_s": wall,
        "latency_per_req_ms": 1e3 * wall / len(reqs),
        "mean_m_used": steps / max(a.requests - warm[5], 1),
        "total_steps": steps,
        "launched_steps": a.launched_steps - warm[1],
        "probe_forwards": a.probe_forwards - warm[2],
        "converged": a.converged - warm[3],
        "early_exits": a.early_exits - warm[4],
        "m_used_hist": {str(k): v for k, v in sorted(a.m_used.items())},
        "cache_misses": eng.stats.misses,
        "mean_delta": float(np.mean([o["delta"] for o in out])),
        "note": args.note,
    }
    os.makedirs(os.path.dirname(TRAJECTORY), exist_ok=True)
    with open(TRAJECTORY, "a") as fh:
        fh.write(json.dumps(rec) + "\n")
    print(json.dumps(rec, indent=1))
    print(f"-> appended to {os.path.normpath(TRAJECTORY)}")
    return rec


def main():
    # allow_abbrev=False: the XLA_FLAGS guard above matches the literal
    # "--explain-adaptive", so abbreviated spellings must not parse either
    ap = argparse.ArgumentParser(allow_abbrev=False)
    ap.add_argument("arch", nargs="?", default="llama3-8b")
    ap.add_argument("shape", nargs="?")
    ap.add_argument("--explain-adaptive", action="store_true",
                    help="measure δ-feedback explain serving instead of a cell")
    ap.add_argument("--method", default="ig", help="attribution method (core.methods)")
    ap.add_argument("--schedule", default="paper", help="schedule family (core.schedule)")
    ap.add_argument("--tol", type=float, default=1e-2)
    ap.add_argument("--base-m", type=int, default=8)
    ap.add_argument("--m-max", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--note", default="", help="free-form tag for the trajectory record")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--serve-dtype", default="float32")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top-dots", type=int, default=8)
    args = ap.parse_args()

    if args.explain_adaptive:
        explain_adaptive_bench(args)
        return
    if not args.shape:
        ap.error("shape is required unless --explain-adaptive is given")

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    cfg = ARCHS[args.arch]
    shape = SHAPES_BY_NAME[args.shape]
    kw = {}
    if shape.kind == "train":
        kw = dict(
            microbatches=1,  # costing variant
            remat=not args.no_remat,
            grad_compression=args.grad_compression,
        )
    else:
        kw = dict(serve_dtype=args.serve_dtype)

    t0 = time.time()
    with mesh, costing_mode():
        cell = build_cell(cfg, shape, mesh, **kw)
        compiled = lower_cell(cell).compile()
    hlo = compiled.as_text()
    cost = cost_analysis_dict(compiled)
    coll = parse_collective_bytes(hlo)
    kb = entry_bytes(hlo)
    rep = roofline_report(
        arch=args.arch, shape=args.shape, mesh_name="perf", chips=mesh.devices.size,
        cost={"flops": cost.get("flops", 0), "bytes accessed": kb},
        coll_bytes_per_chip=coll["total"], mflops=model_flops(cfg, shape),
    )
    print(f"\n{args.arch}:{args.shape}  (compile {time.time()-t0:.0f}s, knobs {kw})")
    print(
        f"  compute={rep.compute_s:.4f}s memory={rep.memory_s:.4f}s "
        f"collective={rep.collective_s:.4f}s dominant={rep.dominant}"
    )
    print(
        f"  flops/chip={rep.flops_per_chip:.3e} bytes/chip={kb:.3e} "
        f"coll/chip={coll['total']:.3e} useful={rep.useful_flops_ratio:.3f} "
        f"frac={rep.roofline_fraction:.4f}"
    )
    print("  collectives:", {k: f"{v/2**30:.2f}GiB" for k, v in coll.items() if v})
    s = dot_flops_summary(hlo, top=args.top_dots)
    print(f"  top dots ({s['num_dots']} total, {s['total_dot_flops']:.3e} flops):")
    for r in s["top"]:
        print(f"    {r['frac']*100:5.1f}% x{r['count']:<4d} {r['shape'][:100]}")
    print("  top memory ops:")
    for r in entry_bytes_by_op(hlo, top=args.top_dots):
        print(f"    {r['frac']*100:5.1f}% x{r['count']:<5d} {r['bytes']:.2e}B  {r['op'][:95]}")


if __name__ == "__main__":
    main()
