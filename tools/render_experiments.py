"""Assemble EXPERIMENTS.md from results/*.json + the handwritten perf log.

    PYTHONPATH=src python tools/render_experiments.py

Re-run after dry-runs / benchmarks / perf iterations to refresh tables.
"""
from __future__ import annotations

import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(ROOT, "results")


def load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(results: dict, *, full: bool) -> str:
    rows = [
        "| cell | status | HBM GiB/chip (args+temp) | flops/chip | coll GiB/chip | compile s |",
        "|---|---|---|---|---|---|",
    ]
    for key in sorted(results):
        r = results[key]
        if r.get("status") == "skipped":
            rows.append(f"| {key} | skipped — {r.get('reason','')} | | | | |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {key} | ERROR {r.get('error','')[:60]} | | | | |")
            continue
        mem = r.get("memory", {})
        hbm = mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
        flops = r.get("cost", {}).get("flops", 0)
        coll = r.get("collectives", {}).get("total", 0)
        rows.append(
            f"| {key} | ok | {fmt_bytes(hbm)} | {flops:.2e} | {fmt_bytes(coll)} | "
            f"{r.get('seconds','')} |"
        )
    return "\n".join(rows)


def roofline_table(results: dict) -> str:
    rows = [
        "| cell | compute s | memory s | collective s | dominant | 6ND/HLO | roofline frac | bottleneck note |",
        "|---|---|---|---|---|---|---|---|",
    ]
    notes = {
        "train": "weights+activation streaming; raise arithmetic intensity (larger per-chip batch) or cut remat",
        "prefill": "KV/activation streaming at 32k; flash-block fusion keeps scores in VMEM",
        "decode": "reads all weights + KV per token — inherently BW-bound; quantize KV/params to cut bytes",
    }
    for key in sorted(results):
        r = results[key]
        if r.get("status") != "ok":
            continue
        rr = r["roofline"]
        kind = "decode" if "decode" in key or "long" in key else (
            "prefill" if "prefill" in key else "train"
        )
        dominant = rr["dominant"]
        note = notes[kind] if dominant == "memory" else (
            "collective-bound: overlap/compress the grad reduction"
            if dominant == "collective"
            else "compute-bound: good — push MFU via block sizes"
        )
        rows.append(
            f"| {key} | {rr['compute_s']:.4f} | {rr['memory_s']:.4f} | "
            f"{rr['collective_s']:.4f} | **{dominant}** | {rr['useful_ratio']:.2f} | "
            f"{rr['roofline_fraction']:.3f} | {note} |"
        )
    return "\n".join(rows)


def bench_section(bench: dict) -> str:
    if not bench:
        return "_benchmarks.json not found — run `python -m benchmarks.run`_"
    out = []
    pi = bench.get("pathinfo", {})
    out.append(
        f"- **Fig 3 reproduction** (trained inception-style classifier, acc "
        f"{bench.get('cnn_accuracy', 0):.3f}): prob(α=0.25)/prob(1.0) = "
        f"{pi.get('prob_frac_at_025', float('nan')):.2f}; 90% of final confidence reached at "
        f"α = {pi.get('alpha_at_90pct', float('nan')):.2f}; 80% of gradient mass lies in "
        f"{100*pi.get('mass80_path_frac', float('nan')):.0f}% of the path."
    )
    conv = bench.get("convergence", {})
    st = conv.get("steps_to_threshold", {})
    if st:
        out.append("\n**Fig 5(b) — steps to reach δ_th (reduction vs uniform):**\n")
        heads = sorted(st)
        ths = sorted({float(t) for m in st.values() for t in m}, reverse=True)
        out.append("| δ_th | " + " | ".join(heads) + " |")
        out.append("|---|" + "---|" * len(heads))
        for th in ths:
            row = [str(th)]
            u = st.get("uniform", {}).get(str(th)) or st.get("uniform", {}).get(th)
            for h in heads:
                v = st[h].get(str(th)) or st[h].get(th)
                if v is None:
                    row.append("-")
                elif h != "uniform" and u:
                    row.append(f"{v} ({u/v:.1f}x)")
                else:
                    row.append(str(v))
            out.append("| " + " | ".join(row) + " |")
    lat = bench.get("latency", {})
    iso = lat.get("iso_delta", {})
    if iso:
        out.append("\n**Fig 6(a) — wall-clock at iso-δ (CPU, jitted; speedup vs uniform):**\n")
        out.append("| δ_th | method | m | latency s | speedup |")
        out.append("|---|---|---|---|---|")
        for th, methods in iso.items():
            for name, rec in methods.items():
                out.append(
                    f"| {th} | {name} | {rec['m']} | {rec['latency_s']:.3f} | "
                    f"{rec['speedup']:.2f}x |"
                )
    ovh = lat.get("probe_overhead", {})
    if ovh:
        pcts = [v["pct"] for v in ovh.values()]
        out.append(
            f"\n- **Fig 6(b) — probe overhead**: {min(pcts):.1f}–{max(pcts):.1f}% of "
            "total latency across n_int ∈ {2,4,8,16}, m ∈ {64,256} "
            "(paper: 0.2–3.2% on TITAN Xp)."
        )
    lmc = bench.get("lm_convergence", {})
    if lmc:
        out.append(
            "\n**Beyond-paper: NUIG on the assigned LM families** (trained reduced"
            " configs, PAD-embedding baseline, next-token probability target —"
            " zero baselines are degenerate for RMSNorm backbones, see"
            " benchmarks/lm_convergence.py):\n"
        )
        out.append("| arch | f range | step conc. (top-2 intervals) | δ uniform | δ paper | δ warp |")
        out.append("|---|---|---|---|---|---|")
        for arch, d in lmc.items():
            if "alloc_top2_frac" not in d:
                continue
            out.append(
                f"| {arch} | {d['f_range']:.3f} | {100*d['alloc_top2_frac']:.0f}% "
                f"| {d['uniform']:.5f} | {d['paper']:.5f} | {d['warp']:.5f} |"
            )
        out.append(
            "\nThe probe finds the same concentrated-Δf profile as the vision "
            "case (SSM/MoE backbones saturate late and sharply), the schedule "
            "concentrates steps where the probability moves, and NUIG beats "
            "uniform at iso-m on all four families (up to ~36% lower δ on "
            "jamba / mamba2). The full iso-convergence speedup curve is "
            "measured on the vision benchmark above — the paper's own domain."
        )
    return "\n".join(out)


PERF_LOG = """
The three hillclimbed cells (selection per the assignment: worst roofline
fraction, most collective-bound, most representative of the paper):
see the iteration log below. Baseline-only numbers for the other 37 cells
are in the §Roofline table.

### Iteration log (hypothesis → change → before → after → verdict)

**#1 — grouped-GQA einsum blocks head-axis TP (llama3-8b:train_4k)**
- *Hypothesis:* per-dot HLO attribution showed attention score matmuls with
  shape `f32[256,4096,128]·→[256,4096,8192]` ×64 — full GLOBAL batch per
  chip. The grouped `(B,S,kv=8,G=4,D)` layout leaves no head factor divisible
  by the 16-way model axis, so SPMD replicates attention 16×/chip.
  Expected win: ~16× on attention flops, visible in total flops/chip.
- *Change:* expand K/V to the full Q-head count in every attention path
  (`attention.py`); head axis (32/48/64) then shards cleanly.
- *Before → after:* flops/chip 8.36e14 → 8.34e14 — **refuted as a standalone
  fix**: the partitioner still replicated activations globally (see #2); the
  layout change was necessary but not sufficient.

**#2 — unconstrained activations let SPMD replicate the batch (llama3-8b:train_4k)**
- *Hypothesis:* 1.1 TB/chip of all-reduce + full-global-batch matmuls on
  every chip mean XLA chose "replicate activations, all-reduce partial sums"
  over "all-gather FSDP weights". Pinning activation layouts
  (`with_sharding_constraint` at block boundaries, MaxText-style) removes
  that choice. Expected: activation matmuls drop 16× (batch stays sharded),
  all-reduce drops to the gradient reduction only.
- *Change:* `sharding/context.py` activation policy + `constrain()` calls in
  embed/attention/mlp/moe/ssm/loss paths (composes with #1 — the "model"
  head constraint only binds on the expanded layout).
- *Before → after:* flops/chip **8.36e14 → 3.90e14**, all-reduce
  **1119 → 212 GiB/chip**, collective term **22.5 → 4.3 s**, useful-flops
  ratio **0.24 → 0.51**. **Confirmed** (jointly with #1). With the
  fusion-aware bytes model the cell lands at compute 1.98 s / memory 28.9 s /
  collective 4.3 s — memory-dominant; next lever is activation-width
  reduction inside attention (f32 score tensors) and remat policy tuning.

**#3 — MoE dispatch is collective-pathological; block-local routing alone
does NOT fix it (qwen3-moe train_4k — the most collective-bound cells)**
- *Hypothesis:* the dispatch argsorts ALL B·S·k routing slots globally and
  scatters into one (E, C, d) buffer: under pjit the global sort/rank force
  cross-shard data movement every layer. Baselines: qwen3-30b **398 s/step**
  collective, qwen3-235b **1558 s/step** (useful ratios 0.09/0.08). Napkin
  math said block-local routing (rank via per-block one-hot cumsum, no sort,
  per-block capacity) should leave only the EP all-to-all ≈ 2 GiB/chip/layer.
- *Change:* block-local dispatch with `moe_dispatch_blocks=32` aligned to
  the DP shards; (nb, E, C, d) buffer constrained (batch, model, -, -).
- *Before → after (qwen3-30b:train_4k):* collective **398 → 420 s/step**
  (all-reduce grew to 18.9 TiB/chip); useful-flops ratio improved 0.09→0.30
  and memory term 189→133 s, but the dominant term got WORSE. **Refuted.**
- *Lesson:* the collective explosion does not come from the sort — it comes
  from scatter/gather ACROSS the data↔model boundary, which XLA's SPMD
  partitioner lowers as replicate+all-reduce regardless of how locally the
  indices were computed. The production fix is an explicit `shard_map`
  dispatch that keeps tokens device-resident and issues a real
  `all_to_all` for the expert exchange (next iteration; the pjit-only
  formulation cannot express it). The in-tree implementation stays the
  sort-based dispatch (simpler, equal collectives, tested); per-block
  capacity is kept available via ``moe_dispatch_blocks``.

**#4 — bf16 serving weights for the memory-bound decode cell
(qwen3-moe-235b-a22b:decode_32k — worst memory-bound serving cell)**
- *Hypothesis:* decode reads every routed expert's weights each token;
  params are the dominant bytes. Casting serving weights f32→bf16 should
  cut the param-read share ~2× (KV is already bf16).
- *Change:* `--serve-dtype bfloat16` (cells.py `_cast_abstract`).
- *Before → after:* memory term **2.49 → 1.83 s/token-step** (bytes/chip
  2.04e12 → 1.50e12). **Confirmed** (the residual is expert-weight reads at
  batch 128 routing to all experts — next lever: int8 expert weights, or
  batched-expert decode islands).

**Instrument fixes made along the way** (required for honest terms; each
validated on a micro-HLO): scan-unrolled costing artifacts (XLA counts a
while body once — 8× undercount on a scan microbenchmark); kernel-level
ENTRY-computation byte accounting (cost_analysis' raw 'bytes accessed'
over-counts ~20×, descending into fusion bodies); convert-only fusions
treated as free with look-through operand charging (XLA:CPU materializes
f32 copies of bf16 matmul operands — a TPU converts in the operand
pipeline; this alone was 60% of the decode cell's apparent traffic).

**Negative/neutral results kept for the record:** int8 gradient compression
(#EF) leaves the costing collective bytes unchanged (197 GiB all-reduce) —
our implementation validates the NUMERICS of the compressed reduction
(error-feedback convergence is unit-tested) but the collective itself still
carries f32 in HLO; wiring the int8 payload through the wire format needs a
shard_map custom reduction, listed as future work.
"""


def main():
    pod1 = load("dryrun_pod16x16.json")
    pod2 = load("dryrun_pod2x16x16.json")
    bench = load("benchmarks.json")

    ok1 = sum(1 for r in pod1.values() if r.get("status") == "ok")
    sk1 = sum(1 for r in pod1.values() if r.get("status") == "skipped")
    ok2 = sum(1 for r in pod2.values() if r.get("status") == "ok")
    sk2 = sum(1 for r in pod2.values() if r.get("status") == "skipped")

    doc = f"""# EXPERIMENTS

All numbers are generated by checked-in harnesses (`benchmarks/`,
`repro.launch.dryrun`) from this container; regenerate with
`python tools/render_experiments.py`. The container is CPU-only: paper-claim
benchmarks measure real wall-clock on CPU, and the TPU-side analysis derives
from compiled-HLO artifacts (see §Roofline methodology).

## §Paper-claims — faithful reproduction

Setup mirrors the paper at CPU scale: an inception-style classifier (conv
stem + mixed towers + GAP) trained to ≥99% accuracy on a synthetic 10-class
task stands in for InceptionV3/ImageNet (DESIGN.md §6); IG interpolates raw
pixels against a black baseline; convergence is the completeness gap δ
(Eq. 3). `paper_nK` = the paper's NUIG with n_int=K; `warp`/`gauss` are our
beyond-paper schedules.

{bench_section(bench)}

**Verdict vs the paper's claims:** the qualitative structure reproduces
exactly (sharp-confidence interval, probe-guided concentration of steps,
iso-δ step reduction growing as δ_th tightens, sub-5% probe overhead). The
quantitative step-reduction at tight thresholds lands in the paper's 2.6–3.6×
band; see the tables above for exact factors per δ_th.

## §Dry-run — (architecture × shape) × mesh lower+compile

Every cell is lowered with explicit in/out shardings and compiled for the
production mesh; `memory_analysis()` proves the per-chip footprint and
`cost_analysis()`/HLO parsing feed §Roofline. Train cells: FSDP(+TP) rules,
8 microbatches, remat. Prefill/decode: TP(+FSDP weights); `long_500k`
decodes with the KV/state sequence-sharded on the data axis (SP).

### Single pod — (data=16, model=16), 256 chips — {ok1} ok / {sk1} skipped

{dryrun_table(pod1, full=True)}

### Multi-pod — (pod=2, data=16, model=16), 512 chips — {ok2} ok / {sk2} skipped

The multi-pod pass proves the `pod` axis shards (gradient all-reduce crosses
the DCN axis; batch spans pod×data). Roofline is reported single-pod per the
assignment; this table is the lower+compile + footprint proof.

{dryrun_table(pod2, full=False)}

## §Roofline — three-term analysis (single pod, per chip)

Methodology: `compute = flops/chip ÷ 197e12`, `memory = HBM bytes/chip ÷
819e9`, `collective = collective bytes/chip ÷ 50e9` (v5e constants). Sources:
the COSTING artifact (all scans unrolled — XLA cost analysis counts loop
bodies once, verified 8×-undercount on a scan microbenchmark) compiled for
the 256-way mesh; flops from `cost_analysis()`, memory bytes from
kernel-granularity ENTRY-computation traffic (fusion bodies excluded —
`cost_analysis()`'s raw 'bytes accessed' over-counts ~20× on the CPU
backend), collective bytes by summing operand sizes of all
all-gather/all-reduce/reduce-scatter/all-to-all/collective-permute ops.
`6ND/HLO` = MODEL_FLOPS (6·N_active·D train, 2·N_active·D inference) over
total HLO flops — the useful-compute ratio; `roofline frac` = MODEL_FLOPS ÷
(chips · peak · max-term), i.e. the MFU bound implied by the dominant term.

Note: this baseline table was produced with the kernel-granularity bytes
model; the §Perf iterations below additionally exclude XLA:CPU's
convert-only fusions (bf16→f32 matmul-operand copies a TPU would fuse),
which lowers memory terms by a further ~20–40% on serving cells — per-cell
before/after uses one instrument consistently within each iteration.

{roofline_table(pod1)}

## §Perf — hypothesis → change → measure → validate
{PERF_LOG}
"""
    out = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(out, "w") as f:
        f.write(doc)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
