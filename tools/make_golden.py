"""Regenerate the golden attribution fixtures under tests/golden/.

    PYTHONPATH=src python tools/make_golden.py

One .npz per registered attribution method, produced on the paper CNN
(random-init from a fixed seed — no trained checkpoint dependency) with a
fixed input batch and the paper schedule. ``tests/test_golden.py`` replays
the identical pipeline and compares within tolerance bands, so engine /
schedule / serving refactors cannot silently change what users see.

Regenerate ONLY when an intentional output-changing change lands, and say so
in the commit message — a diff here is the test's entire point.
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import CONFIG as CNN_CONFIG
from repro.core import perturb
from repro.core.api import Explainer
from repro.core.methods import METHODS
from repro.models import cnn

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "..", "tests", "golden")

# Frozen generation config — test_golden.py mirrors these exactly.
SEED = 0
BATCH = 2
M = 16
N_INT = 4
SCHEDULE = "paper"
N_SAMPLES = 2
SIGMA = 0.05
TARGETS = (1, 2)
# forward-only (perturbation) fixtures: CNN cell grid + mask budget
N_MASKS = 16
CELL = 4  # 32x32x3 -> 8x8 grid of 4x4x3 cells (S=64 positions)


def golden_inputs():
    params = cnn.init(CNN_CONFIG, jax.random.PRNGKey(SEED))
    s = CNN_CONFIG.image_size
    x = jax.random.uniform(
        jax.random.PRNGKey(SEED + 1), (BATCH, s, s, CNN_CONFIG.channels)
    )
    t = jnp.asarray(TARGETS, jnp.int32)
    f = lambda xs, tt: cnn.prob_fn(CNN_CONFIG, params, xs, tt)
    return f, x, jnp.zeros_like(x), t


def golden_explainer(f, method: str) -> Explainer:
    return Explainer(
        f,
        method=method,
        schedule=SCHEDULE,
        m=M,
        n_int=N_INT,
        n_samples=N_SAMPLES,
        sigma=SIGMA,
        sample_seed=SEED,
    )


def golden_perturb_result(f, x, bl, t, method: str):
    """Forward-only fixture pipeline: same seeded CNN and input batch,
    attributed over the 4x4x3 cell grid by ``repro.core.perturb`` — the
    scores are per CELL (B, 64), not per pixel."""
    img_shape = tuple(x.shape[1:])
    fc = perturb.cell_fn(f, img_shape, CELL)
    pe = perturb.PerturbExplainer(fc, method=method, n_masks=N_MASKS, seed=SEED)
    return pe.attribute(
        perturb.image_to_cells(x, CELL), perturb.image_to_cells(bl, CELL), t
    )


def _write(path: str, res) -> None:
    np.savez_compressed(
        path,
        attributions=np.asarray(res.attributions, np.float32),
        f_x=np.asarray(res.f_x, np.float32),
        f_baseline=np.asarray(res.f_baseline, np.float32),
        delta=np.asarray(res.delta, np.float32),
        meta=np.asarray([SEED, BATCH, M, N_INT, N_SAMPLES], np.int64),
    )
    print(f"{path}: |attr| mean {np.abs(np.asarray(res.attributions)).mean():.3e} "
          f"delta {np.asarray(res.delta)}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--forward-only", action="store_true",
        help="regenerate ONLY the perturbation-class fixtures "
        "(occlusion/rise/lime); gradient goldens stay untouched",
    )
    args = ap.parse_args()
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    f, x, bl, t = golden_inputs()
    for method in sorted(METHODS):
        spec = METHODS[method]
        if args.forward_only and not spec.forward_only:
            continue
        if spec.forward_only:
            res = golden_perturb_result(f, x, bl, t, method)
        else:
            res = golden_explainer(f, method).attribute(x, bl, t)
        _write(os.path.join(GOLDEN_DIR, f"cnn_{method}.npz"), res)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
